#!/usr/bin/env bash
# Tier-1 verification (matches ROADMAP.md): the full pytest suite from the
# repo root with the src layout on the path.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
