#!/usr/bin/env bash
# Tier-1 verification (matches ROADMAP.md): the pytest suite from the repo
# root with the src layout on the path.  Tests marked `slow` are deselected
# to keep tier-1 fast — run them with `make test-all` (or plain pytest).
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    -m "not slow" "$@"
