#!/usr/bin/env bash
# Tier-1 verification (matches ROADMAP.md): the pytest suite from the repo
# root with the src layout on the path.  Tests marked `slow` are deselected
# to keep tier-1 fast — run them with `make test-all` (or plain pytest).
set -euo pipefail
cd "$(dirname "$0")/.."
if [ "$#" -gt 0 ]; then
    # explicit args (paths / -k filters): single invocation, as before
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
        -m "not slow" "$@"
else
    # serve engine first: the continuous-batching equivalence/slot-reuse
    # guarantees (contiguous AND paged KV backends) are the newest
    # invariants and the cheapest to break
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
        -m "not slow" tests/test_serve_engine.py tests/test_paged_engine.py \
        tests/test_paged_pool.py tests/test_serve.py
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
        -m "not slow" --ignore=tests/test_serve_engine.py \
        --ignore=tests/test_paged_engine.py \
        --ignore=tests/test_paged_pool.py \
        --ignore=tests/test_serve.py
fi
