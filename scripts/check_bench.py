#!/usr/bin/env python
"""Benchmark regression gate: fresh smoke benches vs committed baselines.

Gates four reports against the committed baseline JSONs in
``benchmarks/results/``:

* ``serve`` — ``benchmarks.bench_serve --smoke`` (continuous batching +
  paged KV);
* ``traffic`` — ``benchmarks.bench_traffic --smoke`` (Poisson-arrival
  replay; deterministic token counts exact, requests/sec and
  wall_speedup banded from below, TTFT/TPOT percentiles banded from
  *above* — latency regressions fail, improvements always pass);
* ``train`` — ``benchmarks.bench_train_loop --smoke`` (period-fused
  runner vs the per-step oracle; wall-clock speedups banded like serve,
  workload identity exact);
* ``iteration`` — ``benchmarks.bench_iteration_time`` (paper Table 1
  through the analytic event-timeline model; every number is
  deterministic model time, so the whole table is compared near-exactly
  — any drift means the profiler/scheduler/time model changed and the
  baseline must be regenerated deliberately);
* ``async`` — ``benchmarks.bench_async`` (async two-tier runtime vs
  barriered DreamDDP over the SimNet scenario library; deterministic
  model time like ``iteration``, so makespans/speedups/staleness are
  near-exact and the staleness histogram is identity — any drift means
  the async executor's time model changed).

Two classes of metric:

* **near-exact** — the paged section's accounting (``decode_tokens``,
  ``kv_bytes_ratio``, ``peak_kv_bytes``, ``peak_pages``) is
  EOS-independent (every request decodes its full budget and page
  traffic depends only on request lengths), so it must match the
  baseline to within ``--exact-tol`` (default 0.5% — tight enough that
  a single dropped token or leaked page shows up).  Any larger drift
  means the engine's scheduling/paging behaviour changed — intentional
  changes regenerate the baseline (``make serve-bench``).
* **banded** — wall-clock numbers (``speedup``, ``goodput_ratio``) are
  noisy on shared CI hardware, and the EOS-picking workload's
  ``useful_tokens`` can move if an XLA upgrade flips a greedy argmax
  tie, so only a *regression* beyond the tolerance band fails: fresh
  must be at least ``(1 - tol)`` of baseline (default ``tol`` 0.5;
  improvements always pass).

Also fails when the fresh run itself misses its absolute bars (the bench
exits non-zero) or when the workload identity fields diverge — that means
the baseline is stale and must be regenerated, not waved through.

Usage::

    python scripts/check_bench.py                 # run all fresh benches
    python scripts/check_bench.py --only serve,train
    # compare an existing serve report without running any bench:
    python scripts/check_bench.py --only serve --fresh f.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_RESULTS = os.path.join(_ROOT, "benchmarks", "results")
BASELINE = os.path.join(_RESULTS, "bench_serve.json")
BASELINE_TRAFFIC = os.path.join(_RESULTS, "bench_traffic.json")
BASELINE_TRAIN = os.path.join(_RESULTS, "bench_train_loop.json")
BASELINE_ITER = os.path.join(_RESULTS, "bench_iteration_time.json")
BASELINE_ASYNC = os.path.join(_RESULTS, "bench_async.json")

# workload identity: a mismatch means stale baseline, not a regression
IDENTITY = ("n_requests", "short_len", "long_len", "gen", "max_batch",
            "max_seq", "page_size", "long_every", "eos_frac")
# useful_tokens/useful_decode_tokens depend on WHERE the greedy stream
# hits its picked EOS, so an XLA upgrade flipping one argmax tie can
# move them legitimately — banded, not near-exact.  The paged workload
# has no EOS (every request decodes its full budget) and its page
# accounting depends only on request lengths, so those stay near-exact.
EXACT_ROW = ()
EXACT_PAGED = ("decode_tokens", "kv_bytes_ratio")
EXACT_PAGED_NESTED = (("paged", "peak_kv_bytes"), ("paged", "peak_pages"),
                      ("contiguous", "kv_bytes"))
BANDED_ROW = ("speedup", "useful_tokens", "useful_decode_tokens")
BANDED_PAGED = ("goodput_ratio",)

# traffic replay: the seeded trace fixes every token, so the counts are
# exact (tol 0); throughput/speedup regress from below, latency
# percentiles regress from above (lower is better)
TRAFFIC_IDENTITY = ("n_requests", "rate_rps", "seed", "max_batch",
                    "decode_block", "prompt_lens", "gens")
TRAFFIC_EXACT = ("prompt_tokens", "generated_tokens")
TRAFFIC_BANDED = ("requests_per_s", "wall_speedup")
TRAFFIC_BANDED_MAX = ("ttft_p50_s", "ttft_p99_s",
                      "tpot_p50_s", "tpot_p99_s")

# train loop: workload identity exact, wall-clock speedups banded
TRAIN_IDENTITY = ("model", "family", "workers", "H", "steps",
                  "batch_per_worker", "seq")
TRAIN_BANDED = ("speedup", "compiled_speedup", "best_speedup")

# Table 1: pure model time — every float is deterministic and compared
# near-exactly; model/workers are the row identity
ITER_IDENTITY = ("model", "workers")
ITER_EXACT = ("ssgd", "ascwfbp", "flsgd", "plsgd-enp", "dreamddp",
              "S1_vs_ascwfbp", "S2_vs_flsgd")

# async vs sync: pure model time from seeded scenarios — makespans and
# staleness stats near-exact; the histogram (and discrete counters)
# must match the baseline verbatim
ASYNC_IDENTITY = ("scenario", "workers", "datacenters", "periods", "H",
                  "merge_rule", "pushes_per_merge", "merges",
                  "max_staleness", "staleness_hist")
ASYNC_EXACT = ("sync_makespan", "async_makespan", "speedup",
               "mean_staleness")

EXACT_TOL = 0.005


def _fail(problems: list[str], msg: str) -> None:
    problems.append(msg)
    print(f"REGRESSION: {msg}")


def _cmp_exact(problems, where, key, base, fresh, tol=EXACT_TOL):
    if abs(fresh - base) > tol * max(abs(base), 1.0):
        _fail(problems, f"{where}.{key}: fresh {fresh!r} != "
                        f"baseline {base!r} (deterministic metric, "
                        f"±{tol:.1%})")


def _cmp_banded(problems, where, key, base, fresh, tol):
    floor = base * (1.0 - tol)
    if fresh < floor:
        _fail(problems, f"{where}.{key}: fresh {fresh:.3f} < "
                        f"{floor:.3f} (baseline {base:.3f} - {tol:.0%} "
                        f"band)")


def _cmp_banded_max(problems, where, key, base, fresh, tol):
    """Lower-is-better metric (latency): only an *increase* beyond the
    band fails; any improvement passes."""
    ceiling = base * (1.0 + tol)
    if fresh > ceiling:
        _fail(problems, f"{where}.{key}: fresh {fresh:.4f} > "
                        f"{ceiling:.4f} (baseline {base:.4f} + {tol:.0%} "
                        f"band, lower is better)")


def _pair_rows(problems, name, base_rows, fresh_rows):
    if len(base_rows) != len(fresh_rows):
        _fail(problems, f"{name}: baseline has {len(base_rows)} rows, "
                        f"fresh has {len(fresh_rows)} — stale baseline?")
        return []
    return list(zip(base_rows, fresh_rows, strict=True))


def _check_section(problems, where, b, f, *, exact, exact_nested,
                   banded, tol, exact_tol, identity=IDENTITY,
                   banded_max=()):
    """One baseline/fresh row pair.  Missing-key policy is uniform:
    keys absent from the *baseline* are skipped (an older baseline
    simply doesn't gate the newer metric); a gated key absent from the
    *fresh* report is a clean failure (report-format skew), never a
    traceback."""

    def present(section, key, container):
        if key in container:
            return True
        _fail(problems, f"{section}.{key}: missing from the fresh "
                        f"report — bench/report version skew, "
                        f"regenerate the baseline")
        return False

    for key in identity:
        if key in b and b.get(key) != f.get(key):
            _fail(problems, f"{where}.{key}: workload changed "
                            f"({b.get(key)!r} -> {f.get(key)!r}) — "
                            f"regenerate the baseline")
    for key in exact:
        if key in b and present(where, key, f):
            _cmp_exact(problems, where, key, b[key], f[key], exact_tol)
    for outer, key in exact_nested:
        if key in b.get(outer, {}) \
                and present(f"{where}.{outer}", key, f.get(outer, {})):
            _cmp_exact(problems, f"{where}.{outer}", key,
                       b[outer][key], f[outer][key], exact_tol)
    for key in banded:
        if key in b and present(where, key, f):
            _cmp_banded(problems, where, key, b[key], f[key], tol)
    for key in banded_max:
        if key in b and present(where, key, f):
            _cmp_banded_max(problems, where, key, b[key], f[key], tol)


def compare(baseline: dict, fresh: dict, *, tol: float,
            exact_tol: float = EXACT_TOL) -> list[str]:
    """The serve report (``bench_serve.json``)."""
    problems: list[str] = []
    for b, f in _pair_rows(problems, "rows", baseline.get("rows", []),
                           fresh.get("rows", [])):
        _check_section(
            problems, f"rows[batch={b.get('max_batch')},gen={b.get('gen')}]",
            b, f, exact=EXACT_ROW, exact_nested=(), banded=BANDED_ROW,
            tol=tol, exact_tol=exact_tol)
    for b, f in _pair_rows(problems, "paged_rows",
                           baseline.get("paged_rows", []),
                           fresh.get("paged_rows", [])):
        _check_section(
            problems, f"paged_rows[batch={b.get('max_batch')}]", b, f,
            exact=EXACT_PAGED, exact_nested=EXACT_PAGED_NESTED,
            banded=BANDED_PAGED, tol=tol, exact_tol=exact_tol)
    return problems


def compare_traffic(baseline: dict, fresh: dict, *, tol: float
                    ) -> list[str]:
    """The traffic-replay report (``bench_traffic.json``): trace counts
    exact (the seeded trace fixes every token), throughput/speedup
    banded from below, latency percentiles banded from above."""
    problems: list[str] = []
    for b, f in _pair_rows(problems, "traffic_rows",
                           baseline.get("rows", []),
                           fresh.get("rows", [])):
        _check_section(
            problems, f"traffic_rows[rate={b.get('rate_rps')}]", b, f,
            exact=TRAFFIC_EXACT, exact_nested=(), banded=TRAFFIC_BANDED,
            banded_max=TRAFFIC_BANDED_MAX, tol=tol, exact_tol=0.0,
            identity=TRAFFIC_IDENTITY)
    return problems


def compare_train(baseline: dict, fresh: dict, *, tol: float,
                  exact_tol: float = EXACT_TOL) -> list[str]:
    """The train-loop report (``bench_train_loop.json``): identity
    fields exact, fused/compiled speedups banded (regression-only)."""
    problems: list[str] = []
    for b, f in _pair_rows(problems, "train_rows",
                           baseline.get("rows", []),
                           fresh.get("rows", [])):
        _check_section(
            problems, f"train_rows[{b.get('model')}]", b, f,
            exact=(), exact_nested=(), banded=TRAIN_BANDED,
            tol=tol, exact_tol=exact_tol, identity=TRAIN_IDENTITY)
    return problems


def compare_iteration(baseline: dict, fresh: dict, *,
                      exact_tol: float = EXACT_TOL) -> list[str]:
    """The Table-1 report (``bench_iteration_time.json``): analytic
    model time only — everything near-exact."""
    problems: list[str] = []
    if baseline.get("H") != fresh.get("H"):
        _fail(problems, f"iteration.H: {baseline.get('H')} -> "
                        f"{fresh.get('H')} — regenerate the baseline")
    for b, f in _pair_rows(problems, "iter_rows",
                           baseline.get("rows", []),
                           fresh.get("rows", [])):
        _check_section(
            problems,
            f"iter_rows[{b.get('model')},W={b.get('workers')}]", b, f,
            exact=ITER_EXACT, exact_nested=(), banded=(),
            tol=0.0, exact_tol=exact_tol, identity=ITER_IDENTITY)
    return problems


def compare_async(baseline: dict, fresh: dict, *,
                  exact_tol: float = EXACT_TOL) -> list[str]:
    """The async-vs-sync report (``bench_async.json``): deterministic
    model time only — everything near-exact, histograms verbatim."""
    problems: list[str] = []
    if baseline.get("H") != fresh.get("H"):
        _fail(problems, f"async.H: {baseline.get('H')} -> "
                        f"{fresh.get('H')} — regenerate the baseline")
    for b, f in _pair_rows(problems, "async_rows",
                           baseline.get("rows", []),
                           fresh.get("rows", [])):
        _check_section(
            problems, f"async_rows[{b.get('scenario')}]", b, f,
            exact=ASYNC_EXACT, exact_nested=(), banded=(),
            tol=0.0, exact_tol=exact_tol, identity=ASYNC_IDENTITY)
    return problems


def _load_baseline(path: str, make_cmd: str) -> dict | None:
    if not os.path.exists(path):
        print(f"no baseline at {path}; run `{make_cmd}` and commit the "
              f"result")
        return None
    with open(path) as fh:
        return json.load(fh)


def _fresh_report(fresh_arg, bench_main, bench_args, name):
    """Run a bench smoke unless an existing report was passed.  Returns
    (report, rc) — rc != 0 means the fresh run missed its absolute
    bars."""
    if fresh_arg is None:
        out = os.path.join(tempfile.mkdtemp(prefix="check_bench_"),
                           f"{name}.json")
        rc = bench_main(bench_args + ["--out", out])
        if rc != 0:
            print(f"REGRESSION: fresh {name} run missed its absolute "
                  f"bars")
            return None, rc
        fresh_arg = out
    with open(fresh_arg) as fh:
        return json.load(fh), 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--baseline-train", default=BASELINE_TRAIN)
    ap.add_argument("--baseline-iteration", default=BASELINE_ITER)
    ap.add_argument("--baseline-traffic", default=BASELINE_TRAFFIC)
    ap.add_argument("--baseline-async", default=BASELINE_ASYNC)
    ap.add_argument("--fresh", default=None,
                    help="existing fresh serve report (skip the bench)")
    ap.add_argument("--fresh-traffic", default=None,
                    help="existing fresh traffic-replay report")
    ap.add_argument("--fresh-train", default=None,
                    help="existing fresh train-loop report")
    ap.add_argument("--fresh-iteration", default=None,
                    help="existing fresh iteration-time report")
    ap.add_argument("--fresh-async", default=None,
                    help="existing fresh async-vs-sync report")
    ap.add_argument("--only", default="serve,traffic,train,iteration,async",
                    help="comma list of gates to run")
    ap.add_argument("--tol", type=float, default=0.5,
                    help="tolerance band for wall-clock metrics")
    ap.add_argument("--exact-tol", type=float, default=EXACT_TOL,
                    help="band for deterministic metrics")
    args = ap.parse_args(argv)
    gates = {g.strip() for g in args.only.split(",") if g.strip()}
    unknown = gates - {"serve", "traffic", "train", "iteration", "async"}
    if unknown:
        ap.error(f"unknown gates {sorted(unknown)}")

    sys.path.insert(0, _ROOT)
    problems: list[str] = []

    if "serve" in gates:
        baseline = _load_baseline(args.baseline, "make serve-bench")
        if baseline is None:
            return 1
        from benchmarks import bench_serve
        fresh, rc = _fresh_report(args.fresh, bench_serve.main,
                                  ["--smoke"], "bench_serve")
        if rc != 0:
            return rc
        problems += compare(baseline, fresh, tol=args.tol,
                            exact_tol=args.exact_tol)

    if "traffic" in gates:
        baseline = _load_baseline(args.baseline_traffic,
                                  "make serve-bench")
        if baseline is None:
            return 1
        from benchmarks import bench_traffic
        fresh, rc = _fresh_report(args.fresh_traffic, bench_traffic.main,
                                  ["--smoke"], "bench_traffic")
        if rc != 0:
            return rc
        problems += compare_traffic(baseline, fresh, tol=args.tol)

    if "train" in gates:
        baseline = _load_baseline(args.baseline_train, "make train-bench")
        if baseline is None:
            return 1
        from benchmarks import bench_train_loop
        fresh, rc = _fresh_report(args.fresh_train, bench_train_loop.main,
                                  ["--smoke"], "bench_train_loop")
        if rc != 0:
            return rc
        problems += compare_train(baseline, fresh, tol=args.tol,
                                  exact_tol=args.exact_tol)

    if "iteration" in gates:
        baseline = _load_baseline(args.baseline_iteration,
                                  "make iteration-bench")
        if baseline is None:
            return 1
        from benchmarks import bench_iteration_time
        fresh, rc = _fresh_report(args.fresh_iteration,
                                  bench_iteration_time.main, [],
                                  "bench_iteration_time")
        if rc != 0:
            return rc
        problems += compare_iteration(baseline, fresh,
                                      exact_tol=args.exact_tol)

    if "async" in gates:
        baseline = _load_baseline(args.baseline_async, "make async-bench")
        if baseline is None:
            return 1
        from benchmarks import bench_async
        fresh, rc = _fresh_report(args.fresh_async, bench_async.main, [],
                                  "bench_async")
        if rc != 0:
            return rc
        problems += compare_async(baseline, fresh,
                                  exact_tol=args.exact_tol)

    if problems:
        print(f"check_bench: {len(problems)} regression(s) vs committed "
              f"baselines")
        return 1
    print(f"check_bench: fresh runs within bands of the committed "
          f"baselines ({', '.join(sorted(gates))})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
