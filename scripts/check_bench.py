#!/usr/bin/env python
"""Benchmark regression gate: fresh ``bench_serve --smoke`` vs baseline.

Compares a fresh smoke run of ``benchmarks.bench_serve`` (or an existing
report passed with ``--fresh``) against the committed baseline JSON in
``benchmarks/results/``.  Two classes of metric:

* **near-exact** — the paged section's accounting (``decode_tokens``,
  ``kv_bytes_ratio``, ``peak_kv_bytes``, ``peak_pages``) is
  EOS-independent (every request decodes its full budget and page
  traffic depends only on request lengths), so it must match the
  baseline to within ``--exact-tol`` (default 0.5% — tight enough that
  a single dropped token or leaked page shows up).  Any larger drift
  means the engine's scheduling/paging behaviour changed — intentional
  changes regenerate the baseline (``make serve-bench``).
* **banded** — wall-clock numbers (``speedup``, ``goodput_ratio``) are
  noisy on shared CI hardware, and the EOS-picking workload's
  ``useful_tokens`` can move if an XLA upgrade flips a greedy argmax
  tie, so only a *regression* beyond the tolerance band fails: fresh
  must be at least ``(1 - tol)`` of baseline (default ``tol`` 0.5;
  improvements always pass).

Also fails when the fresh run itself misses its absolute bars (the bench
exits non-zero) or when the workload identity fields diverge — that means
the baseline is stale and must be regenerated, not waved through.

Usage::

    python scripts/check_bench.py                 # run fresh smoke bench
    python scripts/check_bench.py --fresh f.json  # compare existing file
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(_ROOT, "benchmarks", "results",
                        "bench_serve.json")

# workload identity: a mismatch means stale baseline, not a regression
IDENTITY = ("n_requests", "short_len", "long_len", "gen", "max_batch",
            "max_seq", "page_size", "long_every", "eos_frac")
# useful_tokens/useful_decode_tokens depend on WHERE the greedy stream
# hits its picked EOS, so an XLA upgrade flipping one argmax tie can
# move them legitimately — banded, not near-exact.  The paged workload
# has no EOS (every request decodes its full budget) and its page
# accounting depends only on request lengths, so those stay near-exact.
EXACT_ROW = ()
EXACT_PAGED = ("decode_tokens", "kv_bytes_ratio")
EXACT_PAGED_NESTED = (("paged", "peak_kv_bytes"), ("paged", "peak_pages"),
                      ("contiguous", "kv_bytes"))
BANDED_ROW = ("speedup", "useful_tokens", "useful_decode_tokens")
BANDED_PAGED = ("goodput_ratio",)

EXACT_TOL = 0.005


def _fail(problems: list[str], msg: str) -> None:
    problems.append(msg)
    print(f"REGRESSION: {msg}")


def _cmp_exact(problems, where, key, base, fresh, tol=EXACT_TOL):
    if abs(fresh - base) > tol * max(abs(base), 1.0):
        _fail(problems, f"{where}.{key}: fresh {fresh!r} != "
                        f"baseline {base!r} (deterministic metric, "
                        f"±{tol:.1%})")


def _cmp_banded(problems, where, key, base, fresh, tol):
    floor = base * (1.0 - tol)
    if fresh < floor:
        _fail(problems, f"{where}.{key}: fresh {fresh:.3f} < "
                        f"{floor:.3f} (baseline {base:.3f} - {tol:.0%} "
                        f"band)")


def _pair_rows(problems, name, base_rows, fresh_rows):
    if len(base_rows) != len(fresh_rows):
        _fail(problems, f"{name}: baseline has {len(base_rows)} rows, "
                        f"fresh has {len(fresh_rows)} — stale baseline?")
        return []
    return list(zip(base_rows, fresh_rows))


def _check_section(problems, where, b, f, *, exact, exact_nested,
                   banded, tol, exact_tol):
    """One baseline/fresh row pair.  Missing-key policy is uniform:
    keys absent from the *baseline* are skipped (an older baseline
    simply doesn't gate the newer metric); a gated key absent from the
    *fresh* report is a clean failure (report-format skew), never a
    traceback."""

    def present(section, key, container):
        if key in container:
            return True
        _fail(problems, f"{section}.{key}: missing from the fresh "
                        f"report — bench/report version skew, "
                        f"regenerate the baseline")
        return False

    for key in IDENTITY:
        if key in b and b.get(key) != f.get(key):
            _fail(problems, f"{where}.{key}: workload changed "
                            f"({b.get(key)!r} -> {f.get(key)!r}) — "
                            f"regenerate the baseline")
    for key in exact:
        if key in b and present(where, key, f):
            _cmp_exact(problems, where, key, b[key], f[key], exact_tol)
    for outer, key in exact_nested:
        if key in b.get(outer, {}) \
                and present(f"{where}.{outer}", key, f.get(outer, {})):
            _cmp_exact(problems, f"{where}.{outer}", key,
                       b[outer][key], f[outer][key], exact_tol)
    for key in banded:
        if key in b and present(where, key, f):
            _cmp_banded(problems, where, key, b[key], f[key], tol)


def compare(baseline: dict, fresh: dict, *, tol: float,
            exact_tol: float = EXACT_TOL) -> list[str]:
    problems: list[str] = []
    for b, f in _pair_rows(problems, "rows", baseline.get("rows", []),
                           fresh.get("rows", [])):
        _check_section(
            problems, f"rows[batch={b.get('max_batch')},gen={b.get('gen')}]",
            b, f, exact=EXACT_ROW, exact_nested=(), banded=BANDED_ROW,
            tol=tol, exact_tol=exact_tol)
    for b, f in _pair_rows(problems, "paged_rows",
                           baseline.get("paged_rows", []),
                           fresh.get("paged_rows", [])):
        _check_section(
            problems, f"paged_rows[batch={b.get('max_batch')}]", b, f,
            exact=EXACT_PAGED, exact_nested=EXACT_PAGED_NESTED,
            banded=BANDED_PAGED, tol=tol, exact_tol=exact_tol)
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--fresh", default=None,
                    help="existing fresh report (skip running the bench)")
    ap.add_argument("--tol", type=float, default=0.5,
                    help="tolerance band for wall-clock metrics")
    ap.add_argument("--exact-tol", type=float, default=EXACT_TOL,
                    help="band for deterministic token/page metrics")
    args = ap.parse_args(argv)

    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline}; run `make serve-bench` "
              f"and commit the result")
        return 1
    with open(args.baseline) as fh:
        baseline = json.load(fh)

    if args.fresh is None:
        sys.path.insert(0, _ROOT)
        from benchmarks import bench_serve
        out = os.path.join(tempfile.mkdtemp(prefix="check_bench_"),
                           "bench_serve.json")
        rc = bench_serve.main(["--smoke", "--out", out])
        if rc != 0:
            print("REGRESSION: fresh bench run missed its absolute bars")
            return rc
        args.fresh = out
    with open(args.fresh) as fh:
        fresh = json.load(fh)

    problems = compare(baseline, fresh, tol=args.tol,
                       exact_tol=args.exact_tol)
    if problems:
        print(f"check_bench: {len(problems)} regression(s) vs "
              f"{args.baseline}")
        return 1
    print(f"check_bench: fresh run within bands of {args.baseline}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
