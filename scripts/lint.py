#!/usr/bin/env python3
"""Run the repro.lint static analyzer from the repo root.

Equivalent to ``PYTHONPATH=src python -m repro.lint ...`` — this
wrapper just puts the src layout on sys.path so it works from a bare
checkout (the CI lint job runs before dependencies are installed;
repro.lint is stdlib-only by design).
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.lint.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
