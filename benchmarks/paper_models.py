"""Layer profiles of the paper's four experiment models.

The paper evaluates ResNet-18 (CIFAR-10), ResNet-50 (CIFAR-100), GPT-2
small and a 175M Llama-2, on two clusters (32x 2080Ti @ 1 GB/s ethernet;
32x A6000 @ 20 GB/s).  Table 1/2-style benchmarks consume per-layer
``(name, n_params, fwd_flops)`` tables: the LLMs come from the live
:class:`DecoderLM` cost model; the CIFAR ResNets are derived here from the
standard architecture arithmetic (3x3 convs, basic/bottleneck blocks).
"""

from __future__ import annotations

from repro.core.profiler import (HardwareSpec, LayerProfile,
                                 analytic_profile)
from repro.models.transformer import DecoderLM, LMConfig

__all__ = ["PAPER_MODELS", "CLUSTER_2080TI", "A6000_EFFECTIVE",
           "paper_profile"]

# Effective per-worker ring bandwidth back-solved from the paper's own
# Table 1 (nominal "1 GB/s" / "20 GB/s" ethernet is shared per machine):
# resnet: (2.40 - 0.57) * 5/4 s for 2 * 46.8 MB fp32 -> ~31 MB/s;
# gpt2:   (8.67 - 2.08) * 5/4 s for 2 * 496 MB fp32 -> ~125 MB/s.
CLUSTER_2080TI = HardwareSpec(
    name="2080ti-x32", peak_flops=13.4e12, hbm_bandwidth=616e9,
    bandwidth=3.1e7, latency=3e-5, n_workers=32, mfu=0.20)

A6000_EFFECTIVE = HardwareSpec(
    name="a6000x32", peak_flops=155e12, hbm_bandwidth=768e9,
    bandwidth=1.25e8, latency=3e-5, n_workers=32, mfu=0.12)


# ---------------------------------------------------------------------------
# CIFAR ResNets (paper's vision models)
# ---------------------------------------------------------------------------

def _conv(cin, cout, k, hw):
    params = k * k * cin * cout
    flops = 2.0 * params * hw * hw
    return params, flops


def resnet_layers(depth: int, batch: int):
    """(name, params, fwd_flops) per residual stage-block, CIFAR 32x32."""
    basic = depth == 18
    blocks = [2, 2, 2, 2] if basic else [3, 4, 6, 3]
    widths = [64, 128, 256, 512]
    expansion = 1 if basic else 4
    out = []
    p, f = _conv(3, 64, 3, 32)
    out.append(("stem", p + 128, batch * f))
    cin = 64
    hw = 32
    for s, (n, w) in enumerate(zip(blocks, widths, strict=True)):
        if s > 0:
            hw //= 2
        for b in range(n):
            if basic:
                p1, f1 = _conv(cin, w, 3, hw)
                p2, f2 = _conv(w, w, 3, hw)
                params, flops = p1 + p2, f1 + f2
                cout = w
            else:
                p1, f1 = _conv(cin, w, 1, hw)
                p2, f2 = _conv(w, w, 3, hw)
                p3, f3 = _conv(w, w * 4, 1, hw)
                params, flops = p1 + p2 + p3, f1 + f2 + f3
                cout = w * 4
            if b == 0 and cin != cout:
                ps, fs = _conv(cin, cout, 1, hw)
                params += ps
                flops += fs
            params += 4 * cout                      # BN
            out.append((f"s{s}b{b}", params, batch * flops))
            cin = cout
    ncls = 10 if basic else 100
    out.append(("fc", cin * ncls + ncls, batch * 2.0 * cin * ncls))
    return out


# ---------------------------------------------------------------------------
# Paper LLMs
# ---------------------------------------------------------------------------

GPT2_SMALL = LMConfig(
    name="gpt2-small", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=12, d_ff=3072, vocab=50257, mlp_kind="gelu",
    norm_kind="layernorm", tie_embeddings=True)

LLAMA2_175M = LMConfig(
    name="llama2-175m", n_layers=12, d_model=1024, n_heads=16,
    n_kv_heads=16, d_ff=2752, vocab=32000, mlp_kind="swiglu",
    norm_kind="rmsnorm", tie_embeddings=True)

PAPER_MODELS = {
    "resnet18": dict(kind="resnet", depth=18, batch=128,
                     cluster=CLUSTER_2080TI),
    "resnet50": dict(kind="resnet", depth=50, batch=128,
                     cluster=CLUSTER_2080TI),
    "gpt2": dict(kind="lm", cfg=GPT2_SMALL, batch=8, seq=1024,
                 cluster=A6000_EFFECTIVE),
    "llama2": dict(kind="lm", cfg=LLAMA2_175M, batch=8, seq=1024,
                   cluster=A6000_EFFECTIVE),
}


def paper_profile(name: str, *, n_workers: int = 32,
                  bandwidth: float | None = None) -> LayerProfile:
    spec = PAPER_MODELS[name]
    hw = spec["cluster"].replace(n_workers=n_workers)
    if bandwidth is not None:
        hw = hw.replace(bandwidth=bandwidth)
    if spec["kind"] == "resnet":
        layers = resnet_layers(spec["depth"], spec["batch"])
    else:
        layers = DecoderLM(spec["cfg"]).layer_costs(spec["batch"],
                                                    spec["seq"])
    # the paper synchronizes fp32 tensors (PyTorch DDP default)
    return analytic_profile(layers, hw, param_dtype_bytes=4)
