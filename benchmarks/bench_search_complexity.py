"""Paper Fig. 16: scheduling search complexity — DreamDDP's pruned DFS vs
brute force (theoretical count + measured wall time + visited nodes)."""

from __future__ import annotations

import time

from repro.core.schedule import (brute_force_count, brute_force_schedule,
                                 dreamddp_schedule)

from .paper_models import PAPER_MODELS, paper_profile

H = 5


def run(max_bf_layers: int = 18, csv: bool = True) -> list[dict]:
    rows = []
    for name in PAPER_MODELS:
        full = paper_profile(name, n_workers=32)
        L_full = len(full)
        prof = type(full)(full.layers[:min(L_full, max_bf_layers)],
                          full.hw)
        L = len(prof)

        t0 = time.perf_counter()
        dd = dreamddp_schedule(prof, H)
        t_dd = time.perf_counter() - t0
        t0 = time.perf_counter()
        brute_force_schedule(prof, H)
        t_bf = time.perf_counter() - t0

        rows.append({
            "model": name, "L_full": L_full, "L_compared": L,
            "bf_count_full_theory": brute_force_count(L_full, H),
            "dd_bound_full_theory": 2 ** min(L_full - H, H),
            "bf_solutions": brute_force_count(L, H),
            "dd_nodes": dd.stats.nodes_visited,
            "dd_ms": t_dd * 1e3, "bf_ms": t_bf * 1e3,
            "speedup": t_bf / max(t_dd, 1e-9),
        })
    if csv:
        keys = list(rows[0])
        print(",".join(keys))
        for r in rows:
            print(",".join(f"{r[k]:.4g}" if isinstance(r[k], float)
                           else str(r[k]) for k in keys))
    return rows


if __name__ == "__main__":
    run()
