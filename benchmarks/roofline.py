"""§Roofline: three-term table from the dry-run artifacts.

Usage::

    PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
        [--mesh single_pod] [--markdown]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.analysis.roofline import V5EConstants, roofline_from_artifact

_ADVICE = {
    ("train", "collective"): "overlap/shrink FSDP gathers & sync bytes "
                             "(int8 pod-axis sync; gather once per step, "
                             "not per microbatch)",
    ("train", "compute"): "raise MFU: bigger microbatch, fused attention "
                          "kernel, fewer remat recomputes",
    ("train", "memory"): "fuse optimizer (fused_adam_sync), bf16 grads, "
                         "cut remat stash traffic",
    ("prefill", "compute"): "flash-attention kernel; larger q-chunk",
    ("prefill", "memory"): "KV/layout fusion; avoid repeated-KV "
                           "materialization",
    ("prefill", "collective"): "shard sequence instead of batch to cut "
                               "activation gathers",
    ("decode", "memory"): "decode is weight-streaming-bound: batch more "
                          "requests per step or quantize weights",
    ("decode", "collective"): "avoid per-token weight gathers: "
                              "weight-stationary layout over model axis",
    ("decode", "compute"): "decode should not be compute-bound: check "
                           "dispatch-einsum overhead",
}


def load_artifacts(d: str, mesh: str | None = None) -> list[dict]:
    arts = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            a = json.load(f)
        if mesh is None or a["mesh"] == mesh:
            arts.append(a)
    return arts


def table(arts: list[dict], *, markdown: bool = False) -> list[dict]:
    rows = []
    for a in arts:
        if "flops" not in a.get("cost_analysis", {}):
            continue
        t = roofline_from_artifact(a)
        mem_gb = a["memory_analysis"].get("total_bytes", 0) / 1e9
        rows.append({
            "arch": a["arch"], "shape": a["shape"], "mesh": a["mesh"],
            "compute_s": t.compute_s, "memory_s": t.memory_s,
            "collective_s": t.collective_s, "dominant": t.dominant,
            "useful_ratio": t.useful_ratio,
            "roofline_fraction": t.roofline_fraction,
            "roofline_cc": t.roofline_fraction_cc,
            "mem_gb_per_dev": mem_gb,
            "advice": _ADVICE.get((a["kind"], t.dominant), ""),
        })
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    if markdown:
        hdr = ("| arch | shape | mesh | compute s | memory s | coll s | "
               "dominant | useful | roofline | cc-frac | GB/dev |")
        print(hdr)
        print("|" + "---|" * 11)
        for r in rows:
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
                  f"{r['collective_s']:.3e} | {r['dominant']} | "
                  f"{r['useful_ratio']:.2f} | "
                  f"{r['roofline_fraction']:.3f} | "
                  f"{r['roofline_cc']:.3f} | "
                  f"{r['mem_gb_per_dev']:.1f} |")
    else:
        keys = [k for k in rows[0] if k != "advice"] if rows else []
        print(",".join(keys))
        for r in rows:
            print(",".join(f"{r[k]:.4g}" if isinstance(r[k], float)
                           else str(r[k]) for k in keys))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)
    arts = load_artifacts(args.dir, args.mesh)
    if not arts:
        print(f"no artifacts under {args.dir} — run repro.launch.dryrun")
        return 1
    table(arts, markdown=args.markdown)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
