"""Benchmark aggregator — one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--fast]``

Sections:
  table1   iteration time per algorithm (event-timeline model)
  table2   wall-clock to target (measured steps x modelled iter time)
  fig5     model divergence: partial vs full sync (real runs)
  fig10_14 convergence vs H (real runs)
  fig15    schedule quality vs brute force
  fig16    search complexity
  kernels  Pallas kernels vs oracles + v5e projections
  serve    continuous batching vs naive loop (bench_serve smoke sweep)
  traffic  Poisson traffic replay: TTFT/TPOT percentiles vs naive server
  roofline dry-run roofline table (if artifacts exist)

Asserts the paper's qualitative claims along the way and exits non-zero on
violation.
"""

from __future__ import annotations

import argparse
import sys
import time


def _section(name):
    print(f"\n=== {name} {'=' * max(0, 60 - len(name))}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the real-training sections")
    ap.add_argument("--artifacts", default="artifacts/dryrun")
    args = ap.parse_args(argv)
    t0 = time.time()
    failures = []

    from repro.api import available_strategies

    from . import (bench_iteration_time, bench_kernels, bench_scheduling,
                   bench_search_complexity)

    _section("Strategy registry")
    names = available_strategies()
    print("registered:", ", ".join(names))
    missing = [a for a in ("ssgd", "wfbp", "ascwfbp", "flsgd", "plsgd-enp",
                           "dreamddp") if a not in names]
    if missing:
        failures.append(("registry", missing))

    _section("Table 1: iteration time (s) per algorithm")
    rows = bench_iteration_time.run()
    for r in rows:
        ok = (r["ssgd"] >= r["ascwfbp"] - 1e-12
              and r["ascwfbp"] > r["dreamddp"]
              and r["flsgd"] >= r["dreamddp"] - 1e-12
              and r["plsgd-enp"] >= r["dreamddp"] - 1e-12)
        if not ok:
            failures.append(("table1", r))
    s1 = [r["S1_vs_ascwfbp"] for r in rows]
    s2 = [r["S2_vs_flsgd"] for r in rows]
    print(f"# S1 (vs ASC-WFBP) {min(s1):.2f}x..{max(s1):.2f}x | "
          f"S2 (vs FLSGD) {min(s2):.2f}x..{max(s2):.2f}x "
          f"(paper: 1.73-5.22x / 1.16-1.50x)")

    _section("Fig 15: schedule quality vs brute force")
    for rows_ in (bench_scheduling.run_layers(22),
                  bench_scheduling.run_bandwidth()):
        for r in rows_:
            if r["obj_gap_pct"] > 2.0:
                failures.append(("fig15", r))

    _section("SimNet: per-scenario period time (geo-cluster simulator)")
    sim_rows = bench_scheduling.run_scenarios(H=5)
    by_scenario: dict = {}
    for r in sim_rows:
        by_scenario.setdefault(r["scenario"], {})[r["algo"]] = \
            r["mean_period_s"]
    for name, per in by_scenario.items():
        if per["dreamddp"] > per["flsgd"] * 1.05 + 1e-12:
            failures.append(("simnet", (name, per)))

    _section("Async two-tier runtime vs barriered DreamDDP (SimNet)")
    from . import bench_async
    for r in bench_async.run():
        if r["scenario"] in bench_async.MUST_WIN and r["speedup"] <= 1.0:
            failures.append(("async", r))

    _section("Fig 16: search complexity")
    for r in bench_search_complexity.run():
        if r["dd_nodes"] > r["bf_solutions"]:
            failures.append(("fig16", r))

    _section("Kernels vs oracles (+ v5e roofline projection)")
    for r in bench_kernels.run():
        tol = 0.5 if r["kernel"] == "int8_quant" else 0.15
        if r["max_err"] > tol:
            failures.append(("kernels", r))

    _section("Serving: continuous batching vs naive per-batch loop")
    from . import bench_serve
    serve_report = bench_serve.run(smoke=True)
    best = max(r["speedup"] for r in serve_report["rows"])
    if best < bench_serve.SPEEDUP_BAR:
        failures.append(("serve", {"best_speedup": best}))
    for r in serve_report["paged_rows"]:
        if r["kv_bytes_ratio"] > bench_serve.PAGED_KV_BAR \
                or r["goodput_ratio"] < bench_serve.PAGED_GOODPUT_BAR:
            failures.append(("serve-paged",
                             {"kv_bytes_ratio": r["kv_bytes_ratio"],
                              "goodput_ratio": r["goodput_ratio"]}))

    _section("Serving: Poisson traffic replay (TTFT/TPOT percentiles)")
    from . import bench_traffic
    traffic_report = bench_traffic.run(smoke=True)
    best_wall = max(r["wall_speedup"] for r in traffic_report["rows"])
    if best_wall < bench_traffic.TRAFFIC_WALL_BAR:
        failures.append(("serve-traffic", {"best_wall_speedup": best_wall}))

    if not args.fast:
        from . import bench_convergence
        _section("Fig 5: divergence partial vs full (real runs)")
        div = bench_convergence.run_divergence(csv=False, steps=40)
        print("algo,max_divergence")
        for a, d in div.items():
            print(f"{a},{max(d):.3e}")
        if not (max(div["ssgd"]) < 1e-8
                and max(div["plsgd-enp"]) < max(div["flsgd"])):
            failures.append(("fig5", {k: max(v) for k, v in div.items()}))

        _section("Figs 10-14: convergence vs H (real runs)")
        rows = bench_convergence.run_h_sweep(steps=48)
        for algo in ("flsgd", "dreamddp"):
            rs = {r["H"]: r["loss_last"] for r in rows
                  if r["algo"] == algo}
            if not all(v < 4.0 for v in rs.values()):
                failures.append(("fig10_14", (algo, rs)))

        _section("Table 2: wall-clock to target")
        bench_convergence.run_time_to_target(steps=60)

    _section("Roofline (from dry-run artifacts)")
    try:
        from . import roofline
        arts = roofline.load_artifacts(args.artifacts)
        if arts:
            roofline.table(arts)
        else:
            print("(no artifacts — run repro.launch.dryrun first)")
    except Exception as e:                                  # noqa: BLE001
        print(f"roofline section skipped: {e}")

    print(f"\ntotal {time.time() - t0:.1f}s; {len(failures)} failures")
    for f in failures:
        print("FAIL:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
