"""Paper Figs. 5, 10-14 + Table 2: real CPU training runs.

Trains a small decoder LM on the synthetic Markov corpus under every
algorithm and several H values, recording loss and model-divergence
per step (Figs 10-14 / Fig 5), then combines measured steps-to-target
with the Table-1 per-iteration times to produce wall-clock-to-target
(Table 2).
"""

from __future__ import annotations

from repro.api import JobConfig, Session
from repro.models.transformer import DecoderLM, LMConfig

from .bench_iteration_time import iteration_times

_CFG = LMConfig(name="bench", n_layers=4, d_model=48, n_heads=4,
                n_kv_heads=2, d_ff=96, vocab=64, param_dtype="float32",
                remat=False)


def train_once(algo: str, H: int, *, workers: int = 8, steps: int = 60,
               seed: int = 0, track: bool = True):
    sess = Session(
        JobConfig(algo=algo, workers=workers, period=H, bandwidth=1e9,
                  seq=32, batch_per_worker=4, lr=3e-3, warmup_steps=5,
                  decay_steps=600, track_divergence=track, seed=seed),
        model=DecoderLM(_CFG))
    sess.fit(steps)
    return sess.history


def run_divergence(csv: bool = True, steps: int = 48) -> dict:
    """Fig. 5: divergence trace, partial vs full sync."""
    out = {}
    for algo, H in (("ssgd", 1), ("flsgd", 4), ("plsgd-enp", 4),
                    ("dreamddp", 4)):
        hist = train_once(algo, H, steps=steps)
        out[algo] = [h["divergence"] for h in hist]
    if csv:
        print("step," + ",".join(out))
        for i in range(steps):
            print(f"{i}," + ",".join(f"{out[a][i]:.3e}" for a in out))
    return out


def run_h_sweep(csv: bool = True, steps: int = 60) -> list[dict]:
    """Figs 10-14: convergence for different H."""
    rows = []
    for algo in ("flsgd", "dreamddp"):
        for H in (2, 5, 10):
            hist = train_once(algo, H, steps=steps, track=False)
            losses = [h["loss"] for h in hist]
            rows.append({"algo": algo, "H": H, "loss_first": losses[0],
                         "loss_mid": losses[len(losses) // 2],
                         "loss_last": losses[-1]})
    if csv:
        keys = list(rows[0])
        print(",".join(keys))
        for r in rows:
            print(",".join(f"{r[k]:.4f}" if isinstance(r[k], float)
                           else str(r[k]) for k in keys))
    return rows


def run_time_to_target(csv: bool = True, steps: int = 80,
                       target: float = 2.2) -> list[dict]:
    """Table 2: steps-to-target (measured) x iteration time (modelled)."""
    iter_t = {w: iteration_times("gpt2", w) for w in (8, 32)}
    rows = []
    for algo, H in (("ssgd", 1), ("flsgd", 5), ("plsgd-enp", 5),
                    ("dreamddp", 5)):
        hist = train_once(algo, H, steps=steps, track=False)
        losses = [h["loss"] for h in hist]
        steps_to = next((i for i, l in enumerate(losses) if l <= target),
                        len(losses))
        key = {"ssgd": "ssgd", "flsgd": "flsgd", "plsgd-enp": "plsgd-enp",
               "dreamddp": "dreamddp"}[algo]
        for w in (8, 32):
            rows.append({"algo": algo, "workers": w,
                         "steps_to_target": steps_to,
                         "iter_time_s": iter_t[w][key],
                         "wall_clock_s": steps_to * iter_t[w][key]})
    if csv:
        keys = list(rows[0])
        print(",".join(keys))
        for r in rows:
            print(",".join(f"{r[k]:.4f}" if isinstance(r[k], float)
                           else str(r[k]) for k in keys))
    return rows


if __name__ == "__main__":
    run_divergence()
    run_h_sweep()
    run_time_to_target()
