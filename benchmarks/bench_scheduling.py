"""Paper Fig. 15: extra (non-overlapped) communication time after
scheduling — DreamDDP vs brute-force optimum, over layer count and
bandwidth — plus Table-1-style per-scenario numbers from the SimNet
geo-cluster simulator (``run_scenarios``)."""

from __future__ import annotations

from repro.core.schedule import brute_force_schedule, dreamddp_schedule
from repro.core.time_model import simulate_period

from .paper_models import paper_profile

H = 5


def _exposed(prof, partition) -> float:
    """Total comm time not hidden by computation over one period."""
    return sum(t.exposed_comm for t in simulate_period(prof, partition))


def run_layers(max_layers: int = 30, csv: bool = True) -> list[dict]:
    base = paper_profile("gpt2", n_workers=32)
    rows = []
    for L in range(H + 1, max_layers + 1, 2):
        prof = type(base)(base.layers[:L], base.hw)
        dd = dreamddp_schedule(prof, H)
        bf = brute_force_schedule(prof, H)
        rows.append({
            "n_layers": L,
            "extra_comm_dreamddp": _exposed(prof, dd.partition),
            "extra_comm_brute_force": _exposed(prof, bf.partition),
            "obj_gap_pct": 100.0 * (dd.objective / bf.objective - 1.0),
        })
    if csv:
        _print(rows)
    return rows


def run_bandwidth(csv: bool = True) -> list[dict]:
    rows = []
    for bw in (1e8, 5e8, 1e9, 5e9, 2e10, 1e11):
        prof = paper_profile("gpt2", n_workers=32, bandwidth=bw)
        prof = type(prof)(prof.layers[:24], prof.hw)
        dd = dreamddp_schedule(prof, H)
        bf = brute_force_schedule(prof, H)
        rows.append({
            "bandwidth": bw,
            "extra_comm_dreamddp": _exposed(prof, dd.partition),
            "extra_comm_brute_force": _exposed(prof, bf.partition),
            "obj_gap_pct": 100.0 * (dd.objective / bf.objective - 1.0),
        })
    if csv:
        _print(rows)
    return rows


def run_scenarios(csv: bool = True, *,
                  algos=("dreamddp", "plsgd-enp", "flsgd"),
                  model: str = "gpt2", n_workers: int | None = None,
                  H: int = 5, replan: bool = True) -> list[dict]:
    """Table-1-style numbers per SimNet scenario: replay each strategy's
    plan through every library scenario and report the mean period time
    and the comm time left exposed outside backward compute.

    ``n_workers`` (when given) overrides each scenario's initial worker
    count — comm is charged against the scenario's network, so only the
    scenario topology matters, not the profile's nominal cluster.

    With ``replan`` (the default, matching a live deployment) every
    schedule-relevant event re-solves the plan at the next period
    boundary; ``replan=False`` shows the cost of running a stale plan.
    """
    import dataclasses

    from repro.api import JobConfig, Session
    from repro.sim import available_scenarios, get_scenario

    base = paper_profile(model)
    rows = []
    for name in available_scenarios():
        sc = get_scenario(name)
        if n_workers is not None:
            sc = dataclasses.replace(sc, n_workers=n_workers)
        for algo in algos:
            sess = Session(JobConfig(algo=algo, period=H))
            trace = sess.simulate(sc, replan=replan, profile=base).trace
            rows.append({
                "scenario": name,
                "algo": algo,
                "mean_period_s": sum(trace.period_times())
                / max(trace.n_periods, 1),
                "mean_iter_s": trace.makespan / max(trace.n_iterations, 1),
                "exposed_comm_s": trace.total_exposed_comm(),
                "events": len(trace.events),
            })
    if csv:
        _print(rows)
    return rows


def _print(rows):
    keys = list(rows[0])
    print(",".join(keys))
    for r in rows:
        print(",".join(r[k] if isinstance(r[k], str) else f"{r[k]:.6g}"
                       for k in keys))


if __name__ == "__main__":
    run_layers()
    run_bandwidth()
    run_scenarios()
