"""Kernel benchmarks: correctness deltas + v5e roofline projections.

Interpret-mode wall time on CPU is NOT kernel performance; what we report
per kernel is (a) max abs error vs the jnp oracle, (b) the HBM bytes each
implementation moves, and (c) the projected v5e time at 819 GB/s — the
quantity the fusion actually improves.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.fused_adam_sync import adamw_ref, fused_adamw_step
from repro.kernels.int8_quant import dequantize, quantize
from repro.kernels.ssd_scan import ssd_chunk, ssd_chunk_ref

_HBM = 819e9


def _err(a, b):
    return float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32)
                                 - jnp.asarray(b, jnp.float32))))


def run(csv: bool = True) -> list[dict]:
    rows = []
    # flash attention: bytes ~ q+k+v+o (flash) vs + score map (naive)
    b, s, nq, nkv, hd = 1, 512, 8, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, nq, hd),
                          jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, nkv, hd),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, nkv, hd),
                          jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=128, block_k=128)
    ref = attention_ref(q, k, v)
    io = (q.size + 2 * k.size + out.size) * 2
    naive = io + b * nq * s * s * 4 * 2          # fp32 scores r+w
    rows.append({"kernel": "flash_attention", "max_err": _err(out, ref),
                 "hbm_bytes": io, "naive_bytes": naive,
                 "v5e_us": io / _HBM * 1e6,
                 "v5e_us_naive": naive / _HBM * 1e6})

    # fused adamw: 7 passes vs ~13 unfused (p,g,m,v r/w + casts)
    n = 1 << 20
    p = jax.random.normal(jax.random.PRNGKey(3), (n,), jnp.bfloat16)
    g = jax.random.normal(jax.random.PRNGKey(4), (n,), jnp.float32)
    m = jnp.zeros((n,), jnp.float32)
    vv = jnp.zeros((n,), jnp.float32)
    got = fused_adamw_step(p, g, m, vv, 1e-3, 0)
    want = adamw_ref(p, g, m, vv, lr=1e-3, step=0)
    fused_bytes = n * (2 + 4 * 3) + n * (2 + 4 * 2)
    unfused_bytes = fused_bytes + n * 4 * 6      # extra temps materialized
    rows.append({"kernel": "fused_adam_sync",
                 "max_err": max(_err(a, b) for a, b in zip(got, want, strict=True)),
                 "hbm_bytes": fused_bytes, "naive_bytes": unfused_bytes,
                 "v5e_us": fused_bytes / _HBM * 1e6,
                 "v5e_us_naive": unfused_bytes / _HBM * 1e6})

    # ssd chunk
    B, NC, Hh, cs, pp, nn = 1, 4, 8, 64, 64, 128
    x = jax.random.normal(jax.random.PRNGKey(5), (B, NC, Hh, cs, pp))
    bb = jax.random.normal(jax.random.PRNGKey(6), (B, NC, Hh, cs, nn))
    cc = jax.random.normal(jax.random.PRNGKey(7), (B, NC, Hh, cs, nn))
    da = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(8),
                                            (B, NC, Hh, cs)))
    y, st = ssd_chunk(x, bb, cc, da)
    yr, sr = ssd_chunk_ref(x, bb, cc, da)
    io = (x.size + bb.size + cc.size + y.size) * 4 + st.size * 4
    naive = io + B * NC * Hh * cs * cs * 4 * 2   # L matrix materialized
    rows.append({"kernel": "ssd_scan",
                 "max_err": max(_err(y, yr), _err(st, sr)),
                 "hbm_bytes": io, "naive_bytes": naive,
                 "v5e_us": io / _HBM * 1e6,
                 "v5e_us_naive": naive / _HBM * 1e6})

    # int8 quant: wire bytes halve vs bf16
    r, c = 4096, 1024
    xq = jax.random.normal(jax.random.PRNGKey(9), (r, c))
    qq, ss = quantize(xq)
    deq = dequantize(qq, ss)
    rows.append({"kernel": "int8_quant",
                 "max_err": float(jnp.abs(deq - xq).max()),
                 "hbm_bytes": r * c * (4 + 1) + r * 4,
                 "naive_bytes": r * c * 8,
                 "v5e_us": r * c * 5 / _HBM * 1e6,
                 "v5e_us_naive": r * c * 8 / _HBM * 1e6})

    if csv:
        keys = list(rows[0])
        print(",".join(keys))
        for rr in rows:
            print(",".join(f"{rr[k]:.4g}" if isinstance(rr[k], float)
                           else str(rr[k]) for k in keys))
    return rows


if __name__ == "__main__":
    run()
