"""Continuous batching (``ServeEngine``) vs the naive per-batch loop.

The workload is the one the old ``InferenceSession`` loop handles worst:
mixed prompt lengths (short + long) and early EOS on part of the request
set.  The naive loop admits one uniform batch at a time and decodes every
sequence to the full budget; the engine admits into freed slots every
tick and stops lanes at EOS.

The headline metric is **decode goodput**: useful decode tokens (up to
and including EOS, excluding the per-request first token that prefill
produces) per second of decode time — both sides are charged the same
numerator, prefill is timed separately, everything runs warm (one
untimed pass first, so jit compile time is excluded), and each side
keeps its best of ``REPEATS`` timed passes (CPU wall clock on a tiny
model is noisy; min-of-N is the standard microbenchmark estimator).
End-to-end wall times are reported alongside.

The **paged** section compares the engine against itself across KV
backends on mixed short/long traffic: same requests, same greedy tokens
(asserted), contiguous arena vs ``kv_backend="paged"``.  Two numbers
matter: decode goodput (paged must stay within ``PAGED_GOODPUT_BAR`` of
contiguous — the block-table gather is not free) and **peak KV bytes** —
the pool's high-water page footprint (what a right-sized deployment
provisions) vs the contiguous arena's fixed footprint, which must clear
``PAGED_KV_BAR``.  Token streams and page traffic are deterministic, so
the byte numbers are exact and regression-gated by
``scripts/check_bench.py``.

``python -m benchmarks.bench_serve --smoke`` runs the reduced sweep,
writes the JSON comparison to ``benchmarks/results/bench_serve.json``,
and exits non-zero unless the engine clears the 1.3x bar on the mixed
workload and the paged backend clears both paged bars.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

SPEEDUP_BAR = 1.3
PAGED_KV_BAR = 0.6        # paged peak KV bytes <= 0.6x contiguous arena
PAGED_GOODPUT_BAR = 0.9   # paged decode goodput >= 0.9x contiguous
REPEATS = 3
_OUT = os.path.join(os.path.dirname(__file__), "results",
                    "bench_serve.json")


def _workload(vocab, rng, n_requests, short_len, long_len, gen):
    """Alternating short/long prompts, full budget ``gen`` each."""
    return [rng.randint(0, vocab,
                        size=short_len if i % 2 == 0 else long_len).tolist()
            for i in range(n_requests)]


def _naive_refs(loop, prompts, gen):
    """Full-budget greedy rows per request (the oracle for EOS picking)."""
    return [np.asarray(loop.generate(jnp.asarray([p], jnp.int32),
                                     gen))[0].tolist() for p in prompts]


def _naive_pass(loop, prompts, gen, max_batch):
    """Old-loop semantics: group equal prompt lengths, decode each group
    in fixed sub-batches to the full budget, no EOS exit.  Returns
    (prefill_time_s, decode_time_s), each synced at section boundaries."""
    by_len: dict[int, list[list[int]]] = {}
    for p in prompts:
        by_len.setdefault(len(p), []).append(p)
    batches = [jnp.asarray(group[i:i + max_batch], jnp.int32)
               for _, group in sorted(by_len.items())
               for i in range(0, len(group), max_batch)]
    t_pre = t_dec = 0.0
    for batch in batches:
        b, s = batch.shape
        cache = loop.model.init_cache(b, s + gen)
        t0 = time.perf_counter()
        logits, cache = loop.prefill(loop.params, batch, cache)
        out = jax.block_until_ready(jnp.argmax(logits, -1)
                                    .astype(jnp.int32))
        t_pre += time.perf_counter() - t0
        t0 = time.perf_counter()
        for i in range(gen - 1):
            pos = jnp.full((b,), s + i, jnp.int32)
            logits, cache = loop.decode(loop.params, cache, out, pos)
            out = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(out)
        t_dec += time.perf_counter() - t0
    return t_pre, t_dec


def run_case(model, params, *, n_requests, short_len, long_len, gen,
             max_batch, eos_frac=0.5, eos_at=None, decode_block=8,
             seed=1):
    from repro.serve import EngineConfig, Request, ServeEngine
    from repro.serve.naive import NaiveLoop

    vocab = model.cfg.vocab
    rng = np.random.RandomState(seed)
    prompts = _workload(vocab, rng, n_requests, short_len, long_len, gen)
    loop = NaiveLoop(model, params)
    refs = _naive_refs(loop, prompts, gen)

    # early EOS for a fraction of the requests: stop at the token the
    # greedy stream emits around eos_at (naive can't exit; engine does)
    eos_at = eos_at or max(gen // 4, 1)
    eos_ids = [None] * n_requests
    useful = [gen] * n_requests
    for i in range(n_requests):
        if i % max(int(round(1 / eos_frac)), 1) == 0 and eos_frac > 0:
            tok = refs[i][eos_at - 1]
            eos_ids[i] = tok
            useful[i] = refs[i].index(tok) + 1
    total_useful = sum(useful)
    # each request's first token comes from prefill on both sides
    useful_decode = total_useful - n_requests

    # ---- naive loop (warm, then best of REPEATS)
    _naive_pass(loop, prompts, gen, max_batch)
    naive_pre, naive_dec = zip(*(_naive_pass(loop, prompts, gen,
                                             max_batch)
                                 for _ in range(REPEATS)), strict=True)
    naive_dec_s, naive_wall = min(naive_dec), min(
        p + d for p, d in zip(naive_pre, naive_dec, strict=True))

    # ---- engine (warm, then best of REPEATS)
    engine = ServeEngine(
        model, params,
        EngineConfig(max_batch=max_batch,
                     max_seq=long_len + gen,
                     decode_block=decode_block))
    reqs = [Request(tokens=p, max_new_tokens=gen, eos_id=e)
            for p, e in zip(prompts, eos_ids, strict=True)]
    eng_dec, eng_wall_all, comps = [], [], None
    engine.generate(list(reqs))
    for _ in range(REPEATS):
        engine.reset(params=params)
        t0 = time.perf_counter()
        comps = engine.generate(list(reqs))
        eng_wall_all.append(time.perf_counter() - t0)
        eng_dec.append(engine.stats.decode_time_s)
        # goodput sanity: greedy equivalence means the engine generates
        # exactly the useful tokens
        for c, u, r in zip(comps, useful, refs, strict=True):
            assert c.tokens == r[:u], "engine/naive divergence in bench"
        assert engine.stats.decode_tokens == useful_decode
    engine_dec_s, engine_wall = min(eng_dec), min(eng_wall_all)

    return {
        "n_requests": n_requests, "short_len": short_len,
        "long_len": long_len, "gen": gen, "max_batch": max_batch,
        "eos_frac": eos_frac, "useful_tokens": total_useful,
        "useful_decode_tokens": useful_decode,
        "naive": {"wall_s": naive_wall, "decode_time_s": naive_dec_s,
                  "decoded_tokens": n_requests * gen,
                  "decode_tokens_per_s": useful_decode / naive_dec_s,
                  "tokens_per_s": total_useful / naive_wall},
        "engine": {"wall_s": engine_wall, "decode_time_s": engine_dec_s,
                   "decode_tokens_per_s": useful_decode / engine_dec_s,
                   "tokens_per_s": total_useful / engine_wall,
                   "stats": engine.stats.as_dict()},
        "speedup": naive_dec_s / engine_dec_s,
        "wall_speedup": naive_wall / engine_wall,
    }


def run_paged_case(model, params, *, n_requests, short_len, long_len,
                   gen, max_batch, max_seq, page_size, long_every=4,
                   decode_block=8, seed=2):
    """Contiguous vs paged KV backend on mixed-length traffic.

    One long prompt in every ``long_every`` requests; both engines see
    the identical request list and must emit identical greedy tokens.
    Goodput is decode-time goodput (warm, best of REPEATS); KV bytes are
    deterministic: the contiguous arena is always ``n_slots * max_seq``
    deep, the paged pool reports its high-water footprint.
    """
    from repro.serve import EngineConfig, Request, ServeEngine

    vocab = model.cfg.vocab
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(0, vocab,
                           size=long_len if i % long_every ==
                           long_every - 1 else short_len).tolist()
               for i in range(n_requests)]
    reqs = [Request(tokens=p, max_new_tokens=gen) for p in prompts]

    def measure(cfg):
        eng = ServeEngine(model, params, cfg)
        eng.generate(list(reqs))                     # warm
        best_dec, toks = None, None
        for _ in range(REPEATS):
            eng.reset(params=params)
            comps = eng.generate(list(reqs))
            dec = eng.stats.decode_time_s
            if best_dec is None or dec < best_dec:
                best_dec = dec
            toks = [c.tokens for c in comps]
        return eng, best_dec, eng.stats.decode_tokens, toks

    cont_cfg = EngineConfig(max_batch=max_batch, max_seq=max_seq,
                            decode_block=decode_block)
    paged_cfg = EngineConfig(max_batch=max_batch, max_seq=max_seq,
                             decode_block=decode_block,
                             kv_backend="paged", page_size=page_size)
    cont_eng, cont_dec, dec_tokens, cont_toks = measure(cont_cfg)
    paged_eng, paged_dec, paged_tokens, paged_toks = measure(paged_cfg)
    assert cont_toks == paged_toks, "paged/contiguous divergence in bench"
    assert dec_tokens == paged_tokens

    cont_bytes = cont_eng.pool.kv_bytes()
    peak_bytes = paged_eng.pool.peak_kv_bytes()
    return {
        "n_requests": n_requests, "short_len": short_len,
        "long_len": long_len, "gen": gen, "max_batch": max_batch,
        "max_seq": max_seq, "page_size": page_size,
        "long_every": long_every, "decode_tokens": dec_tokens,
        "contiguous": {"decode_time_s": cont_dec,
                       "decode_tokens_per_s": dec_tokens / cont_dec,
                       "kv_bytes": cont_bytes},
        "paged": {"decode_time_s": paged_dec,
                  "decode_tokens_per_s": dec_tokens / paged_dec,
                  "peak_kv_bytes": peak_bytes,
                  "provisioned_kv_bytes": paged_eng.pool.kv_bytes(),
                  "peak_pages": paged_eng.pool.peak_pages_in_use,
                  "total_pages": paged_eng.pool.n_usable_pages},
        "kv_bytes_ratio": peak_bytes / cont_bytes,
        "goodput_ratio": cont_dec / paged_dec,
    }


def run(*, arch="qwen3-1.7b", smoke=True, out_json=_OUT):
    from repro.configs import get_arch

    spec = get_arch(arch)
    model = spec.make_smoke() if smoke else spec.make_model()
    params = model.init(jax.random.PRNGKey(0))

    cases = ([dict(n_requests=12, short_len=8, long_len=24, gen=16,
                   max_batch=4),
              dict(n_requests=8, short_len=8, long_len=16, gen=24,
                   max_batch=2)]
             if smoke else
             [dict(n_requests=32, short_len=16, long_len=64, gen=g,
                   max_batch=b)
              for b in (4, 8) for g in (32, 64)])

    rows = []
    for case in cases:
        r = run_case(model, params, **case)
        rows.append(r)
        print(f"batch={r['max_batch']} gen={r['gen']} decode goodput: "
              f"naive={r['naive']['decode_tokens_per_s']:.1f} tok/s  "
              f"engine={r['engine']['decode_tokens_per_s']:.1f} tok/s  "
              f"speedup={r['speedup']:.2f}x "
              f"(wall {r['wall_speedup']:.2f}x; useful "
              f"{r['useful_tokens']}/{r['naive']['decoded_tokens']} "
              f"decoded)")

    paged_cases = ([dict(n_requests=16, short_len=8, long_len=120,
                         gen=8, max_batch=8, max_seq=128, page_size=16)]
                   if smoke else
                   [dict(n_requests=32, short_len=16, long_len=240,
                         gen=16, max_batch=8, max_seq=256, page_size=16),
                    dict(n_requests=32, short_len=16, long_len=112,
                         gen=16, max_batch=16, max_seq=128,
                         page_size=16)])
    paged_rows = []
    for case in paged_cases:
        r = run_paged_case(model, params, **case)
        paged_rows.append(r)
        print(f"paged batch={r['max_batch']} short={r['short_len']} "
              f"long={r['long_len']}: goodput "
              f"contiguous={r['contiguous']['decode_tokens_per_s']:.1f} "
              f"paged={r['paged']['decode_tokens_per_s']:.1f} tok/s "
              f"({r['goodput_ratio']:.2f}x); peak KV "
              f"{r['paged']['peak_kv_bytes'] / 1e6:.2f} MB vs "
              f"{r['contiguous']['kv_bytes'] / 1e6:.2f} MB "
              f"({r['kv_bytes_ratio']:.2f}x, pages "
              f"{r['paged']['peak_pages']}/{r['paged']['total_pages']})")

    report = {"arch": arch, "smoke": smoke, "speedup_bar": SPEEDUP_BAR,
              "paged_kv_bar": PAGED_KV_BAR,
              "paged_goodput_bar": PAGED_GOODPUT_BAR,
              "rows": rows, "paged_rows": paged_rows}
    os.makedirs(os.path.dirname(out_json), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"wrote {out_json}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=_OUT)
    args = ap.parse_args(argv)
    report = run(arch=args.arch, smoke=args.smoke, out_json=args.out)
    rc = 0
    best = max(r["speedup"] for r in report["rows"])
    if best < SPEEDUP_BAR:
        print(f"FAIL: best speedup {best:.2f}x < {SPEEDUP_BAR}x")
        rc = 1
    else:
        print(f"continuous batching >= {SPEEDUP_BAR}x bar: "
              f"best {best:.2f}x")
    for r in report["paged_rows"]:
        if r["kv_bytes_ratio"] > PAGED_KV_BAR:
            print(f"FAIL: paged peak KV {r['kv_bytes_ratio']:.2f}x "
                  f"contiguous > {PAGED_KV_BAR}x bar")
            rc = 1
        if r["goodput_ratio"] < PAGED_GOODPUT_BAR:
            print(f"FAIL: paged goodput {r['goodput_ratio']:.2f}x "
                  f"contiguous < {PAGED_GOODPUT_BAR}x bar")
            rc = 1
    if rc == 0 and report["paged_rows"]:
        worst_kv = max(r["kv_bytes_ratio"] for r in report["paged_rows"])
        worst_gp = min(r["goodput_ratio"] for r in report["paged_rows"])
        print(f"paged KV <= {PAGED_KV_BAR}x bar: worst {worst_kv:.2f}x; "
              f"goodput >= {PAGED_GOODPUT_BAR}x bar: worst "
              f"{worst_gp:.2f}x")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
