"""Training hot path: period-fused runner vs the per-step oracle.

Measures steps/sec of the SAME training job (smoke model, DreamDDP
schedule, synthetic Markov corpus) through the three runner execution
paths:

* ``per_step`` — one jitted dispatch + one host sync per iteration (the
  oracle; includes the straggler-clock fix, so it blocks on the
  completed step);
* ``fused`` — period-granularity pipeline (default fused path): donated
  per-phase executables dispatched back-to-back, ONE host sync per
  H-step period, device-resident metrics drained every ``log_every``
  periods, data prefetched one period ahead;
* ``compiled`` — one donated ``make_period_step`` executable per period
  (``lax.scan`` over the pre-batched ``[H, ...]`` data).

Everything runs warm (untimed warmup pass compiles every executable)
and each path keeps its best of ``REPEATS`` timed passes.  The fused
path must clear ``SPEEDUP_BAR`` on at least one model family; the JSON
report is committed as ``benchmarks/results/bench_train_loop.json`` and
regression-gated by ``scripts/check_bench.py`` (identity fields exact,
wall-clock speedups tolerance-banded).

``python -m benchmarks.bench_train_loop --smoke`` runs the reduced
sweep used by CI.
"""

from __future__ import annotations

import argparse
import json
import os
import time

SPEEDUP_BAR = 1.3
REPEATS = 3
H = 5
WORKERS = 4
BATCH = 2
SEQ = 8
_OUT = os.path.join(os.path.dirname(__file__), "results",
                    "bench_train_loop.json")


def _bench_models():
    """Two model families (dense GQA transformer / attention-free SSM)
    at bench scale: small enough that the per-iteration dispatch + host
    sync + per-op overhead the fused runner amortizes is a measurable
    share of the step — the CPU-container proxy for the accelerator
    regime, where these families' sub-ms smoke steps make dispatch
    overhead dominant."""
    from repro.models.mamba2 import Mamba2Config, Mamba2LM
    from repro.models.transformer import DecoderLM, LMConfig
    return (
        ("transformer", "dense", DecoderLM(LMConfig(
            name="bench-dense", n_layers=2, d_model=16, n_heads=2,
            n_kv_heads=1, d_ff=32, vocab=128, head_dim=8,
            param_dtype="float32", remat=False))),
        ("mamba2", "ssm", Mamba2LM(Mamba2Config(
            name="bench-ssm", n_layers=2, d_model=32, vocab=128,
            d_state=16, head_dim=8, chunk=8,
            param_dtype="float32"))),
    )


def _steps_per_s(runner, state, n_steps, start, *, fused, repeats):
    """Best-of-N steps/sec; every pass runs warm and continues the same
    stream (``start`` advances by whole periods so the fused path stays
    period-aligned)."""
    best = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        state = runner.run(state, n_steps, start_step=start, fused=fused)
        dt = time.perf_counter() - t0
        start += n_steps
        best = max(best, n_steps / dt)
    return best, state, start


def run_family(name: str, family: str, model, *, steps: int,
               repeats: int = REPEATS, seed: int = 0) -> dict:
    import jax

    from repro.core import HardwareSpec, analytic_profile, build_plan
    from repro.data import MarkovCorpus
    from repro.optim import make_optimizer
    from repro.runtime import (Runner, RunnerConfig, StepConfig,
                               init_train_state)

    prof = analytic_profile(model.layer_costs(BATCH, SEQ),
                            HardwareSpec(bandwidth=1e9, n_workers=WORKERS))
    plan = build_plan("dreamddp", prof, H)
    opt = make_optimizer("adam", lr=3e-3, warmup_steps=5, decay_steps=400)
    data = MarkovCorpus(vocab=model.cfg.vocab, seq_len=SEQ,
                        batch_per_worker=BATCH, n_workers=WORKERS,
                        seed=seed)
    scfg = StepConfig()

    row = {"model": name, "family": family, "workers": WORKERS, "H": H,
           "steps": steps, "batch_per_worker": BATCH, "seq": SEQ}
    rates = {}
    for mode, fused, exec_ in (("per_step", False, "pipeline"),
                               ("fused", True, "pipeline"),
                               ("compiled", True, "compiled")):
        runner = Runner(model, opt, plan, data, step_cfg=scfg,
                        run_cfg=RunnerConfig(fused_period=fused,
                                             period_exec=exec_))
        state = init_train_state(model, opt, jax.random.PRNGKey(seed),
                                 WORKERS, cfg=scfg)
        # warm: compile every executable off the clock
        state = runner.run(state, H, start_step=0, fused=fused)
        sps, state, _ = _steps_per_s(runner, state, steps, H,
                                     fused=fused, repeats=repeats)
        rates[mode] = sps
    row["per_step_steps_per_s"] = rates["per_step"]
    row["fused_steps_per_s"] = rates["fused"]
    row["compiled_steps_per_s"] = rates["compiled"]
    row["speedup"] = rates["fused"] / rates["per_step"]
    row["compiled_speedup"] = rates["compiled"] / rates["per_step"]
    # the bar is on the period-fused runner in its best executor for
    # this family (pipeline = bitwise oracle parity; compiled = one
    # donated executable per period)
    row["best_speedup"] = max(row["speedup"], row["compiled_speedup"])
    return row


def run(*, smoke: bool = False, out_json: str = _OUT) -> dict:
    # a timed pass must be long enough to dominate scheduler noise on
    # shared hardware: ~200 steps ≈ 0.3-0.7 s per pass at bench scale
    steps = 200 if smoke else 400
    rows = []
    for name, family, model in _bench_models():
        row = run_family(name, family, model, steps=steps)
        rows.append(row)
        print(f"{name:>14} ({family}): per-step "
              f"{row['per_step_steps_per_s']:7.1f} it/s | fused "
              f"{row['fused_steps_per_s']:7.1f} it/s "
              f"({row['speedup']:.2f}x) | compiled "
              f"{row['compiled_steps_per_s']:7.1f} it/s "
              f"({row['compiled_speedup']:.2f}x)")
    report = {"smoke": smoke, "H": H, "workers": WORKERS,
              "speedup_bar": SPEEDUP_BAR, "rows": rows}
    os.makedirs(os.path.dirname(out_json), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"wrote {out_json}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=_OUT)
    args = ap.parse_args(argv)
    report = run(smoke=args.smoke, out_json=args.out)
    best = max(r["best_speedup"] for r in report["rows"])
    if best < SPEEDUP_BAR:
        print(f"FAIL: best fused speedup {best:.2f}x < {SPEEDUP_BAR}x")
        return 1
    print(f"period-fused runner >= {SPEEDUP_BAR}x bar: best {best:.2f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
