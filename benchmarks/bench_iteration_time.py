"""Paper Table 1: average iteration wall-clock time per algorithm.

Reproduced through the calibrated event-timeline model (this container has
no 32-GPU cluster): per-layer compute/comm costs from the analytic
profiler at the paper's cluster specs, algorithms as their exact
schedules.  The paper's qualitative ordering
(S-SGD > ASC-WFBP > FLSGD > PLSGD-ENP > DreamDDP) is asserted by
``benchmarks.run``.

``python -m benchmarks.bench_iteration_time --out ...`` writes the table
as JSON; the committed copy in ``benchmarks/results/`` is the Table-1
regression baseline for ``scripts/check_bench.py`` — every number is a
deterministic model-time metric (analytic profile -> schedule search ->
event timeline; no wall clock), so the gate compares them near-exactly.
"""

from __future__ import annotations

import argparse
import json
import os

from repro.core import (ascwfbp_iteration_time, build_plan,
                        flsgd_period_time, simulate_period,
                        ssgd_iteration_time)
from repro.core.time_model import Partition

from .paper_models import PAPER_MODELS, paper_profile

H = 5
_OUT = os.path.join(os.path.dirname(__file__), "results",
                    "bench_iteration_time.json")


def iteration_times(name: str, n_workers: int) -> dict[str, float]:
    prof = paper_profile(name, n_workers=n_workers)
    out = {
        "ssgd": ssgd_iteration_time(prof),
        "ascwfbp": ascwfbp_iteration_time(prof),
        "flsgd": flsgd_period_time(prof, H) / H,
    }
    for algo in ("plsgd-enp", "dreamddp"):
        plan = build_plan(algo, prof, H)
        part = Partition(tuple(plan.meta["partition_counts"]))
        fills = None
        if algo == "dreamddp":
            n = plan.n_units
            fills = [[n - 1 - u for u in f] for f in plan.fill_units]
        tls = simulate_period(prof, part, fills)
        out[algo] = sum(t.iteration_time for t in tls) / H
    return out


def run(csv: bool = True) -> list[dict]:
    rows = []
    for name in PAPER_MODELS:
        for w in (8, 32):
            t = iteration_times(name, w)
            rows.append({
                "model": name, "workers": w, **t,
                "S1_vs_ascwfbp": t["ascwfbp"] / t["dreamddp"],
                "S2_vs_flsgd": t["flsgd"] / t["dreamddp"],
            })
    if csv:
        keys = list(rows[0])
        print(",".join(keys))
        for r in rows:
            print(",".join(f"{r[k]:.4f}" if isinstance(r[k], float)
                           else str(r[k]) for k in keys))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=_OUT,
                    help="write the table as JSON (the committed copy is "
                         "the check_bench baseline)")
    args = ap.parse_args(argv)
    rows = run()
    report = {"H": H, "rows": rows}
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
