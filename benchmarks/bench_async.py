"""Async two-tier runtime vs barriered DreamDDP on the SimNet library.

For every scenario in the simulator's library the same DreamDDP plan is
replayed twice over the same virtual cluster: once through the barriered
:class:`repro.sim.SimExecutor` (sync), once through the asynchronous
two-tier :class:`repro.hier.AsyncSimExecutor` (workers on their own
clocks, staleness-aware merges, double-buffered pulls).  Both runs
complete the same amount of work — ``periods * n_workers``
worker-periods — so the makespans are directly comparable.  The async
makespan is ``max(last span end, final merge time)``: trailing merges
count, a run isn't done until its last delta lands.

Every number is deterministic model time (seeded scenario -> event heap
-> op log; no wall clock), so the committed report in
``benchmarks/results/`` is gated near-exactly by
``scripts/check_bench.py --only async`` — any drift means the async time
model changed and the baseline must be regenerated deliberately.

The run itself enforces the paper-level claim as an absolute bar: async
must beat sync (speedup > 1) on the ``straggler`` and ``churn``
scenarios, the two the DreamDDP comparison targets.

``python -m benchmarks.bench_async --out ...`` writes the report.
"""

from __future__ import annotations

import argparse
import json
import os

from repro.api.registry import get_strategy
from repro.hier import AsyncSimExecutor
from repro.sim import (SimExecutor, available_scenarios, get_scenario,
                       prepare_run, synthetic_profile)

H = 4
# scenarios where async must strictly beat sync (absolute bar)
MUST_WIN = ("straggler", "churn")
_OUT = os.path.join(os.path.dirname(__file__), "results",
                    "bench_async.json")


def scenario_row(name: str) -> dict:
    """One sync-vs-async comparison over a library scenario."""
    strategy = get_strategy("dreamddp")
    profile = synthetic_profile()
    sc = get_scenario(name)

    cluster, plan = prepare_run(sc, strategy, H, profile)
    sync_makespan = SimExecutor(profile, plan, cluster).run(
        sc.periods).makespan

    cluster, plan = prepare_run(sc, strategy, H, profile)
    trace = AsyncSimExecutor(profile, plan, cluster).run(sc.periods)
    meta = trace.meta
    async_makespan = max(trace.makespan, meta["final_merge_time"])

    hist = meta["staleness_hist"]
    merges = meta["merges"]
    mean_tau = sum(int(k) * v for k, v in hist.items()) / max(merges, 1)
    return {
        "scenario": name,
        "workers": sc.n_workers,
        "datacenters": sc.n_datacenters,
        "periods": sc.periods,
        "H": H,
        "merge_rule": meta["merge_rule"],
        "pushes_per_merge": meta["pushes_per_merge"],
        "sync_makespan": sync_makespan,
        "async_makespan": async_makespan,
        "speedup": sync_makespan / async_makespan,
        "merges": merges,
        "max_staleness": max((int(k) for k in hist), default=0),
        "mean_staleness": mean_tau,
        "staleness_hist": hist,
    }


def run(csv: bool = True) -> list[dict]:
    rows = [scenario_row(name) for name in available_scenarios()]
    if csv:
        keys = ("scenario", "workers", "periods", "sync_makespan",
                "async_makespan", "speedup", "merges", "max_staleness",
                "mean_staleness")
        print(",".join(keys))
        for r in rows:
            print(",".join(f"{r[k]:.4f}" if isinstance(r[k], float)
                           else str(r[k]) for k in keys))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=_OUT,
                    help="write the report as JSON (the committed copy "
                         "is the check_bench baseline)")
    args = ap.parse_args(argv)
    rows = run()
    report = {"H": H, "must_win": list(MUST_WIN), "rows": rows}
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=1, sort_keys=True)
    print(f"wrote {args.out}")
    rc = 0
    by_name = {r["scenario"]: r for r in rows}
    for name in MUST_WIN:
        row = by_name.get(name)
        if row is None:
            print(f"FAIL: scenario {name!r} missing from the library")
            rc = 1
        elif row["speedup"] <= 1.0:
            print(f"FAIL: async does not beat sync on {name!r} "
                  f"(speedup {row['speedup']:.3f}x)")
            rc = 1
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
