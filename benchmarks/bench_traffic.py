"""Traffic replay: the serve engine under Poisson arrivals vs the naive
loop serving the same trace.

``bench_serve`` measures throughput on a closed batch (every request
present at t=0).  This bench measures what an online server sees: a
seeded Poisson arrival process with mixed prompt/generation lengths,
replayed against the wall clock — a request may only be submitted once
its arrival time has passed, so queueing delay is real and TTFT/TPOT
percentiles mean what they mean in serving papers.  This is the workload
where per-request admission dispatch hurt most: bursts of short-gen
arrivals spend their life in prefill, so admission cost lands directly
on TTFT and on wall clock.

Both sides replay the identical trace:

* **engine** — requests are submitted as they arrive (``submit_t``
  pinned to the arrival time) and the engine ticks continuously;
  admissions batch per shape bucket within a tick (PR 7).
* **naive** — the old loop as an online server: FIFO head-of-line, and
  each dispatch greedily batches up to ``max_batch`` *arrived* requests
  with the head's (prompt length, budget) — the strongest grouping the
  fixed-batch loop can do online.  First tokens are synced at the
  prefill boundary so its TTFT is honest, not end-of-batch.

Token streams are asserted identical to per-request naive references
(greedy, no EOS), so ``prompt_tokens`` / ``generated_tokens`` are exact
and regression-gated by ``scripts/check_bench.py --only traffic``;
wall-clock metrics (requests/sec, wall_speedup) are banded and latency
percentiles are banded from above (lower is better).

Every jit the replay can hit is compiled in an untimed sweep first
(every (group size, prompt length) pair on the engine side, every
(batch, length) on the naive side), so compile time never pollutes a
timed replay and mid-replay group-size jitter cannot recompile.

``python -m benchmarks.bench_traffic --smoke`` writes
``benchmarks/results/bench_traffic.json`` and exits non-zero unless the
engine clears ``TRAFFIC_WALL_BAR`` on the best row.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

TRAFFIC_WALL_BAR = 1.0    # engine wall clock must beat the naive server
REPEATS = 3
_OUT = os.path.join(os.path.dirname(__file__), "results",
                    "bench_traffic.json")


def make_traffic(vocab, *, n_requests, rate_rps, prompt_lens, gens, seed):
    """Seeded Poisson arrival trace: ``[(t_arrival, prompt, gen), ...]``
    sorted by arrival, prompt/gen lengths drawn per request."""
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n_requests)
    arrivals = np.cumsum(gaps)
    trace = []
    for t in arrivals:
        s = int(rng.choice(prompt_lens))
        g = int(rng.choice(gens))
        trace.append((float(t), rng.randint(0, vocab, size=s).tolist(), g))
    return trace


def _percentiles(vals):
    return {"p50": float(np.percentile(vals, 50)),
            "p99": float(np.percentile(vals, 99))}


def _latency_metrics(ttfts, tpots, n, wall):
    t, p = _percentiles(ttfts), _percentiles(tpots)
    return {"wall_s": wall, "requests_per_s": n / wall,
            "ttft_p50_s": t["p50"], "ttft_p99_s": t["p99"],
            "tpot_p50_s": p["p50"], "tpot_p99_s": p["p99"]}


# ------------------------------------------------------------------- engine

def _warm_engine(engine, prompt_lens, gens, max_batch, vocab, seed):
    """Compile every executable a replay can hit: each (K, S) admission
    group for K = 1..max_batch, plus the decode block."""
    from repro.serve import Request

    rng = np.random.RandomState(seed)
    for s in sorted(set(prompt_lens)):
        for k in range(1, max_batch + 1):
            engine.generate([
                Request(tokens=rng.randint(0, vocab, size=s).tolist(),
                        max_new_tokens=2)
                for _ in range(k)])
    engine.reset()


def _replay_engine(engine, trace):
    """Submit each request when its arrival time passes; tick until the
    trace is drained.  Returns (completions by submit order, wall_s)."""
    from repro.serve import Request

    engine.reset(params=engine.params)
    comps = {}
    i = 0
    t0 = time.perf_counter()
    while i < len(trace) or engine.has_work:
        now = time.perf_counter() - t0
        while i < len(trace) and trace[i][0] <= now:
            t_arr, prompt, gen = trace[i]
            engine.submit(Request(tokens=prompt, max_new_tokens=gen,
                                  request_id=i),
                          submit_t=t0 + t_arr)
            i += 1
        if engine.has_work:
            for c in engine.step():
                comps[c.request_id] = c
        elif i < len(trace):
            time.sleep(min(max(trace[i][0] - now, 0.0), 5e-4))
    wall = time.perf_counter() - t0
    return [comps[j] for j in range(len(trace))], wall


# -------------------------------------------------------------------- naive

def _warm_naive(loop, prompt_lens, gens, max_batch, vocab, seed):
    """The naive cache is sized ``s + gen``, so every (batch, prompt
    length, budget) combination is its own set of executables — warm
    them all or the timed replay pays compile time."""
    rng = np.random.RandomState(seed)
    for s in sorted(set(prompt_lens)):
        for g in sorted(set(gens)):
            for k in range(1, max_batch + 1):
                loop.generate(jnp.asarray(rng.randint(
                    0, vocab, size=(k, s)), jnp.int32), g)


def _replay_naive(loop, trace, max_batch):
    """The old loop as an online server: when free, dispatch the FIFO
    head batched with up to ``max_batch - 1`` arrived requests of the
    same (prompt length, budget); prefill syncs first tokens (TTFT),
    the decode loop runs the batch to its full budget (no EOS exit)."""
    queue = list(range(len(trace)))
    ttft = [0.0] * len(trace)
    done = [0.0] * len(trace)
    t0 = time.perf_counter()
    while queue:
        now = time.perf_counter() - t0
        head = queue[0]
        if trace[head][0] > now:
            time.sleep(min(trace[head][0] - now, 5e-4))
            continue
        key = (len(trace[head][1]), trace[head][2])
        batch_ids = [head]
        for j in queue[1:]:
            if len(batch_ids) == max_batch:
                break
            if trace[j][0] <= now and \
                    (len(trace[j][1]), trace[j][2]) == key:
                batch_ids.append(j)
        queue = [j for j in queue if j not in batch_ids]
        s, gen = key
        batch = jnp.asarray([trace[j][1] for j in batch_ids], jnp.int32)
        b = len(batch_ids)
        cache = loop.model.init_cache(b, s + gen)
        logits, cache = loop.prefill(loop.params, batch, cache)
        out = jax.block_until_ready(jnp.argmax(logits, -1)
                                    .astype(jnp.int32))
        t_first = time.perf_counter() - t0
        for j in batch_ids:
            ttft[j] = t_first - trace[j][0]
        for i in range(gen - 1):
            pos = jnp.full((b,), s + i, jnp.int32)
            logits, cache = loop.decode(loop.params, cache, out, pos)
            out = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(out)
        t_done = time.perf_counter() - t0
        for j in batch_ids:
            done[j] = t_done - trace[j][0]
    wall = time.perf_counter() - t0
    return ttft, done, wall


# --------------------------------------------------------------------- case

def run_case(model, params, *, n_requests, rate_rps, prompt_lens, gens,
             max_batch, decode_block=4, seed=7):
    from repro.serve import EngineConfig, ServeEngine
    from repro.serve.naive import NaiveLoop

    vocab = model.cfg.vocab
    trace = make_traffic(vocab, n_requests=n_requests, rate_rps=rate_rps,
                         prompt_lens=prompt_lens, gens=gens, seed=seed)
    loop = NaiveLoop(model, params)
    refs = [np.asarray(loop.generate(jnp.asarray([p], jnp.int32),
                                     g))[0].tolist()
            for _, p, g in trace]

    engine = ServeEngine(model, params, EngineConfig(
        max_batch=max_batch, max_seq=max(prompt_lens) + max(gens),
        decode_block=decode_block))
    _warm_engine(engine, prompt_lens, gens, max_batch, vocab, seed)
    best_eng = None
    for _ in range(REPEATS):
        comps, wall = _replay_engine(engine, trace)
        for c, r in zip(comps, refs, strict=True):
            assert c.tokens == r, "engine/naive divergence in bench"
        if best_eng is None or wall < best_eng[1]:
            best_eng = (comps, wall, engine.stats.as_dict())
    comps, eng_wall, eng_stats = best_eng
    eng_ttft = [c.ttft_s for c in comps]
    eng_tpot = [(c.latency_s - c.ttft_s) / (len(c.tokens) - 1)
                for c in comps if len(c.tokens) > 1]

    _warm_naive(loop, prompt_lens, gens, max_batch, vocab, seed)
    best_naive = None
    for _ in range(REPEATS):
        ttft, done, wall = _replay_naive(loop, trace, max_batch)
        if best_naive is None or wall < best_naive[2]:
            best_naive = (ttft, done, wall)
    nv_ttft, nv_done, nv_wall = best_naive
    nv_tpot = [(d - t) / (g - 1)
               for t, d, (_, _, g) in zip(nv_ttft, nv_done, trace,
                                          strict=True) if g > 1]

    eng_m = _latency_metrics(eng_ttft, eng_tpot, n_requests, eng_wall)
    eng_m["stats"] = eng_stats
    nv_m = _latency_metrics(nv_ttft, nv_tpot, n_requests, nv_wall)
    return {
        "n_requests": n_requests, "rate_rps": rate_rps, "seed": seed,
        "prompt_lens": list(prompt_lens), "gens": list(gens),
        "max_batch": max_batch, "decode_block": decode_block,
        "prompt_tokens": sum(len(p) for _, p, _ in trace),
        "generated_tokens": sum(g for _, _, g in trace),
        "engine": eng_m, "naive": nv_m,
        # gated metrics at the row top level (engine side)
        "requests_per_s": eng_m["requests_per_s"],
        "ttft_p50_s": eng_m["ttft_p50_s"],
        "ttft_p99_s": eng_m["ttft_p99_s"],
        "tpot_p50_s": eng_m["tpot_p50_s"],
        "tpot_p99_s": eng_m["tpot_p99_s"],
        "wall_speedup": nv_wall / eng_wall,
    }


def run(*, arch="qwen3-1.7b", smoke=True, out_json=_OUT):
    from repro.configs import get_arch

    spec = get_arch(arch)
    model = spec.make_smoke() if smoke else spec.make_model()
    params = model.init(jax.random.PRNGKey(0))

    # one admission-heavy burst (short budgets: prefill-dominated) and
    # one steadier decode-heavy trace
    cases = ([dict(n_requests=24, rate_rps=2000.0, prompt_lens=(8, 16),
                   gens=(4, 8), max_batch=4),
              dict(n_requests=12, rate_rps=400.0, prompt_lens=(8, 16),
                   gens=(16,), max_batch=4)]
             if smoke else
             [dict(n_requests=64, rate_rps=200.0, prompt_lens=(16, 32),
                   gens=(8, 16), max_batch=8),
              dict(n_requests=32, rate_rps=50.0, prompt_lens=(16, 32),
                   gens=(32,), max_batch=8)])

    rows = []
    for case in cases:
        r = run_case(model, params, **case)
        rows.append(r)
        print(f"rate={r['rate_rps']:.0f}/s gens={r['gens']}: "
              f"engine {r['requests_per_s']:.1f} req/s "
              f"(wall {r['engine']['wall_s']:.3f}s, "
              f"TTFT p50/p99 {r['ttft_p50_s'] * 1e3:.1f}/"
              f"{r['ttft_p99_s'] * 1e3:.1f} ms, TPOT p50 "
              f"{r['tpot_p50_s'] * 1e3:.2f} ms) vs naive "
              f"{r['naive']['requests_per_s']:.1f} req/s — "
              f"wall speedup {r['wall_speedup']:.2f}x")

    report = {"arch": arch, "smoke": smoke,
              "traffic_wall_bar": TRAFFIC_WALL_BAR, "rows": rows}
    os.makedirs(os.path.dirname(out_json), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"wrote {out_json}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=_OUT)
    args = ap.parse_args(argv)
    report = run(arch=args.arch, smoke=args.smoke, out_json=args.out)
    best = max(r["wall_speedup"] for r in report["rows"])
    if best < TRAFFIC_WALL_BAR:
        print(f"FAIL: best traffic wall speedup {best:.2f}x < "
              f"{TRAFFIC_WALL_BAR}x bar")
        return 1
    print(f"traffic replay >= {TRAFFIC_WALL_BAR}x wall bar: "
          f"best {best:.2f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
