.PHONY: test test-all test-fast bench sim serve-bench

# Tier-1 suite (scripts/ci.sh; deselects tests marked `slow`)
test:
	./scripts/ci.sh

# Everything, including slow end-to-end tests (ROADMAP.md verify command)
test-all:
	PYTHONPATH=src python -m pytest -x -q

# Skip the slow end-to-end training tests
test-fast:
	PYTHONPATH=src python -m pytest -x -q --ignore=tests/test_train_integration.py

bench:
	PYTHONPATH=src python -m benchmarks.run --fast

# Continuous batching vs naive serving loop (writes benchmarks/results/)
serve-bench:
	PYTHONPATH=src python -m benchmarks.bench_serve --smoke

# Full SimNet scenario library: conformance sweep + sim-marked tests
sim:
	PYTHONPATH=src python -m repro.sim
	PYTHONPATH=src python -m pytest -q -m sim
