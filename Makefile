.PHONY: test test-fast bench

# Tier-1 suite (ROADMAP.md verify command)
test:
	./scripts/ci.sh

# Skip the slow end-to-end training tests
test-fast:
	PYTHONPATH=src python -m pytest -x -q --ignore=tests/test_train_integration.py

bench:
	PYTHONPATH=src python -m benchmarks.run --fast
