.PHONY: test test-all test-fast bench sim serve-bench train-bench \
	iteration-bench async-bench lint repro-lint kernels-test \
	check-bench ci

# Every target preserves an existing PYTHONPATH (same idiom as
# scripts/ci.sh) instead of clobbering it.
PY_PATH = PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH))

# Tier-1 suite (scripts/ci.sh; deselects tests marked `slow`)
test:
	./scripts/ci.sh

# Everything, including slow end-to-end tests (ROADMAP.md verify command)
test-all:
	$(PY_PATH) python -m pytest -x -q

# Skip the slow end-to-end training tests
test-fast:
	$(PY_PATH) python -m pytest -x -q --ignore=tests/test_train_integration.py

bench:
	$(PY_PATH) python -m benchmarks.run --fast

# Continuous batching vs naive serving loop + paged-vs-contiguous KV,
# then the Poisson traffic replay (TTFT/TPOT percentiles)
# (writes benchmarks/results/ — the check-bench baselines)
serve-bench:
	$(PY_PATH) python -m benchmarks.bench_serve --smoke
	$(PY_PATH) python -m benchmarks.bench_traffic --smoke

# Period-fused training runner vs the per-step oracle (1.3x bar;
# writes benchmarks/results/bench_train_loop.json)
train-bench:
	$(PY_PATH) python -m benchmarks.bench_train_loop --smoke

# Paper Table 1 through the analytic time model (deterministic;
# writes benchmarks/results/bench_iteration_time.json)
iteration-bench:
	$(PY_PATH) python -m benchmarks.bench_iteration_time

# Async two-tier runtime vs barriered DreamDDP across the SimNet
# scenario library (deterministic model time; must beat sync on
# straggler + churn; writes benchmarks/results/bench_async.json)
async-bench:
	$(PY_PATH) python -m benchmarks.bench_async

# Full SimNet scenario library: conformance sweep + sim-marked tests
sim:
	$(PY_PATH) python -m repro.sim
	$(PY_PATH) python -m pytest -q -m sim

# ---------------------------------------------------------------- CI tiers
# The same steps .github/workflows/ci.yml runs, executable locally.

# Syntax gate + style gate + JAX-aware hazard rules (repro.lint).
# Ruff is required: a missing linter fails loudly instead of silently
# degrading, so `make lint` locally means exactly what CI's lint job
# means.
lint:
	python -m compileall -q src tests benchmarks scripts examples
	@command -v ruff >/dev/null 2>&1 || { \
		echo "error: ruff is not installed (pip install ruff);" \
		     "refusing to degrade to compileall-only lint" >&2; \
		exit 1; }
	ruff check src tests benchmarks scripts examples
	$(PY_PATH) python -m repro.lint src/repro --baseline .repro-lint-baseline.json

# The JAX-aware rules alone (no ruff needed; pure stdlib)
repro-lint:
	$(PY_PATH) python -m repro.lint src/repro --baseline .repro-lint-baseline.json

# Pallas kernel parity sweeps (interpret mode vs pure-jnp oracles)
kernels-test:
	$(PY_PATH) python -m pytest -x -q tests/test_kernels.py

# Fresh smoke benches (serve + train loop + Table 1) vs the committed
# baselines (deterministic metrics exact, wall-clock banded)
check-bench:
	$(PY_PATH) python scripts/check_bench.py

ci: lint test kernels-test check-bench
