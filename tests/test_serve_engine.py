"""repro.serve: continuous-batching engine vs the naive loop.

The two load-bearing guarantees:

* **greedy equivalence** — under greedy sampling the engine is
  token-for-token identical to the old ``InferenceSession`` loop for every
  arch family in the smoke set, including mid-stream admission (more
  requests than slots, staggered budgets);
* **slot reuse** — finishing a request and admitting a new one into the
  freed slot leaks no stale KV (output matches a fresh engine) and causes
  zero recompiles (jit cache-miss counters pinned).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.serve import (CachePool, Completion, EngineConfig, EngineStats,
                         NaiveLoop, Request, SamplingParams, ServeEngine)

# (arch_id, family): one representative per serving-relevant family
SMOKE_ARCHS = [
    ("qwen3-1.7b", "transformer"),
    ("mamba2-780m", "mamba2"),
    ("qwen3-moe-30b-a3b", "moe"),
    ("whisper-medium", "audio"),
    ("llava-next-34b", "vision"),
]

_PROMPT_LENS = (8, 5, 8, 11, 5)
_BUDGETS = (6, 4, 9, 3, 7)


def _setup(arch_id):
    arch = get_arch(arch_id)
    model = arch.make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, model.cfg.vocab, size=n).tolist()
               for n in _PROMPT_LENS]
    nf = 12 if arch.frontend == "audio" else 8
    extras = [()] * len(prompts)
    if arch.frontend:
        extras = [(np.asarray(rng.standard_normal(
            (nf, model.cfg.d_model)), np.float32),) for _ in prompts]
    return arch, model, params, prompts, extras


def _naive_rows(model, params, prompts, extras, budgets, frontend):
    loop = NaiveLoop(model, params, frontend=frontend)
    rows = []
    for p, e, g in zip(prompts, extras, budgets, strict=True):
        batched = tuple(jnp.asarray(a)[None] for a in e)
        rows.append(np.asarray(loop.generate(
            jnp.asarray([p], jnp.int32), g, *batched))[0].tolist())
    return rows


# ---------------------------------------------------------------- equivalence

@pytest.mark.parametrize("arch_id,family", SMOKE_ARCHS,
                         ids=[f for _, f in SMOKE_ARCHS])
def test_greedy_equivalence_with_midstream_admission(arch_id, family):
    """max_batch=2 over 5 staggered requests: slots free mid-decode and new
    requests are admitted into them; every token must match the naive
    per-request loop bit-for-bit."""
    arch, model, params, prompts, extras = _setup(arch_id)
    refs = _naive_rows(model, params, prompts, extras, _BUDGETS,
                       arch.frontend)
    eng = ServeEngine(
        model, params, EngineConfig(max_batch=2, max_seq=64,
                                    decode_block=4),
        frontend=arch.frontend)
    comps = eng.generate([
        Request(tokens=p, max_new_tokens=g, extra=e)
        for p, g, e in zip(prompts, _BUDGETS, extras, strict=True)])
    for comp, ref, g in zip(comps, refs, _BUDGETS, strict=True):
        assert comp.tokens == ref
        assert comp.finish_reason == "length"
        assert len(comp.tokens) == g
    assert eng.stats.requests_completed == len(prompts)
    assert eng.stats.generated_tokens == sum(_BUDGETS)


def test_eos_early_exit_matches_naive_prefix():
    _, model, params, prompts, extras = _setup("qwen3-1.7b")
    ref = _naive_rows(model, params, prompts[:1], extras[:1], (9,), None)[0]
    eos = ref[4]
    expect = ref[:ref.index(eos) + 1]
    eng = ServeEngine(model, params, EngineConfig(max_batch=2, max_seq=64))
    comp = eng.generate([Request(tokens=prompts[0], max_new_tokens=9,
                                 eos_id=eos)])[0]
    assert comp.tokens == expect
    assert comp.finish_reason == "stop"


def test_chunked_prefill_greedy_exact():
    """Bucketed prompt lengths (prefill_chunk) keep greedy decoding exact
    for attention-KV models and bound the number of prefill executables."""
    _, model, params, prompts, extras = _setup("qwen3-1.7b")
    refs = _naive_rows(model, params, prompts, extras, _BUDGETS, None)
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=2, max_seq=64,
                                   prefill_chunk=8))
    comps = eng.generate([Request(tokens=p, max_new_tokens=g)
                          for p, g in zip(prompts, _BUDGETS, strict=True)])
    for comp, ref in zip(comps, refs, strict=True):
        assert comp.tokens == ref
    # prompt lengths {5, 8, 11} collapse into buckets {8, 16}: batched
    # admission compiles once per (group size, bucket) = {(1, 8), (1, 16)}
    assert eng.compile_stats()["prefill_batched"] == 2
    assert eng.compile_stats()["prefill"] == 0


# ------------------------------------------------------------------ slot reuse

@pytest.mark.parametrize("arch_id,family", SMOKE_ARCHS,
                         ids=[f for _, f in SMOKE_ARCHS])
def test_slot_reuse_no_stale_kv_and_zero_recompiles(arch_id, family):
    """One slot, two sequential requests: the second tenant of the slot
    must see none of the first's cache, and re-admission must hit every
    jit cache."""
    arch, model, params, prompts, extras = _setup(arch_id)
    cfg = EngineConfig(max_batch=1, max_seq=64)
    eng = ServeEngine(model, params, cfg, frontend=arch.frontend)
    first = eng.generate([Request(tokens=prompts[0], max_new_tokens=6,
                                  extra=extras[0])])[0]
    assert len(first.tokens) == 6
    misses_before = eng.compile_stats()
    reused = eng.generate([Request(tokens=prompts[2], max_new_tokens=6,
                                   extra=extras[2])])[0]
    assert eng.compile_stats() == misses_before, "slot reuse recompiled"

    fresh_eng = ServeEngine(model, params, cfg, frontend=arch.frontend)
    fresh = fresh_eng.generate([Request(tokens=prompts[2],
                                        max_new_tokens=6,
                                        extra=extras[2])])[0]
    assert reused.tokens == fresh.tokens, "stale KV leaked across reuse"


def test_cache_pool_free_list():
    model = get_arch("qwen3-1.7b").make_smoke()
    pool = CachePool(model, n_slots=3, max_seq=16)
    slots = [pool.alloc() for _ in range(3)]
    assert sorted(slots) == [0, 1, 2] and pool.alloc() is None
    pool.free(slots[1])
    assert pool.n_free == 1 and pool.alloc() == slots[1]
    with pytest.raises(ValueError):
        pool.free(99)


def test_arena_allocated_once_never_reallocates():
    _, model, params, prompts, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params, EngineConfig(max_batch=2, max_seq=64))
    shapes0 = [a.shape for a in jax.tree_util.tree_leaves(eng.pool.arena)]
    eng.generate([Request(tokens=p, max_new_tokens=5) for p in prompts])
    assert [a.shape for a in
            jax.tree_util.tree_leaves(eng.pool.arena)] == shapes0


# -------------------------------------------------------------------- sampling

def test_sampling_seeded_deterministic_and_batch_independent():
    _, model, params, prompts, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params, EngineConfig(max_batch=3, max_seq=64))
    sp = SamplingParams(temperature=0.9, top_k=16, seed=42)
    solo = eng.generate([Request(tokens=prompts[0], max_new_tokens=8,
                                 sampling=sp)])[0]
    # same request sharing the batch with two other (greedy) requests
    eng.reset(params=params)
    crowd = eng.generate([
        Request(tokens=prompts[0], max_new_tokens=8, sampling=sp),
        Request(tokens=prompts[1], max_new_tokens=8),
        Request(tokens=prompts[3], max_new_tokens=8),
    ])[0]
    assert solo.tokens == crowd.tokens
    assert all(0 <= t < model.cfg.vocab for t in solo.tokens)


def test_top_k_one_equals_greedy():
    _, model, params, prompts, extras = _setup("qwen3-1.7b")
    ref = _naive_rows(model, params, prompts[:1], extras[:1], (8,), None)[0]
    eng = ServeEngine(model, params, EngineConfig(max_batch=1, max_seq=64))
    comp = eng.generate([Request(
        tokens=prompts[0], max_new_tokens=8,
        sampling=SamplingParams(temperature=0.7, top_k=1, seed=3))])[0]
    assert comp.tokens == ref


# ----------------------------------------------------------- incremental mode

def test_submit_step_streaming_callbacks():
    _, model, params, prompts, extras = _setup("qwen3-1.7b")
    ref = _naive_rows(model, params, prompts[:1], extras[:1], (5,), None)[0]
    eng = ServeEngine(model, params, EngineConfig(max_batch=2, max_seq=64))
    seen = []
    rid = eng.submit(Request(tokens=prompts[0], max_new_tokens=5),
                     on_token=lambda r, tok, i: seen.append((r, tok, i)))
    comps = eng.drain()
    assert [c.request_id for c in comps] == [rid]
    assert [t for _, t, _ in seen] == ref
    assert [r for r, _, _ in seen] == [rid] * 5
    assert [i for _, _, i in seen] == list(range(5))


def test_submit_rejects_oversized_and_empty_requests():
    _, model, params, _, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params, EngineConfig(max_batch=1, max_seq=16))
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(Request(tokens=[1] * 10, max_new_tokens=10))
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(tokens=[], max_new_tokens=4))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(Request(tokens=[1, 2], max_new_tokens=0))


def test_submit_capacity_accounts_for_prefill_padding():
    """A prompt whose chunk-padded prefill would overflow the cache must
    be rejected at submit, not explode mid-admission."""
    _, model, params, _, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params,
                      EngineConfig(max_batch=1, max_seq=12,
                                   prefill_chunk=8))
    # 9 + 3 = 12 fits, but the padded prefill needs 16 positions
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(Request(tokens=[1] * 9, max_new_tokens=3))


def test_chunked_prefill_rejected_for_recurrent_state_models():
    for arch_id in ("mamba2-780m", "recurrentgemma-9b"):
        model = get_arch(arch_id).make_smoke()
        params = model.init(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="recurrent state"):
            ServeEngine(model, params,
                        EngineConfig(max_batch=1, max_seq=32,
                                     prefill_chunk=8))


def test_single_token_budget_finishes_at_admission():
    _, model, params, prompts, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params, EngineConfig(max_batch=1, max_seq=64))
    comp = eng.generate([Request(tokens=prompts[0], max_new_tokens=1)])[0]
    assert len(comp.tokens) == 1 and comp.finish_reason == "length"
    assert eng.stats.decode_ticks == 0


def test_engine_stats_accounting():
    _, model, params, prompts, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params, EngineConfig(max_batch=2, max_seq=64))
    eng.generate([Request(tokens=p, max_new_tokens=g)
                  for p, g in zip(prompts, _BUDGETS, strict=True)])
    st = eng.stats
    assert st.requests_completed == len(prompts)
    assert st.generated_tokens == sum(_BUDGETS)
    assert st.prompt_tokens == sum(_PROMPT_LENS)
    # prefill produces each request's first token; decode the rest
    assert st.decode_tokens == sum(_BUDGETS) - len(prompts)
    assert st.decode_time_s > 0 and st.prefill_time_s > 0
    assert st.decode_tokens_per_s > 0
    assert len(st.ttft_s) == len(prompts)
    assert all(l >= t > 0 for t, l in zip(st.ttft_s, st.latency_s, strict=True))
    assert 0 < st.slot_utilization <= 1
    d = st.as_dict()
    assert d["generated_tokens"] == sum(_BUDGETS)


def test_engine_reset_keeps_compiled_steps():
    _, model, params, prompts, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params, EngineConfig(max_batch=2, max_seq=64))
    a = eng.generate([Request(tokens=prompts[0], max_new_tokens=5)])[0]
    misses = eng.compile_stats()
    eng.reset(params=params)
    assert eng.stats.requests_completed == 0
    b = eng.generate([Request(tokens=prompts[0], max_new_tokens=5)])[0]
    assert a.tokens == b.tokens
    assert eng.compile_stats() == misses
