"""Session.replan / Session.simulate under drift, churn and degradation.

Scenario regression tests the repo could not express before SimNet: the
facade's replan path must converge to the schedule that is optimal for
the *new* network conditions, both inside the simulator (replay-driven
re-solves) and on the live session object (``.replan(bandwidth=...)``).
"""

import jax
import pytest

from repro.api import JobConfig, Session, get_strategy
from repro.models.transformer import DecoderLM, LMConfig
from repro.sim import (BandwidthDrift, LinkSpec, Scenario, WorkerLeave,
                       get_scenario)

_CFG = LMConfig(name="t", n_layers=8, d_model=48, n_heads=4, n_kv_heads=2,
                d_ff=96, vocab=64, param_dtype="float32", remat=False)


def _session(algo="dreamddp", *, workers=8, H=4, bandwidth=1e9, **kw):
    cfg = JobConfig(algo=algo, workers=workers, period=H,
                    bandwidth=bandwidth, seq=32, batch_per_worker=2,
                    warmup_steps=2, decay_steps=200, **kw)
    return Session(cfg, model=DecoderLM(_CFG))


# ------------------------------------------------- simulate-driven replans

def test_simulate_replans_to_newly_optimal_partition():
    """After a drift event the in-sim re-solve must produce exactly the
    plan the strategy would build for the drifted network."""
    sess = _session()
    sc = get_scenario("drifting-bandwidth")
    report = sess.simulate(sc)
    assert report.replanned
    (p0, plan0), (p1, plan1) = report.plans
    assert (p0, p1) == (0, 1)

    drifted_bw = sc.events[0].bandwidth
    t1 = report.trace.period_start(1)
    cluster = sc.build(4)
    cluster.advance(4, t1)
    expected = sess.strategy.build_plan(
        cluster.effective_profile(sess.profile(), t1), 4)
    assert plan1.phase_units == expected.phase_units
    assert plan1.meta["partition_counts"] == \
        expected.meta["partition_counts"]
    assert plan1.meta["bandwidth"] == drifted_bw


def test_simulate_replan_improves_post_drift_period():
    """Re-planning after drift must not be worse than keeping the stale
    plan — and for a real drift it should strictly help."""
    sess = _session()
    with_replan = sess.simulate("drifting-bandwidth", replan=True)
    without = sess.simulate("drifting-bandwidth", replan=False)
    # period 2 is fully post-drift in both runs
    assert with_replan.trace.period_time(2) <= \
        without.trace.period_time(2) + 1e-12


def test_simulate_churn_replans_on_membership_change():
    sess = _session()
    report = sess.simulate("churn")
    assert report.replanned
    periods = [p for p, _ in report.plans]
    assert periods[0] == 0 and all(p >= 1 for p in periods[1:])
    # final plan was solved for the restored 8-worker membership
    assert report.final_plan.meta["n_workers"] == 8


def test_simulate_no_replan_on_static_scenario():
    report = _session().simulate("homogeneous")
    assert not report.replanned
    assert report.trace.n_periods == 2


def test_simulate_mid_period_event_replans_at_next_boundary():
    """An iteration-scheduled drift that lands mid-period must still
    trigger the re-solve — deferred to the next period boundary."""
    sc = Scenario(name="mid-period-drift", description="",
                  n_workers=8,
                  events=(BandwidthDrift(iteration=6, link="intra",
                                         bandwidth=1e7),),
                  periods=3)
    report = _session(H=4).simulate(sc)
    assert report.replanned
    # fired at iteration 6 (period 1) -> replanned from period 2 on
    assert [p for p, _ in report.plans] == [0, 2]
    assert report.final_plan.meta["bandwidth"] == 1e7


def test_simulate_custom_scenario_object():
    sc = Scenario(name="custom-drift", description="",
                  n_workers=4, intra=LinkSpec(bandwidth=5e9, latency=1e-4),
                  events=(BandwidthDrift(period=1, link="intra",
                                         bandwidth=1e8),
                          WorkerLeave(period=2, n=1)),
                  periods=3)
    report = _session(workers=4).simulate(sc)
    assert report.trace.n_periods == 3
    assert len(report.trace.events) == 2


# ------------------------------------------------- live-session regression

def test_live_replan_matches_simulated_optimum():
    """The session's own .replan(bandwidth=...) lands on the same
    partition the simulator converged to after the same drift."""
    sc = get_scenario("drifting-bandwidth")
    sess = _session(bandwidth=1e9, latency=sc.intra.latency)
    report = sess.simulate(sc)
    assert report.replanned
    live_plan = sess.replan(bandwidth=sc.events[0].bandwidth)
    assert live_plan.phase_units == report.final_plan.phase_units


@pytest.mark.slow
def test_replan_under_drift_keeps_training(tmp_path):
    """Drift mid-run: fit -> replan -> fit keeps descending, and the
    rebuilt steps execute the new partition."""
    sess = _session(workers=4, H=4)
    sess.fit(8)
    old_units = sess.plan.phase_units
    sess.replan(bandwidth=2e7)
    sess.fit(8)
    assert len(sess.history) == 16
    losses = [h["loss"] for h in sess.history]
    assert losses[-1] < losses[0]
    assert sess.runner.plan.phase_units != old_units or \
        sess.plan.meta["bandwidth"] == 2e7


@pytest.mark.slow
def test_replan_elastic_leave_then_join_roundtrip():
    """Elastic membership round-trip 4 -> 2 -> 4 workers mid-run."""
    sess = _session(workers=4, H=4)
    sess.fit(4)
    sess.replan(workers=2)
    assert jax.tree_util.tree_leaves(sess.state.params)[0].shape[0] == 2
    sess.fit(4)
    sess.replan(workers=4)
    assert jax.tree_util.tree_leaves(sess.state.params)[0].shape[0] == 4
    sess.fit(4)
    assert len(sess.history) == 12


def test_gradient_sync_strategy_simulates_with_H1():
    """ssgd forces H == 1; simulate must follow the plan's period."""
    report = _session(algo="ssgd", H=4).simulate("homogeneous")
    assert report.final_plan.H == 1
    assert report.trace.H == 1
