"""ServeEngine with ``kv_backend="paged"``: equivalence + zero-recompile.

The paged pool must be a pure storage-layout change: under greedy
sampling the engine is token-for-token identical to both the naive
per-request loop and the contiguous-backend engine for every KV-cache
family (transformer / moe / mla / vision-prefixed), including mid-stream
admission into freed slots and page-exhaustion-deferred admission.
Admit / extend / finish churn must never recompile (jit cache sizes
pinned) and never reallocate the pool.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.serve import (EngineConfig, NaiveLoop, PagedCachePool, Request,
                         SamplingParams, ServeEngine)

# KV-cache families only: recurrent state (mamba2/rglru) and the audio
# cross-KV decoder have nothing to page and are covered by the rejection
# test below.
PAGED_ARCHS = [
    ("qwen3-1.7b", "transformer"),
    ("qwen3-moe-30b-a3b", "moe"),
    ("deepseek-v3-671b", "mla"),
    ("llava-next-34b", "vision"),
]

_PROMPT_LENS = (8, 5, 8, 11, 5)
_BUDGETS = (6, 4, 9, 3, 7)


def _setup(arch_id):
    arch = get_arch(arch_id)
    model = arch.make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, model.cfg.vocab, size=n).tolist()
               for n in _PROMPT_LENS]
    extras = [()] * len(prompts)
    if arch.frontend:
        extras = [(np.asarray(rng.standard_normal(
            (8, model.cfg.d_model)), np.float32),) for _ in prompts]
    return arch, model, params, prompts, extras


def _naive_rows(model, params, prompts, extras, budgets, frontend):
    loop = NaiveLoop(model, params, frontend=frontend)
    rows = []
    for p, e, g in zip(prompts, extras, budgets, strict=True):
        batched = tuple(jnp.asarray(a)[None] for a in e)
        rows.append(np.asarray(loop.generate(
            jnp.asarray([p], jnp.int32), g, *batched))[0].tolist())
    return rows


def _paged_cfg(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("decode_block", 4)
    kw.setdefault("kv_backend", "paged")
    kw.setdefault("page_size", 8)
    return EngineConfig(**kw)


# ---------------------------------------------------------------- equivalence

@pytest.mark.parametrize("arch_id,family", PAGED_ARCHS,
                         ids=[f for _, f in PAGED_ARCHS])
def test_paged_greedy_equivalence_with_midstream_admission(arch_id,
                                                           family):
    """max_batch=2 over 5 staggered requests: slots and their pages free
    mid-decode and new requests are admitted into them; every token must
    match the naive per-request loop bit-for-bit."""
    arch, model, params, prompts, extras = _setup(arch_id)
    refs = _naive_rows(model, params, prompts, extras, _BUDGETS,
                       arch.frontend)
    eng = ServeEngine(model, params, _paged_cfg(),
                      frontend=arch.frontend)
    comps = eng.generate([
        Request(tokens=p, max_new_tokens=g, extra=e)
        for p, g, e in zip(prompts, _BUDGETS, extras, strict=True)])
    for comp, ref, g in zip(comps, refs, _BUDGETS, strict=True):
        assert comp.tokens == ref
        assert len(comp.tokens) == g
    assert eng.stats.requests_completed == len(prompts)


def test_paged_matches_contiguous_backend_token_for_token():
    _, model, params, prompts, _ = _setup("qwen3-1.7b")
    reqs = lambda: [Request(tokens=p, max_new_tokens=g)
                    for p, g in zip(prompts, _BUDGETS, strict=True)]
    cont = ServeEngine(model, params, _paged_cfg(kv_backend="contiguous"))
    paged = ServeEngine(model, params, _paged_cfg())
    a = cont.generate(reqs())
    b = paged.generate(reqs())
    assert [c.tokens for c in a] == [c.tokens for c in b]


def test_paged_zero_recompiles_across_admit_extend_finish():
    """Two full generate() rounds over the same shapes: the second round
    re-admits into freed slots, re-extends pages, and re-finishes — and
    must hit every jit cache (prefill, decode block, prefill scatter)."""
    _, model, params, prompts, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params, _paged_cfg())
    reqs = lambda: [Request(tokens=p, max_new_tokens=g)
                    for p, g in zip(prompts, _BUDGETS, strict=True)]
    first = eng.generate(reqs())
    misses = eng.compile_stats()
    assert "prefill_scatter" in misses
    again = eng.generate(reqs())
    assert eng.compile_stats() == misses, "paged admit/extend/finish " \
        "recompiled"
    assert [c.tokens for c in first] == [c.tokens for c in again]


def test_paged_slot_reuse_no_stale_pages():
    """One slot, two sequential requests: the pages freed by the first
    tenant are re-allocated to the second, which must see none of the
    first's KV (output matches a fresh engine)."""
    _, model, params, prompts, _ = _setup("qwen3-1.7b")
    cfg = _paged_cfg(max_batch=1)
    eng = ServeEngine(model, params, cfg)
    eng.generate([Request(tokens=prompts[0], max_new_tokens=6)])
    reused = eng.generate([Request(tokens=prompts[2],
                                   max_new_tokens=6)])[0]
    fresh = ServeEngine(model, params, cfg).generate(
        [Request(tokens=prompts[2], max_new_tokens=6)])[0]
    assert reused.tokens == fresh.tokens, "stale KV leaked across pages"


def test_page_exhaustion_defers_admission_not_corrupts():
    """A pool with pages for only ~one request at a time still completes
    every request correctly — admission waits for retirements."""
    _, model, params, prompts, extras = _setup("qwen3-1.7b")
    refs = _naive_rows(model, params, prompts, extras, _BUDGETS, None)
    # largest request: prefix 0 + max(11 + 3, -) = 14 tokens -> 2 pages
    # of 8... need covers s + max_new; give 4 usable pages (+1 trash)
    eng = ServeEngine(model, params, _paged_cfg(kv_pages=5))
    comps = eng.generate([Request(tokens=p, max_new_tokens=g)
                          for p, g in zip(prompts, _BUDGETS, strict=True)])
    for comp, ref in zip(comps, refs, strict=True):
        assert comp.tokens == ref
    assert eng.pool.peak_pages_in_use <= 4


def test_paged_chunked_prefill_greedy_exact():
    _, model, params, prompts, extras = _setup("qwen3-1.7b")
    refs = _naive_rows(model, params, prompts, extras, _BUDGETS, None)
    eng = ServeEngine(model, params, _paged_cfg(prefill_chunk=8))
    comps = eng.generate([Request(tokens=p, max_new_tokens=g)
                          for p, g in zip(prompts, _BUDGETS, strict=True)])
    for comp, ref in zip(comps, refs, strict=True):
        assert comp.tokens == ref
    # prompt lengths {5, 8, 11} collapse into buckets {8, 16}: the exact
    # bucket hits the no-refeed admit once ((1, 8)); the padded prompts
    # hit the refeed admit at (1, 8) and (1, 16)
    assert eng.compile_stats()["paged_admit"] == 1
    assert eng.compile_stats()["paged_admit_refeed"] == 2


def test_paged_sampling_seeded_deterministic_and_batch_independent():
    _, model, params, prompts, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params, _paged_cfg(max_batch=3))
    sp = SamplingParams(temperature=0.9, top_k=16, seed=42)
    solo = eng.generate([Request(tokens=prompts[0], max_new_tokens=8,
                                 sampling=sp)])[0]
    eng.reset(params=params)
    crowd = eng.generate([
        Request(tokens=prompts[0], max_new_tokens=8, sampling=sp),
        Request(tokens=prompts[1], max_new_tokens=8),
        Request(tokens=prompts[3], max_new_tokens=8),
    ])[0]
    assert solo.tokens == crowd.tokens


def test_paged_pool_never_reallocates():
    _, model, params, prompts, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params, _paged_cfg())
    assert isinstance(eng.pool, PagedCachePool)
    leaves = jax.tree_util.tree_leaves(eng.pool.arena) \
        + jax.tree_util.tree_leaves(eng.pool.scratch)
    shapes0 = [a.shape for a in leaves]
    eng.generate([Request(tokens=p, max_new_tokens=5) for p in prompts])
    leaves = jax.tree_util.tree_leaves(eng.pool.arena) \
        + jax.tree_util.tree_leaves(eng.pool.scratch)
    assert [a.shape for a in leaves] == shapes0


def test_paged_peak_footprint_beats_contiguous_on_mixed_lengths():
    """Mixed short/long traffic: the pool's high-water page footprint
    (what a right-sized deployment would provision) must undercut the
    contiguous arena."""
    _, model, params, _, _ = _setup("qwen3-1.7b")
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, model.cfg.vocab,
                           size=8 if i % 4 else 56).tolist()
               for i in range(8)]
    cont = ServeEngine(model, params, EngineConfig(
        max_batch=4, max_seq=64, decode_block=4))
    paged = ServeEngine(model, params, _paged_cfg(max_batch=4))
    reqs = lambda: [Request(tokens=p, max_new_tokens=8) for p in prompts]
    a = cont.generate(reqs())
    b = paged.generate(reqs())
    assert [c.tokens for c in a] == [c.tokens for c in b]
    assert paged.pool.peak_kv_bytes() < cont.pool.kv_bytes()


def test_paged_rejected_for_recurrent_and_cross_kv_models():
    for arch_id in ("mamba2-780m", "recurrentgemma-9b", "whisper-medium"):
        model = get_arch(arch_id).make_smoke()
        params = model.init(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="paged"):
            ServeEngine(model, params, _paged_cfg(max_batch=1,
                                                  max_seq=32))


def test_config_validation():
    with pytest.raises(ValueError, match="kv_backend"):
        EngineConfig(kv_backend="virtual")
    with pytest.raises(ValueError, match="multiple"):
        EngineConfig(kv_backend="paged", max_seq=100, page_size=16)
    with pytest.raises(ValueError, match="kv_pages"):
        EngineConfig(kv_backend="paged", max_seq=64, page_size=8,
                     kv_pages=1)
