"""Worker-stacked pytree partial synchronization semantics."""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partial_sync import (UnitEntry, UnitLayout,
                                     contiguous_ranges, divergence,
                                     sync_units, tree_worker_mean,
                                     unit_divergence, worker_stack,
                                     worker_unstack)


def _layout():
    return UnitLayout((
        UnitEntry("embed", "embed", None),
        UnitEntry("l0", "blocks", 0),
        UnitEntry("l1", "blocks", 1),
        UnitEntry("l2", "blocks", 2),
        UnitEntry("head", "head", None),
    ))


def _params(key, w=4):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": {"table": jax.random.normal(k1, (w, 8, 4))},
        "blocks": {"w": jax.random.normal(k2, (w, 3, 4, 4)),
                   "b": jax.random.normal(k3, (w, 3, 4))},
        "head": {"out": jax.random.normal(k1, (w, 4, 8))},
    }


def test_sync_selected_units_only():
    p = _params(jax.random.PRNGKey(0))
    out = sync_units(p, [1, 2], _layout())
    # blocks 0,1 synced: identical across workers
    for leaf in ("w", "b"):
        synced = out["blocks"][leaf][:, 0:2]
        np.testing.assert_allclose(np.asarray(synced - synced[:1]), 0.0,
                                   atol=1e-6)
        # block 2 untouched
        np.testing.assert_array_equal(np.asarray(out["blocks"][leaf][:, 2]),
                                      np.asarray(p["blocks"][leaf][:, 2]))
    np.testing.assert_array_equal(np.asarray(out["embed"]["table"]),
                                  np.asarray(p["embed"]["table"]))


def test_sync_preserves_mean():
    """Averaging preserves the worker mean of every synced leaf."""
    p = _params(jax.random.PRNGKey(1))
    out = sync_units(p, [0, 2, 4], _layout())
    for (_ka, a), (_kb, b) in zip(
            jax.tree_util.tree_leaves_with_path(p),
            jax.tree_util.tree_leaves_with_path(out), strict=True):
        np.testing.assert_allclose(np.asarray(a.mean(0)),
                                   np.asarray(b.mean(0)), atol=1e-5)


def test_full_sync_kills_divergence():
    p = _params(jax.random.PRNGKey(2))
    assert float(divergence(p)) > 0.1
    synced = tree_worker_mean(p)
    assert float(divergence(synced)) < 1e-10


def test_unit_divergence_vector():
    p = _params(jax.random.PRNGKey(3))
    layout = _layout()
    before = unit_divergence(p, layout)
    out = sync_units(p, [1], layout)
    after = unit_divergence(out, layout)
    assert float(after[1]) < 1e-10
    np.testing.assert_allclose(np.asarray(after[0]), np.asarray(before[0]),
                               rtol=1e-6)


def test_worker_stack_roundtrip():
    p = {"a": jnp.arange(6.0).reshape(2, 3)}
    s = worker_stack(p, 5)
    assert s["a"].shape == (5, 2, 3)
    np.testing.assert_array_equal(np.asarray(worker_unstack(s, 3)["a"]),
                                  np.asarray(p["a"]))
    assert float(divergence(s)) == 0.0


@pytest.mark.parametrize("seed", range(25))
def test_contiguous_ranges_property(seed):
    """Seeded replacement for the hypothesis property: random index lists
    (including empty and duplicate-heavy ones) always round-trip."""
    rng = random.Random(seed)
    xs = [rng.randint(0, 30) for _ in range(rng.randint(0, 20))]
    rs = contiguous_ranges(xs)
    covered = sorted(i for lo, hi in rs for i in range(lo, hi))
    assert covered == sorted(set(xs))
    # ranges are disjoint, ordered, non-adjacent
    for (_l1, h1), (l2, _h2) in zip(rs, rs[1:], strict=False):
        assert h1 < l2


def test_bad_layout_raises():
    layout = UnitLayout((UnitEntry("x", "missing", None),))
    with pytest.raises(KeyError):
        layout.validate_against({"blocks": {}})
