"""AsyncHierRunner: real training over the deterministic op log —
loss progress, bitwise determinism, single-shot run semantics, exact
checkpoint/restore mid-run, elastic join/leave (the fault suite)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.registry import get_strategy
from repro.checkpoint import CheckpointManager
from repro.core import HardwareSpec, analytic_profile
from repro.data import MarkovCorpus
from repro.hier import AsyncHierRunner, AsyncRunnerConfig, JoinOp, LeaveOp
from repro.models.transformer import DecoderLM, LMConfig
from repro.optim import make_optimizer
from repro.sim.events import WorkerJoin, WorkerLeave
from repro.sim.network import LinkSpec
from repro.sim.scenarios import Scenario

SEQ = 32
PERIODS = 4
H = 4


@pytest.fixture(scope="module")
def model():
    cfg = LMConfig(name="t", n_layers=4, d_model=48, n_heads=4,
                   n_kv_heads=2, d_ff=96, vocab=64,
                   param_dtype="float32", remat=False)
    return DecoderLM(cfg)


def _scenario(n_workers, events=()):
    return Scenario(name=f"tiny-{n_workers}w-{len(events)}ev",
                    description="", n_workers=n_workers, n_datacenters=1,
                    intra=LinkSpec(bandwidth=1e9, latency=1e-4,
                                   jitter=0.0),
                    inter=None, drift={}, events=tuple(events),
                    periods=PERIODS, seed=0)


def _runner(model, scenario, *, ckpt=None, ckpt_every=0):
    w = scenario.n_workers
    profile = analytic_profile(model.layer_costs(4, SEQ),
                               HardwareSpec(bandwidth=1e9, n_workers=w))
    opt = make_optimizer("adam", lr=3e-3, warmup_steps=5, decay_steps=400)
    data = MarkovCorpus(vocab=64, seq_len=SEQ, batch_per_worker=4,
                        n_workers=w, seed=0)
    return AsyncHierRunner(
        model, opt, get_strategy("dreamddp"), data, profile=profile,
        scenario=scenario, H=H, seed=0, ckpt=ckpt,
        run_cfg=AsyncRunnerConfig(ckpt_every_merges=ckpt_every))


def _final_loss(runner):
    hist = sorted(runner.history, key=lambda h: h["t_end"])
    return hist[0]["loss"], hist[-1]["loss"]


def _assert_trees_equal(a, b):
    for (path, x), (_, y) in zip(
            jax.tree_util.tree_flatten_with_path(a)[0],
            jax.tree_util.tree_flatten_with_path(b)[0]):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=str(path))


def test_loss_decreases_and_trace_deterministic(model):
    sc = _scenario(2)
    r1 = _runner(model, sc)
    tr1 = r1.run(PERIODS)
    first, last = _final_loss(r1)
    assert last < first
    assert len(r1.history) == PERIODS * sc.n_workers
    r2 = _runner(model, sc)
    tr2 = r2.run(PERIODS)
    assert tr1.fingerprint() == tr2.fingerprint()
    _assert_trees_equal(r1.server.params, r2.server.params)


def test_run_is_single_shot(model):
    r = _runner(model, _scenario(2))
    r.run(PERIODS)
    with pytest.raises(ValueError, match="op-log replay cannot extend"):
        r.run(PERIODS + 1)
    # same total is a no-op replay continuation, not an error
    r.run(PERIODS)


def test_stacked_params_broadcasts_global_model(model):
    r = _runner(model, _scenario(2))
    r.run(PERIODS)
    stacked = r.stacked_params(3)
    flat = jax.tree_util.tree_leaves(stacked)
    assert all(leaf.shape[0] == 3 for leaf in flat)
    one = jax.tree.map(lambda x: x[1], stacked)
    want = jax.tree.map(lambda g, p: g.astype(p.dtype), r.server.params,
                        jax.tree.map(lambda x: x[0],
                                     r._template.params))
    _assert_trees_equal(one, want)


def test_checkpoint_restore_replays_identical_run(model, tmp_path):
    """Acceptance criterion: a resumed run replays to the same seeded
    SimNet trace and bitwise-identical parameters."""
    sc = _scenario(2)
    ref = _runner(model, sc)
    ref_trace = ref.run(PERIODS)

    d = os.fspath(tmp_path)
    ck = _runner(model, sc, ckpt=CheckpointManager(d, keep=50),
                 ckpt_every=12)
    ck_trace = ck.run(PERIODS)
    assert ck_trace.fingerprint() == ref_trace.fingerprint()
    steps = sorted(int(p.split("_")[1]) for p in os.listdir(d)
                   if p.startswith("step_"))
    assert len(steps) >= 2, "need a mid-run checkpoint to test restore"

    res = _runner(model, sc, ckpt=CheckpointManager(d, keep=50))
    version = res.restore(step=steps[len(steps) // 2])
    assert version == steps[len(steps) // 2]
    assert 0 < res.cursor
    trace = res.run(PERIODS)
    assert trace.fingerprint() == ref_trace.fingerprint()
    _assert_trees_equal(res.server.params, ref.server.params)
    for w in sorted(ref.states):
        _assert_trees_equal(res.states[w].params, ref.states[w].params)


def test_restore_rejects_foreign_plan(model, tmp_path):
    sc = _scenario(2)
    d = os.fspath(tmp_path)
    r = _runner(model, sc, ckpt=CheckpointManager(d, keep=5),
                ckpt_every=12)
    r.run(PERIODS)
    other = _runner(model, _scenario(3), ckpt=CheckpointManager(d, keep=5))
    with pytest.raises(ValueError, match="different.*plan|plan"):
        other.restore()


def test_elastic_join_leave_round_trip(model):
    """Acceptance criterion: elastic membership mid-async-run — the
    leaver's state drops, the joiner bootstraps from the global model
    and trains, and the whole run stays deterministic."""
    sc = _scenario(3, events=(WorkerLeave(period=1, iteration=None, n=1),
                              WorkerJoin(period=2, iteration=None, n=1)))
    r = _runner(model, sc)
    trace = r.run(PERIODS)
    ops = r._schedule(PERIODS)[0]
    joins = [o for o in ops if isinstance(o, JoinOp)]
    leaves = [o for o in ops if isinstance(o, LeaveOp)]
    assert len(joins) == 1 and len(leaves) == 1
    assert leaves[0].worker not in r.states
    assert joins[0].worker in r.states
    assert any(h["worker"] == joins[0].worker for h in r.history)
    first, last = _final_loss(r)
    assert last < first
    r2 = _runner(model, sc)
    assert r2.run(PERIODS).fingerprint() == trace.fingerprint()
    _assert_trees_equal(r.server.params, r2.server.params)


def test_non_mean_policy_rejected(model):
    from repro.runtime.step import StepConfig
    sc = _scenario(2)
    profile = analytic_profile(model.layer_costs(4, SEQ),
                               HardwareSpec(bandwidth=1e9, n_workers=2))
    opt = make_optimizer("adam", lr=3e-3, warmup_steps=5, decay_steps=400)
    data = MarkovCorpus(vocab=64, seq_len=SEQ, batch_per_worker=4,
                        n_workers=2, seed=0)
    with pytest.raises(ValueError, match="mean sync policy"):
        AsyncHierRunner(model, opt, get_strategy("dreamddp"), data,
                        profile=profile, scenario=sc, H=H,
                        step_cfg=StepConfig(compress="int8_ef"))
