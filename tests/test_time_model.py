"""Eq. 7/8 time model: algebraic identities + baseline orderings."""

import random

import pytest

from repro.core.time_model import (Partition, flsgd_period_time, objective,
                                   simulate_period, simulate_phase,
                                   ssgd_iteration_time, wfbp_iteration_time)

from conftest import random_profile


def test_wfbp_no_slower_than_ssgd(profile12):
    """Overlap can only help (paper §2, WFBP motivation)."""
    assert wfbp_iteration_time(profile12) <= \
        ssgd_iteration_time(profile12) + 1e-12


def test_ascwfbp_no_slower_than_wfbp(profile12):
    assert wfbp_iteration_time(profile12, n_channels=4) <= \
        wfbp_iteration_time(profile12, n_channels=1) + 1e-12


@pytest.mark.parametrize("H", [2, 5])
def test_partial_sync_period_beats_flsgd(profile12, H):
    """Eq. 7: overlapped partial sync <= full-sync LSGD per period."""
    part = Partition.equal_number(len(profile12), H)
    plsgd = sum(t.iteration_time for t in simulate_period(profile12, part)) \
        + H * 0  # comm already included
    assert plsgd <= flsgd_period_time(profile12, H) + 1e-9


def test_simulate_phase_dependencies(profile12):
    """Comm of layer l starts only after its BP completes and after the
    previous comm finishes (the tau-recursion)."""
    tl = simulate_phase(profile12, range(len(profile12)))
    prev_done = 0.0
    for i in sorted(tl.comm_start):
        assert tl.comm_start[i] >= tl.bp_done[i] - 1e-12
        assert tl.comm_start[i] >= prev_done - 1e-12
        prev_done = tl.comm_done[i]


def test_empty_phase_is_local_step(profile12):
    tl = simulate_phase(profile12, [])
    assert tl.iteration_time == pytest.approx(
        profile12.t_fp_total + profile12.t_bp_total)
    assert tl.exposed_comm == 0.0


@pytest.mark.parametrize("seed", range(20))
def test_objective_vs_exact_timeline(seed):
    """Eq. 8 (sum-comm approximation) is a LOWER bound on the exact
    event timeline only up to serialization effects; both must bound the
    pure-compute floor from below.  (Seeded replacement for the
    hypothesis property.)"""
    rng = random.Random(seed)
    L, H = rng.randint(2, 12), rng.randint(2, 5)
    prof = random_profile(L, seed=seed)
    part = Partition.equal_number(L, H)
    floor = H * (prof.t_fp_total + prof.t_bp_total)
    exact = sum(t.iteration_time for t in simulate_period(prof, part))
    assert exact >= floor - 1e-12
    assert objective(prof, part, include_fp=True) >= floor - 1e-12


def test_partition_layer_ids_roundtrip():
    p = Partition((2, 3, 1))
    ids = p.layer_ids()
    flat = sorted(i for ph in ids for i in ph)
    assert flat == list(range(6))
    # phase 0 holds the output-most layers (network ids 4, 5)
    assert ids[0] == [4, 5]
