"""PagedCachePool allocator invariants under seeded churn.

The pool is pure host-side bookkeeping over a fixed device arena, so the
properties are classic allocator properties: pages are never leaked,
never shared by two live slots, exhaustion *rejects* admission (returns
None) instead of over-committing, and reset reclaims everything.  The
commitment invariant — admission reserves the worst case so ``extend``
can never fail mid-decode — is exercised by growing every live slot to
its full footprint each round.
"""

import random

import pytest

from repro.configs import get_arch
from repro.serve import CachePool, PagedCachePool
from repro.serve.cache import TRASH_PAGE


def _pool(n_slots=4, max_seq=64, page_size=8, n_pages=None):
    model = get_arch("qwen3-1.7b").make_smoke()
    return PagedCachePool(model, n_slots, max_seq, page_size=page_size,
                          n_pages=n_pages)


def _check_invariants(pool):
    """No page leaked, none shared, block tables consistent."""
    live = [p for row in pool._pages_of for p in row]
    assert len(live) == len(set(live)), "page shared by two live slots"
    assert TRASH_PAGE not in live, "trash page allocated"
    free = set(pool._free_pages)
    assert not free & set(live), "page both free and live"
    assert len(free) + len(live) == pool.n_usable_pages, "page leaked"
    assert pool.pages_in_use == len(live)
    for slot, row in enumerate(pool._pages_of):
        got = pool.block_tables[slot, :len(row)].tolist()
        assert got == row, "block table diverged from allocator"
        assert (pool.block_tables[slot, len(row):] == TRASH_PAGE).all(), \
            "stale block-table entries past the allocation"


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_alloc_extend_free_churn(seed):
    rng = random.Random(seed)
    pool = _pool(n_slots=6, max_seq=64, page_size=8, n_pages=25)
    live: dict[int, int] = {}            # slot -> committed tokens
    grown: dict[int, int] = {}
    for _ in range(300):
        op = rng.random()
        if op < 0.45:
            need = rng.randint(1, 64)
            slot = pool.alloc(need)
            if slot is not None:
                assert slot not in live
                live[slot] = need
                grown[slot] = rng.randint(1, need)
                pool.extend(slot, grown[slot])
        elif op < 0.75 and live:
            slot = rng.choice(list(live))
            grown[slot] = min(live[slot],
                              grown[slot] + rng.randint(0, 16))
            pool.extend(slot, grown[slot])   # never raises: committed
        elif live:
            slot = rng.choice(list(live))
            pool.free(slot)
            del live[slot], grown[slot]
        _check_invariants(pool)
    # drain and verify everything comes back
    for slot in list(live):
        pool.free(slot)
    _check_invariants(pool)
    assert pool.n_free_pages == pool.n_usable_pages
    assert pool.n_free == pool.n_slots


def test_exhaustion_rejects_admission_instead_of_corrupting():
    pool = _pool(n_slots=4, max_seq=64, page_size=8, n_pages=9)  # 8 usable
    a = pool.alloc(40)                    # 5 pages
    assert a is not None
    b = pool.alloc(32)                    # 4 pages: 5 + 4 > 8 -> reject
    assert b is None
    _check_invariants(pool)
    c = pool.alloc(24)                    # 3 pages: exactly fits
    assert c is not None
    assert pool.alloc(8) is None          # committed full
    pool.free(a)
    assert pool.alloc(41) is None         # 6 pages > the 5 uncommitted
    d = pool.alloc(40)                    # 5 pages fit again
    assert d is not None
    _check_invariants(pool)


def test_slot_exhaustion_still_bounded_by_slots():
    pool = _pool(n_slots=2, max_seq=64, page_size=8)
    assert pool.alloc(8) is not None
    assert pool.alloc(8) is not None
    assert pool.alloc(8) is None          # no slot, plenty of pages


def test_extend_clamps_to_commitment_and_free_returns_pages():
    pool = _pool(n_slots=2, max_seq=64, page_size=8, n_pages=17)
    slot = pool.alloc(17)                 # commit 3 pages
    pool.extend(slot, 64)                 # asks for 8, clamped to 3
    assert len(pool._pages_of[slot]) == 3
    before = pool.n_free_pages
    pool.free(slot)
    assert pool.n_free_pages == before + 3
    with pytest.raises(ValueError):
        pool.free(slot)                   # double free


def test_extend_on_zero_commitment_slot_is_loud():
    """alloc() without need_tokens commits no pages; extending such a
    slot must raise instead of silently routing writes to the trash
    page."""
    pool = _pool(n_slots=2)
    slot = pool.alloc()                   # inherited no-need signature
    with pytest.raises(ValueError, match="commitment"):
        pool.extend(slot, 8)
    pool.extend(slot, 0)                  # zero-length extend is fine


def test_double_free_and_bad_slot_rejected():
    pool = _pool(n_slots=3)
    slot = pool.alloc(8)
    pool.free(slot)
    with pytest.raises(ValueError):
        pool.free(slot)
    with pytest.raises(ValueError):
        pool.free(99)
    with pytest.raises(ValueError):
        pool.extend(slot, 8)              # extend on a free slot


def test_reset_reclaims_everything():
    pool = _pool(n_slots=4, max_seq=64, page_size=8)
    for _ in range(3):
        s = pool.alloc(30)
        pool.extend(s, 30)
    assert pool.pages_in_use > 0
    peak = pool.peak_pages_in_use
    assert peak > 0
    pool.reset()
    _check_invariants(pool)
    assert pool.n_free == pool.n_slots
    assert pool.n_free_pages == pool.n_usable_pages
    assert pool.pages_in_use == 0 and pool.peak_pages_in_use == 0
    assert (pool.block_tables == TRASH_PAGE).all()


def test_worst_case_default_sizing_matches_contiguous_capacity():
    pool = _pool(n_slots=4, max_seq=64, page_size=8)
    assert pool.n_usable_pages == 4 * (64 // 8)
    # every slot can commit its full lane simultaneously
    slots = [pool.alloc(64) for _ in range(4)]
    assert None not in slots
    for s in slots:
        pool.extend(s, 64)
    _check_invariants(pool)
    assert pool.n_free_pages == 0


def test_rejects_unsupported_models_and_bad_geometry():
    mamba = get_arch("mamba2-780m").make_smoke()
    with pytest.raises(ValueError, match="paged"):
        PagedCachePool(mamba, 2, 32, page_size=8)
    model = get_arch("qwen3-1.7b").make_smoke()
    with pytest.raises(ValueError, match="multiple"):
        PagedCachePool(model, 2, 30, page_size=8)


def test_contiguous_free_bitmask_still_detects_double_free():
    model = get_arch("qwen3-1.7b").make_smoke()
    pool = CachePool(model, n_slots=3, max_seq=16)
    slots = [pool.alloc() for _ in range(3)]
    assert sorted(slots) == [0, 1, 2] and pool.alloc() is None
    pool.free(slots[1])
    assert pool.n_free == 1 and pool.alloc() == slots[1]
    with pytest.raises(ValueError):
        pool.free(99)
    pool.free(0)
    with pytest.raises(ValueError):
        pool.free(0)


def test_peak_pages_tracks_high_water():
    pool = _pool(n_slots=4, max_seq=64, page_size=8)
    a = pool.alloc(32); pool.extend(a, 32)      # 4 pages
    b = pool.alloc(16); pool.extend(b, 16)      # +2 = 6
    pool.free(a)
    c = pool.alloc(8); pool.extend(c, 8)        # 2 + 1 = 3 in use
    assert pool.pages_in_use == 3
    assert pool.peak_pages_in_use == 6
    assert pool.peak_kv_bytes() < pool.kv_bytes()
