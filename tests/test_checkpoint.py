"""Checkpoint manager: roundtrip, keep-k, atomicity, elastic reshard."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, reshard_workers


def _state(key, w=4):
    return {
        "params": {"a": jax.random.normal(key, (w, 3, 5)),
                   "b": jax.random.normal(key, (w, 7))},
        "step": jnp.asarray(13, jnp.int32),
    }


def test_roundtrip(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=2)
    s = _state(jax.random.PRNGKey(0))
    ck.save(10, s, meta={"x": 1}, block=True)
    step, got, meta = ck.restore(s)
    assert step == 10 and meta == {"x": 1}
    for a, b in zip(jax.tree_util.tree_leaves(s),
                    jax.tree_util.tree_leaves(got), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=2)
    s = _state(jax.random.PRNGKey(1))
    for step in (1, 2, 3, 4):
        ck.save(step, s, block=True)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]
    assert ck.latest_step() == 4


def test_no_tmp_left_behind(tmp_path):
    ck = CheckpointManager(str(tmp_path))
    ck.save(5, _state(jax.random.PRNGKey(2)), block=True)
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_async_save_then_wait(tmp_path):
    ck = CheckpointManager(str(tmp_path), async_save=True)
    ck.save(7, _state(jax.random.PRNGKey(3)))
    ck.wait()
    assert ck.latest_step() == 7


def test_restore_missing(tmp_path):
    ck = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore({"a": jnp.zeros(1)})


def test_reshard_workers_mean_property():
    s = _state(jax.random.PRNGKey(4), w=4)
    out = reshard_workers(s["params"], 6)
    for k in ("a", "b"):
        assert out[k].shape[0] == 6
        # every new replica equals the old mean
        want = np.asarray(s["params"][k]).mean(0)
        for i in range(6):
            np.testing.assert_allclose(np.asarray(out[k][i]), want,
                                       rtol=1e-6)
