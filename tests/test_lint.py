"""repro.lint: each rule fires on a seeded violation and stays silent on
the nearest legitimate idiom; pragmas, baseline round-trip, JSON schema,
CLI exit codes; and the tree itself lints clean."""

from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.lint import ERROR, WARNING, all_rules, hot_path, lint_paths
from repro.lint import baseline as baseline_io
from repro.lint.__main__ import main as lint_main
from repro.lint.engine import lint_text

REPO = Path(__file__).resolve().parent.parent


def rules_of(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------- HOST-SYNC

HOT_PREAMBLE = """
import jax
import numpy as np
from repro.lint import hot_path
"""


def test_host_sync_flags_float_of_loss_in_period_loop():
    # the acceptance scenario: float(loss) injected into the period loop
    src = HOT_PREAMBLE + """
class Runner:
    @hot_path
    def run_period(self, steps):
        state = self.state
        for r in range(steps):
            state, metrics = self.step_fn(state, self.data.batch(r))
            self.history.append(float(metrics["loss"]))
        return state
"""
    findings = lint_text(src, "runner.py")
    assert rules_of(findings) == ["HOST-SYNC"]
    assert findings[0].severity == ERROR
    assert "float" in findings[0].message


def test_host_sync_flags_np_asarray_and_item():
    src = HOT_PREAMBLE + """
@hot_path
def drain(metrics):
    a = np.asarray(metrics["loss"])
    b = metrics["grad_norm"].item()
    return a, b
"""
    assert sorted(rules_of(lint_text(src, "m.py"))) == \
        ["HOST-SYNC", "HOST-SYNC"]


def test_host_sync_silent_on_explicit_batched_device_get():
    # near miss: same drain, but through the blessed explicit sync
    src = HOT_PREAMBLE + """
class Runner:
    @hot_path
    def run_period(self, steps):
        state = self.state
        for r in range(steps):
            state, metrics = self.step_fn(state, self.data.batch(r))
        host = jax.device_get(metrics)
        self.history.append({k: float(v) for k, v in host.items()})
        return state
"""
    assert lint_text(src, "runner.py") == []


def test_host_sync_ignores_cold_functions():
    src = HOT_PREAMBLE + """
def summarize(metrics):
    return float(np.asarray(metrics["loss"]))
"""
    assert lint_text(src, "m.py") == []


def test_host_sync_print_of_device_value_warns():
    src = HOT_PREAMBLE + """
@hot_path
def tick(state):
    out = jax.numpy.sum(state)
    print(out)
    print("static label")
    return out
"""
    findings = lint_text(src, "m.py")
    assert rules_of(findings) == ["HOST-SYNC"]
    assert findings[0].severity == WARNING


# ---------------------------------------------------------------- RECOMPILE

def test_recompile_flags_jit_in_decode_tick():
    # the acceptance scenario: jax.jit inside the per-request/tick body
    src = """
import jax

class Engine:
    def step(self, reqs):
        for req in reqs:
            fn = jax.jit(self.decode_fn)
            out = fn(self.state, req)
        return out
"""
    findings = lint_text(src, "engine.py")
    assert rules_of(findings) == ["RECOMPILE"]
    assert findings[0].severity == ERROR


def test_recompile_silent_on_jit_at_init():
    src = """
import jax

class Engine:
    def __init__(self, decode_fn):
        self.decode = jax.jit(decode_fn, donate_argnums=(0,))

    def step(self, reqs):
        for req in reqs:
            out = self.decode(self.state, req)
        return out
"""
    assert lint_text(src, "engine.py") == []


def test_recompile_warns_on_traced_branch():
    src = """
import jax

@jax.jit
def f(x, lo):
    if x > lo:
        return x
    return -x
"""
    findings = lint_text(src, "m.py")
    assert rules_of(findings) == ["RECOMPILE"]
    assert findings[0].severity == WARNING


def test_recompile_silent_on_static_branches():
    # shape reads, `is None`, and static_argnames params are not traced
    src = """
import functools
import jax

@functools.partial(jax.jit, static_argnames=("mode",))
def f(x, mask, mode):
    if mode == "train":
        x = x * 2
    if mask is not None:
        x = x + mask
    if x.ndim == 2:
        x = x[None]
    return x
"""
    assert lint_text(src, "m.py") == []


def test_recompile_flags_unhashable_static_arg():
    src = """
import jax

def build(f):
    g = jax.jit(f, static_argnums=(1,))
    return g(x, [1, 2, 3])
"""
    assert rules_of(lint_text(src, "m.py")) == ["RECOMPILE"]


# ------------------------------------------------------------------- DONATE

def test_donate_flags_use_after_donate():
    # the acceptance scenario: donated buffer read after the call
    src = """
import jax

def train(step, state, batches):
    g = jax.jit(step, donate_argnums=(0,))
    new_state, metrics = g(state, batches[0])
    return state.params, metrics
"""
    findings = lint_text(src, "m.py")
    assert rules_of(findings) == ["DONATE"]
    assert "state" in findings[0].message


def test_donate_silent_on_rebind_idiom():
    src = """
import jax

def train(step, state, batches):
    g = jax.jit(step, donate_argnums=(0,))
    for b in batches:
        state, metrics = g(state, b)
    return state, metrics
"""
    assert lint_text(src, "m.py") == []


def test_donate_flags_re_donation_in_loop():
    # donated once, then donated again without rebinding
    src = """
import jax

def train(step, state, batches):
    g = jax.jit(step, donate_argnums=(0,))
    outs = []
    for b in batches:
        outs.append(g(state, b))
    return outs
"""
    assert "DONATE" in rules_of(lint_text(src, "m.py"))


# ---------------------------------------------------------------- KEY-REUSE

def test_key_reuse_flags_reused_key():
    # the acceptance scenario: the same PRNG key consumed twice
    src = """
import jax

def init(seed):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (4, 4))
    b = jax.random.normal(key, (4,))
    return w, b
"""
    findings = lint_text(src, "m.py")
    assert rules_of(findings) == ["KEY-REUSE"]
    assert "key" in findings[0].message


def test_key_reuse_silent_on_split():
    src = """
import jax

def init(seed):
    key = jax.random.PRNGKey(seed)
    kw, kb = jax.random.split(key)
    w = jax.random.normal(kw, (4, 4))
    b = jax.random.normal(kb, (4,))
    return w, b
"""
    assert lint_text(src, "m.py") == []


def test_key_reuse_flags_key_param_in_loop():
    src = """
import jax

def rollout(key, n):
    outs = []
    for i in range(n):
        outs.append(jax.random.normal(key, (4,)))
    return outs
"""
    assert rules_of(lint_text(src, "m.py")) == ["KEY-REUSE"]


def test_key_reuse_silent_on_per_iteration_split():
    src = """
import jax

def rollout(key, n):
    outs = []
    for i in range(n):
        key, sub = jax.random.split(key)
        outs.append(jax.random.normal(sub, (4,)))
    return outs
"""
    assert lint_text(src, "m.py") == []


def test_key_reuse_tracks_split_subscripts():
    src = """
import jax

def f(seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.random.normal(keys[0], (2,))
    b = jax.random.normal(keys[0], (2,))
    return a, b
"""
    assert rules_of(lint_text(src, "m.py")) == ["KEY-REUSE"]


# ------------------------------------------------------------------- PALLAS

PALLAS_PREAMBLE = """
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
"""


def test_pallas_flags_index_map_arity():
    src = PALLAS_PREAMBLE + """
def kern(x_ref, o_ref):
    o_ref[...] = x_ref[...]

def run(x):
    return pl.pallas_call(
        kern,
        grid=(4, 2),
        in_specs=[pl.BlockSpec((128, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((128, 128), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((512, 256), jnp.float32),
    )(x)
"""
    findings = lint_text(src, "src/repro/kernels/k/kernel.py")
    assert rules_of(findings) == ["PALLAS"]
    assert "rank 2" in findings[0].message


def test_pallas_counts_scalar_prefetch_in_arity():
    # index maps under PrefetchScalarGridSpec(num_scalar_prefetch=k)
    # take k extra leading scalar-ref arguments
    src = PALLAS_PREAMBLE + """
def kern(s_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]

def run(x, s):
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(4,),
            in_specs=[pl.BlockSpec((128,), lambda s0, i: (i,))],
            out_specs=pl.BlockSpec((128,), lambda s0, i: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((512,), jnp.float32),
    )(s, x)
"""
    assert lint_text(src, "src/repro/kernels/k/kernel.py") == []


def test_pallas_flags_python_branch_on_program_id():
    src = PALLAS_PREAMBLE + """
def kern(x_ref, o_ref):
    i = pl.program_id(0)
    if i == 0:
        o_ref[...] = x_ref[...]
"""
    findings = lint_text(src, "src/repro/kernels/k/kernel.py")
    assert rules_of(findings) == ["PALLAS"]
    assert "pl.when" in findings[0].message


def test_pallas_silent_on_pl_when():
    src = PALLAS_PREAMBLE + """
def kern(x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _first():
        o_ref[...] = x_ref[...]
"""
    assert lint_text(src, "src/repro/kernels/k/kernel.py") == []


def test_pallas_warns_on_dtype_mismatch():
    src = PALLAS_PREAMBLE + """
def kern(x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(jnp.float16)

def run(x):
    return pl.pallas_call(
        kern,
        grid=(4,),
        in_specs=[pl.BlockSpec((128,), lambda i: (i,))],
        out_specs=pl.BlockSpec((128,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((512,), jnp.float32),
    )(x)
"""
    findings = lint_text(src, "src/repro/kernels/k/kernel.py")
    assert rules_of(findings) == ["PALLAS"]
    assert findings[0].severity == WARNING


# ---------------------------------------------------------- SIM-DETERMINISM

def test_sim_determinism_flags_wallclock_and_set_iteration():
    src = """
import time

class Sim:
    def run(self, pending: set):
        t0 = time.time()
        out = []
        for ev in pending:
            out.append(ev)
        return out, t0
"""
    findings = lint_text(src, "src/repro/sim/executor.py")
    assert sorted(rules_of(findings)) == \
        ["SIM-DETERMINISM", "SIM-DETERMINISM"]


def test_sim_determinism_silent_on_sorted_and_seeded_rng():
    src = """
import random

class Sim:
    def run(self, pending: set, seed: int):
        rng = random.Random(seed)
        out = [rng.random() for _ in sorted(pending)]
        return out, len(pending)
"""
    assert lint_text(src, "src/repro/sim/executor.py") == []


def test_sim_determinism_scoped_to_sim_modules():
    # the same hazards outside sim/ and core/schedule.py don't apply
    src = """
import time

def f(pending: set):
    t = time.time()
    return [e for e in pending], t
"""
    assert lint_text(src, "src/repro/serve/engine.py") == []


# ------------------------------------------------------- pragmas / baseline

def test_pragma_suppresses_named_rule():
    src = HOT_PREAMBLE + """
@hot_path
def tick(x):
    v = x.item()  # repro-lint: disable=HOST-SYNC -- measured on purpose
    return v
"""
    assert lint_text(src, "m.py") == []


def test_pragma_standalone_comment_covers_next_statement():
    src = HOT_PREAMBLE + """
@hot_path
def tick(x):
    # repro-lint: disable=HOST-SYNC -- this sync IS the
    # measurement boundary (two-line justification)
    v = x.item()
    return v
"""
    assert lint_text(src, "m.py") == []


def test_pragma_other_rule_does_not_suppress():
    src = HOT_PREAMBLE + """
@hot_path
def tick(x):
    v = x.item()  # repro-lint: disable=RECOMPILE
    return v
"""
    assert rules_of(lint_text(src, "m.py")) == ["HOST-SYNC"]


def test_baseline_round_trip(tmp_path):
    src = HOT_PREAMBLE + """
@hot_path
def tick(x):
    return x.item()
"""
    findings = lint_text(src, "m.py")
    assert len(findings) == 1
    path = tmp_path / "baseline.json"
    baseline_io.save(path, findings)
    grandfathered = baseline_io.load(path)
    new, old = baseline_io.partition(findings, grandfathered)
    assert new == [] and len(old) == 1
    # a second, identical-looking occurrence is NOT absorbed: the
    # baseline matches by count
    new2, old2 = baseline_io.partition(findings * 2, grandfathered)
    assert len(new2) == 1 and len(old2) == 1


def test_baseline_missing_file_gates_everything(tmp_path):
    assert baseline_io.load(tmp_path / "absent.json") == {}


def test_baseline_version_mismatch_raises(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"version": 99, "findings": []}))
    with pytest.raises(ValueError):
        baseline_io.load(p)


def test_fingerprint_stable_under_line_churn():
    src_a = HOT_PREAMBLE + """
@hot_path
def tick(x):
    return x.item()
"""
    src_b = HOT_PREAMBLE + "\n\n\n" + """
@hot_path
def tick(x):
    return   x.item()
"""
    fa = lint_text(src_a, "m.py")[0]
    fb = lint_text(src_b, "m.py")[0]
    assert fa.line != fb.line
    assert fa.fingerprint() == fb.fingerprint()


# ------------------------------------------------------------ CLI / output

def test_cli_exit_codes_and_json_schema(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(HOT_PREAMBLE + """
@hot_path
def tick(x):
    return x.item()
""")
    rc = lint_main([str(bad), "--format", "json"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["version"] == 1
    assert payload["summary"]["errors"] == 1
    (f,) = payload["findings"]
    assert set(f) >= {"rule", "severity", "path", "line", "col",
                      "message", "context", "fingerprint"}
    assert f["rule"] == "HOST-SYNC" and f["context"] == "tick"

    # baselining the finding turns the run green
    rc = lint_main([str(bad), "--baseline", str(tmp_path / "b.json"),
                    "--write-baseline"])
    assert rc == 0
    capsys.readouterr()
    rc = lint_main([str(bad), "--baseline", str(tmp_path / "b.json")])
    assert rc == 0
    assert "baselined" in capsys.readouterr().out


def test_cli_warning_only_exits_zero_unless_strict(tmp_path, capsys):
    warn = tmp_path / "warn.py"
    warn.write_text(HOT_PREAMBLE + """
@hot_path
def tick(state):
    out = jax.numpy.sum(state)
    print(out)
    return out
""")
    assert lint_main([str(warn)]) == 0
    capsys.readouterr()
    assert lint_main([str(warn), "--strict"]) == 1
    capsys.readouterr()


def test_cli_select_and_ignore(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(HOT_PREAMBLE + """
@hot_path
def tick(x):
    return x.item()
""")
    assert lint_main([str(bad), "--select", "RECOMPILE"]) == 0
    capsys.readouterr()
    assert lint_main([str(bad), "--ignore", "HOST-SYNC"]) == 0
    capsys.readouterr()


def test_cli_syntax_error_reports_parse_finding(tmp_path, capsys):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    assert lint_main([str(bad)]) == 1
    assert "PARSE" in capsys.readouterr().out


def test_module_entrypoint_runs_clean_on_tree():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.lint", "src/repro",
         "--baseline", ".repro-lint-baseline.json"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ------------------------------------------------------------- self checks

def test_registry_has_all_rule_families():
    names = {r.name for r in all_rules().values()}
    assert names >= {"HOST-SYNC", "RECOMPILE", "DONATE", "KEY-REUSE",
                     "PALLAS", "SIM-DETERMINISM"}


def test_hot_path_decorator_is_passthrough():
    @hot_path
    def f(x):
        return x + 1

    assert f(1) == 2
    assert f.__repro_hot_path__ is True
    assert f.__name__ == "f"


def test_repo_tree_lints_clean():
    findings = lint_paths([REPO / "src" / "repro"])
    assert [f.render() for f in findings
            if f.severity == ERROR] == []
