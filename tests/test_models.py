"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness asserts; decode-vs-full-forward equivalence per family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models.layers import count_params

ALL_ARCHS = sorted(ARCHS)


def _smoke_batch(arch, model, key, b=2, s=16):
    cfg = model.cfg
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if arch.frontend == "audio":
        batch["frames"] = jax.random.normal(key, (b, cfg.n_frames,
                                                  cfg.d_model))
    if arch.frontend == "vision":
        batch["embeds"] = jax.random.normal(key, (b, 4, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch_id):
    arch = get_arch(arch_id)
    model = arch.make_smoke()
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    assert count_params(params) == model.param_count()

    batch = _smoke_batch(arch, model, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert jnp.isfinite(loss), arch_id
    gleaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in gleaves), arch_id
    # one SGD step moves the loss
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
    loss2 = model.loss(params2, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_smoke_unit_layout_matches_costs(arch_id):
    arch = get_arch(arch_id)
    for model in (arch.make_smoke(), arch.make_model()):
        layout = model.unit_layout()
        costs = model.layer_costs(2, 64)
        assert len(layout) == len(costs)
        assert [c[0] for c in costs] == list(layout.names)
        layout.validate_against(
            jax.eval_shape(model.init, jax.random.PRNGKey(0)),
            worker_stacked=False)


@pytest.mark.parametrize("arch_id", ["granite-3-2b", "qwen3-moe-30b-a3b",
                                     "deepseek-v3-671b", "mamba2-780m",
                                     "recurrentgemma-9b", "whisper-medium"])
def test_smoke_decode_matches_full_forward(arch_id):
    arch = get_arch(arch_id)
    model = arch.make_smoke()
    if getattr(model.cfg, "moe", None) is not None:
        # capacity dropping is order-dependent (full-seq prefill may drop
        # what one-token decode never does); compare with dropless capacity
        import dataclasses
        moe = dataclasses.replace(model.cfg.moe,
                                  capacity_factor=float(
                                      model.cfg.moe.n_experts))
        model = type(model)(dataclasses.replace(model.cfg, moe=moe))
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              model.cfg.vocab)
    cache = model.init_cache(b, s + 4)
    if arch.frontend == "audio":
        frames = jax.random.normal(key, (b, model.cfg.n_frames,
                                         model.cfg.d_model))
        lg, cache = model.prefill(params, toks, cache, frames)
        full = model.apply(params, toks, frames)
    else:
        lg, cache = model.prefill(params, toks, cache)
        full = model.apply(params, toks)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-3,
                               atol=2e-3)
    nxt = jnp.argmax(lg, -1).astype(jnp.int32)
    lg2, cache = model.decode_step(params, cache, nxt,
                                   jnp.full((b,), s, jnp.int32))
    toks2 = jnp.concatenate([toks, nxt], 1)
    if arch.frontend == "audio":
        full2 = model.apply(params, toks2, frames)
    else:
        full2 = model.apply(params, toks2)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]),
                               np.asarray(full2[:, -1]), rtol=5e-3,
                               atol=5e-3)


def test_full_config_param_counts():
    """Published sizes (the config-fidelity check)."""
    expect = {
        "granite-3-2b": (2.3e9, 2.8e9),
        "phi4-mini-3.8b": (3.5e9, 4.2e9),
        "qwen2.5-32b": (31e9, 34e9),
        "qwen3-1.7b": (1.6e9, 2.1e9),
        "llava-next-34b": (33e9, 36e9),
        "mamba2-780m": (0.7e9, 0.85e9),
        "recurrentgemma-9b": (8.0e9, 9.5e9),
        "qwen3-moe-30b-a3b": (29e9, 32e9),
        "deepseek-v3-671b": (650e9, 700e9),
        "whisper-medium": (0.7e9, 0.85e9),
    }
    for aid, (lo, hi) in expect.items():
        n = get_arch(aid).make_model().param_count()
        assert lo <= n <= hi, (aid, n)
    # MoE active counts
    assert 3.0e9 <= get_arch("qwen3-moe-30b-a3b").make_model() \
        .active_param_count() <= 3.7e9
    assert 34e9 <= get_arch("deepseek-v3-671b").make_model() \
        .active_param_count() <= 40e9


def test_segment_cuts_preserve_function():
    arch = get_arch("granite-3-2b")
    model = arch.make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              model.cfg.vocab)
    a = model.apply(params, toks)
    b = model.apply(params, toks, segment_cuts=(2, 3))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
