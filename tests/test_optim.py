"""Optimizer correctness: descent on a quadratic, state footprints."""

import jax
import jax.numpy as jnp
import pytest

from repro.optim import make_optimizer


def _quad_loss(p):
    return sum(jnp.sum((x - 0.5) ** 2)
               for x in jax.tree_util.tree_leaves(p))


@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "adamw",
                                  "adafactor"])
def test_descends_quadratic(name):
    opt = make_optimizer(name, lr=5e-2, warmup_steps=1, decay_steps=1000,
                         grad_clip=0.0)
    params = {"w": jnp.ones((16, 16)), "b": jnp.ones((16,))}
    state = opt.init(params)
    l0 = float(_quad_loss(params))
    for t in range(50):
        g = jax.grad(_quad_loss)(params)
        params, state = opt.update(g, state, params, jnp.asarray(t))
    assert float(_quad_loss(params)) < 0.2 * l0


def test_adafactor_state_is_factored():
    opt_a = make_optimizer("adam")
    opt_f = make_optimizer("adafactor", beta1=0.0)
    params = {"w": jnp.ones((256, 512))}
    na = sum(x.size for x in jax.tree_util.tree_leaves(opt_a.init(params)))
    nf = sum(x.size for x in jax.tree_util.tree_leaves(opt_f.init(params)))
    assert nf < na / 100          # (256+512) vs 2*256*512


def test_grad_clip_bounds_update():
    opt = make_optimizer("sgd", lr=1.0, grad_clip=1.0, warmup_steps=1)
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    g = {"w": jnp.full((4,), 100.0)}
    new, _ = opt.update(g, state, params, jnp.asarray(5))
    assert float(jnp.linalg.norm(new["w"])) <= 1.0 + 1e-5


def test_worker_stacked_update_is_per_worker():
    """No cross-worker mixing inside the optimizer (LSGD local step)."""
    opt = make_optimizer("adam", lr=1e-2, grad_clip=0.0)
    params = {"w": jnp.zeros((3, 4))}            # 3 workers
    state = opt.init(params)
    g = {"w": jnp.stack([jnp.ones(4), jnp.zeros(4), -jnp.ones(4)])}
    new, _ = opt.update(g, state, params, jnp.asarray(0))
    assert float(jnp.abs(new["w"][1]).max()) == 0.0
    assert float(new["w"][0].max()) < 0.0
    assert float(new["w"][2].min()) > 0.0
