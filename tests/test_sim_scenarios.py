"""Scenario library: conformance sweep + per-scenario behaviour."""

import pytest

from repro.core.plans import build_plan
from repro.sim import (SimExecutor, available_scenarios, check_scenario,
                       get_scenario, synthetic_profile)

pytestmark = pytest.mark.sim

LIBRARY = available_scenarios()


# ---------------------------------------------------- conformance sweep

@pytest.mark.parametrize("name", LIBRARY)
@pytest.mark.parametrize("algo", ["dreamddp", "plsgd-enp", "flsgd"])
def test_library_conformance(name, algo):
    """Acceptance criterion: every scenario's simulated period time
    agrees with time_model.simulate_period on every static window."""
    report = check_scenario(get_scenario(name), algo=algo, H=4)
    assert report.checks, f"{name}: no static windows were checkable"
    assert report.ok, report.summary()
    assert report.max_rel_err < 1e-9                  # stated tol is 1e-6


@pytest.mark.parametrize("name", LIBRARY)
def test_library_determinism(name):
    """Acceptance criterion: identical seeds -> byte-identical traces."""
    fps = [check_scenario(get_scenario(name), algo="dreamddp",
                          H=4).trace.fingerprint() for _ in range(2)]
    assert fps[0] == fps[1]


@pytest.mark.parametrize("name", LIBRARY)
def test_library_runs_under_hier_strategy(name):
    """Beyond-partition plans (hot/cold tiers) replay fine too."""
    report = check_scenario(get_scenario(name), algo="hier-2tier", H=4)
    assert report.ok, report.summary()


def test_conformance_mid_period_failure_not_misattributed():
    """An iteration-scheduled (non-boundary) TransientFailure makes its
    own period non-static but must NOT leak its stall into the next
    static period's expected time."""
    from repro.sim import Scenario, TransientFailure
    sc = Scenario(name="mid-failure", description="", n_workers=8,
                  events=(TransientFailure(iteration=6, worker=0,
                                           downtime=0.05),),
                  periods=3)
    report = check_scenario(sc, algo="dreamddp", H=4)
    assert report.skipped_periods == [1]
    assert [c.period for c in report.checks] == [0, 2]
    assert report.ok, report.summary()


@pytest.mark.parametrize("name", ["straggler", "drifting-bandwidth"])
def test_conformance_when_strategy_forces_h1(name):
    """Gradient-sync strategies force plan.H=1; the reference replay must
    convert event periods with the plan's H, not the requested one."""
    report = check_scenario(get_scenario(name), algo="ssgd", H=4)
    assert report.H == 1
    assert report.checks
    assert report.ok, report.summary()


# ----------------------------------------------------- scenario behaviour

def _simulate(name, algo="dreamddp", H=4):
    sc = get_scenario(name)
    prof = synthetic_profile()
    cluster = sc.build(H)
    plan = build_plan(algo, cluster.effective_profile(prof, 0.0), H)
    ex = SimExecutor(prof, plan, cluster)
    return ex.run(sc.periods), plan


def test_straggler_slows_only_its_period():
    tr, _ = _simulate("straggler")
    p0, p1, p2 = tr.period_times()
    assert p1 > p0 * 1.2                 # 2.5x compute on the critical path
    assert p2 == pytest.approx(p0, rel=1e-9)   # recovers fully


def test_drift_slows_following_periods():
    tr, _ = _simulate("drifting-bandwidth")
    p0, p1, p2 = tr.period_times()
    assert p1 > p0                       # 1 GB/s -> 150 MB/s
    assert p2 == pytest.approx(p1, rel=1e-9)   # drift is permanent
    assert any(e["kind"] == "BandwidthDrift" for e in tr.events)


def test_churn_changes_ring_and_recovers():
    tr, _ = _simulate("churn")
    p0, p1, p2 = tr.period_times()
    # 6-worker ring ships less redundant data than 8-worker ring
    assert p1 < p0
    assert p2 == pytest.approx(p0, rel=1e-9)   # back to 8 workers
    kinds = [e["kind"] for e in tr.events]
    assert kinds == ["WorkerLeave", "WorkerJoin"]


def test_transient_failure_stalls_one_iteration():
    tr, _ = _simulate("transient-failure")
    stalls = tr.of_kind("stall")
    assert len(stalls) == 1
    assert stalls[0].iteration == 4      # first iteration of period 1
    assert stalls[0].duration == pytest.approx(0.05)
    p0, p1, p2 = tr.period_times()
    assert p1 == pytest.approx(p0 + 0.05, rel=1e-9)
    assert p2 == pytest.approx(p0, rel=1e-9)


def test_degraded_inter_window_recovers():
    tr, _ = _simulate("degraded-inter")
    p0, p1, p2 = tr.period_times()
    assert p1 > p0
    assert p2 == pytest.approx(p0, rel=1e-9)


def test_hier_2tier_charges_both_links():
    tr, plan = _simulate("hier-2tier")
    # every synchronized unit pays at least the inter-DC latency (5 ms)
    comms = tr.of_kind("comm")
    assert comms and all(iv.duration >= 5e-3 for iv in comms)


def test_unknown_scenario_raises():
    with pytest.raises(KeyError):
        get_scenario("no-such-scenario")
