"""System-level behaviour: outer optimizer, CLI drivers, serving path."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.outer_opt import OuterConfig, outer_init, outer_sync_units
from repro.core.partial_sync import UnitEntry, UnitLayout


def test_outer_sync_moves_toward_worker_mean():
    layout = UnitLayout((UnitEntry("u0", "g", None),))
    w = 4
    params = {"g": {"w": jnp.stack([jnp.full((3,), float(i))
                                    for i in range(w)])}}
    state = outer_init(params)
    new_p, new_state = outer_sync_units(
        params, state, [0], layout, OuterConfig(lr=1.0, beta=0.0,
                                                nesterov=False))
    # pseudo-grad = outer(0-init? no: outer starts at params) ...
    # outer starts equal to the stacked params; with lr=1 the outer moves
    # exactly onto the worker mean
    mean = np.asarray(params["g"]["w"]).mean(0)
    for i in range(w):
        np.testing.assert_allclose(np.asarray(new_p["g"]["w"][i]), mean,
                                   rtol=1e-6)
    # all replicas reset to the same value (a synchronization point)
    assert float(jnp.abs(new_p["g"]["w"] - new_p["g"]["w"][:1]).max()) == 0


def test_outer_sync_untouched_units():
    layout = UnitLayout((UnitEntry("u0", "a", None),
                         UnitEntry("u1", "b", None)))
    params = {"a": {"w": jnp.ones((2, 3))},
              "b": {"w": jnp.arange(6.0).reshape(2, 3)}}
    state = outer_init(params)
    new_p, _ = outer_sync_units(params, state, [0], layout)
    np.testing.assert_array_equal(np.asarray(new_p["b"]["w"]),
                                  np.asarray(params["b"]["w"]))


def test_train_cli_runs():
    from repro.launch.train import main
    rc = main(["--arch", "qwen3-1.7b", "--smoke", "--steps", "6",
               "--workers", "2", "--batch-per-worker", "2", "--seq", "32",
               "--period", "3"])
    assert rc == 0


def test_train_cli_async_dry_run(capsys):
    from repro.launch.train import main
    rc = main(["--arch", "qwen3-1.7b", "--smoke", "--async",
               "--workers", "2", "--period", "4", "--steps", "8",
               "--merge-rule", "delayed-nesterov",
               "--staleness-beta", "0.8", "--dry-run"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "exec=async" in out
    assert "rule=delayed-nesterov" in out
    assert "beta=0.8" in out
    assert "dry run" in out


def test_serve_cli_runs():
    from repro.launch.serve import main
    rc = main(["--arch", "granite-3-2b", "--smoke", "--batch", "2",
               "--prompt-len", "8", "--gen", "4"])
    assert rc == 0
