"""Sharding-rule unit tests (no devices needed beyond 1)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import RULES, leaf_spec


class _FakeMesh:
    shape = {"pod": 2, "data": 16, "model": 16}


def test_basic_tp_mapping():
    assert leaf_spec((None, "heads"), worker_axes=("data",)) \
        == P("data", None, "model")
    assert leaf_spec(("ff", None), worker_axes=()) == P(None, "model", None)


def test_moe_dedup_expert_wins():
    sp = leaf_spec(("layers", "expert", None, "ff"), worker_axes=())
    assert sp == P(None, None, "model", None, None)


def test_fsdp_places_data_on_first_free_dim():
    sp = leaf_spec(("layers", None, "heads"), worker_axes=("pod",),
                   fsdp=True)
    assert sp == P("pod", None, "data", "model")


def test_fsdp_skips_when_worker_uses_data():
    sp = leaf_spec((None, "heads"), worker_axes=("pod", "data"), fsdp=True)
    assert sp == P(("pod", "data"), None, "model")


def test_divisibility_fallback():
    # vocab 50280 % 16 != 0 -> replicated (shape has no worker lead here)
    sp = leaf_spec(("vocab", None), worker_axes=(), with_lead=False,
                   shape=(50280, 1536), mesh=_FakeMesh())
    assert sp == P(None, None)
    sp2 = leaf_spec(("vocab", None), worker_axes=(), with_lead=False,
                    shape=(49152, 1536), mesh=_FakeMesh())
    assert sp2 == P("model", None)
    # worker-stacked variant: shape carries the lead dim
    sp3 = leaf_spec(("vocab", None), worker_axes=("data",),
                    shape=(16, 50280, 1536), mesh=_FakeMesh())
    assert sp3 == P("data", None, None)


def test_serving_no_lead():
    sp = leaf_spec((None, "heads"), worker_axes=(), with_lead=False)
    assert sp == P(None, "model")


def test_rules_table_closed():
    assert set(RULES) == {"vocab", "heads", "ff", "expert", "layers", None}
