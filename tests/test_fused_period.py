"""Period-fused runner: equivalence with the per-step oracle + fault
tolerance at period granularity (runtime/DESIGN.md).

The fused pipeline executor re-uses the oracle's traced phase programs,
so its TrainState must be **bitwise identical** — params, opt state, EF
residuals and DiLoCo outer state — across sync policies and period
lengths, including run tails that don't fill a period and a ``replan``
landing mid-period.  The compiled executor (one ``lax.scan`` program
per period) is numerically free to re-round across phase boundaries
(~ULPs); it gets a tight-tolerance parity check plus exact-loss
trajectory at H=1 where the programs coincide.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import HardwareSpec, analytic_profile, build_plan
from repro.data import MarkovCorpus
from repro.models.transformer import DecoderLM, LMConfig
from repro.optim import make_optimizer
from repro.runtime import (PeriodPrefetcher, Runner, RunnerConfig,
                           StepConfig, init_train_state,
                           stack_period_batches)

W = 4


@pytest.fixture(scope="module")
def setup():
    cfg = LMConfig(name="t", n_layers=4, d_model=48, n_heads=4,
                   n_kv_heads=2, d_ff=96, vocab=64, param_dtype="float32",
                   remat=False)
    model = DecoderLM(cfg)
    hw = HardwareSpec(bandwidth=1e9, n_workers=W)
    prof = analytic_profile(model.layer_costs(4, 32), hw)
    opt = make_optimizer("adam", lr=3e-3, warmup_steps=5, decay_steps=400)
    data = MarkovCorpus(vocab=64, seq_len=32, batch_per_worker=4,
                        n_workers=W, seed=0)
    return model, prof, opt, data


def _assert_tree_equal(a, b, what=""):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb)
    for (pa, la), (_, lb) in zip(fa, fb, strict=True):
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb),
            err_msg=f"{what}{jax.tree_util.keystr(pa)}")


def _runner(setup, H, *, scfg=None, fused=False, exec_="pipeline",
            algo="dreamddp", **run_kw):
    model, prof, opt, data = setup
    plan = build_plan(algo, prof, H)
    scfg = scfg or StepConfig()
    run_cfg = RunnerConfig(fused_period=fused, period_exec=exec_,
                           **run_kw)
    return Runner(model, opt, plan, data, step_cfg=scfg,
                  run_cfg=run_cfg), scfg


POLICIES = [
    pytest.param({}, id="plain"),
    pytest.param({"compress": "int8_ef"}, id="int8_ef",
                 marks=pytest.mark.slow),
    pytest.param({"outer": True}, id="outer", marks=pytest.mark.slow),
]


@pytest.mark.parametrize("H", [1, 5])
@pytest.mark.parametrize("policy_kw", POLICIES)
def test_fused_pipeline_bitwise_equals_per_step(setup, H, policy_kw):
    """Params / opt state / EF / outer state bitwise across policies and
    H; n_steps includes a tail that doesn't fill a period."""
    model, prof, opt, data = setup
    scfg = StepConfig(**policy_kw)
    n = 2 * H + 2
    rp, _ = _runner(setup, H, scfg=scfg)
    sp = rp.run(init_train_state(model, opt, jax.random.PRNGKey(0), W,
                                 cfg=scfg), n, fused=False)
    rf, _ = _runner(setup, H, scfg=scfg, fused=True)
    sf = rf.run(init_train_state(model, opt, jax.random.PRNGKey(0), W,
                                 cfg=scfg), n)
    _assert_tree_equal(sp, sf, "state")
    assert [h["loss"] for h in rp.history] == \
        [h["loss"] for h in rf.history]
    assert [h["step"] for h in rf.history] == list(range(n))


@pytest.mark.parametrize("H", [1, pytest.param(5, marks=pytest.mark.slow)])
def test_compiled_period_matches_oracle_to_ulps(setup, H):
    """The one-executable-per-period program re-rounds across phase
    boundaries; it must stay within float32 ULPs of the oracle (and be
    bitwise at H=1, where the programs coincide)."""
    model, prof, opt, data = setup
    scfg = StepConfig()
    n = 2 * H
    rp, _ = _runner(setup, H, scfg=scfg)
    sp = rp.run(init_train_state(model, opt, jax.random.PRNGKey(0), W,
                                 cfg=scfg), n, fused=False)
    rc, _ = _runner(setup, H, scfg=scfg, fused=True, exec_="compiled")
    sc = rc.run(init_train_state(model, opt, jax.random.PRNGKey(0), W,
                                 cfg=scfg), n)
    if H == 1:
        _assert_tree_equal(sp, sc, "state")
    else:
        for a, b in zip(jax.tree_util.tree_leaves(sp),
                        jax.tree_util.tree_leaves(sc), strict=True):
            np.testing.assert_allclose(np.asarray(a, np.float64),
                                       np.asarray(b, np.float64),
                                       rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_replan_mid_period_bitwise(setup):
    """An elastic/bandwidth replan landing mid-period: the fused path
    runs per-step to the boundary, swaps the plan, and must stay bitwise
    with an oracle run doing the same schedule switch."""
    model, prof, opt, data = setup
    H = 4
    plan_a = build_plan("dreamddp", prof, H)
    plan_b = build_plan("dreamddp", prof.with_bandwidth(1e8), H)
    assert plan_a.fingerprint() != plan_b.fingerprint()
    scfg = StepConfig()

    def run_with_switch(fused):
        r = Runner(model, opt, plan_a, data, step_cfg=scfg,
                   run_cfg=RunnerConfig(fused_period=fused))
        s = init_train_state(model, opt, jax.random.PRNGKey(0), W,
                             cfg=scfg)
        s = r.run(s, H + 2, fused=fused)          # ends mid-period
        r.replan(plan_b)
        s = r.run(s, 2 * H, start_step=H + 2, fused=fused)
        return s, r

    sp, rp = run_with_switch(False)
    sf, rf = run_with_switch(True)
    _assert_tree_equal(sp, sf, "state")
    assert len(rf.history) == len(rp.history) == 3 * H + 2


def test_fused_checkpoint_restart(setup, tmp_path):
    """Failure injection at period granularity: restore + replay."""
    model, prof, opt, data = setup
    scfg = StepConfig()
    plan = build_plan("dreamddp", prof, 4)
    state = init_train_state(model, opt, jax.random.PRNGKey(0), W,
                             cfg=scfg)
    ck = CheckpointManager(str(tmp_path))
    r = Runner(model, opt, plan, data, ckpt=ck, step_cfg=scfg,
               run_cfg=RunnerConfig(ckpt_every=8, fused_period=True))
    ck.save(0, state, block=True)
    state = r.run(state, 20, inject_failure_at=11, fused=True)
    assert r.retries == 1
    assert len(r.history) >= 20
    assert int(state.step) == 20


@pytest.mark.slow
def test_fused_checkpoint_restart_equals_uninterrupted(setup, tmp_path):
    """Replay after a mid-run restore converges on the exact same state
    as a run that never failed (same steps replayed, same data)."""
    model, prof, opt, data = setup
    scfg = StepConfig()
    plan = build_plan("dreamddp", prof, 4)

    ck = CheckpointManager(str(tmp_path))
    r1 = Runner(model, opt, plan, data, ckpt=ck, step_cfg=scfg,
                run_cfg=RunnerConfig(ckpt_every=8, fused_period=True))
    s0 = init_train_state(model, opt, jax.random.PRNGKey(0), W, cfg=scfg)
    ck.save(0, s0, block=True)
    s_fail = r1.run(s0, 16, inject_failure_at=10, fused=True)

    r2, _ = _runner(setup, 4, fused=True)
    s_ok = r2.run(init_train_state(model, opt, jax.random.PRNGKey(0), W,
                                   cfg=scfg), 16)
    _assert_tree_equal(s_ok, s_fail, "state")


def test_fused_straggler_requeues_and_makes_up(setup):
    """A blown period re-queues its sync units; the make-up runs at a
    later period boundary and clears the queue — under fused=True."""
    model, prof, opt, data = setup
    plan = build_plan("dreamddp", prof, 4)
    scfg = StepConfig()
    state = init_train_state(model, opt, jax.random.PRNGKey(0), W,
                             cfg=scfg)
    r = Runner(model, opt, plan, data, step_cfg=scfg,
               run_cfg=RunnerConfig(deadline_factor=2.0, min_history=2,
                                    fused_period=True))
    # straggle a step inside period 3 (periods 0-2 build the median)
    r.run(state, 24, inject_straggler_at=(13, 100.0), fused=True)
    assert r.skipped_syncs >= 1
    assert not r.pending_units          # make-up ran at a later boundary
    assert len(r.period_times) == 6


def test_fused_respects_default_and_hook_fallback(setup):
    """fused=None follows RunnerConfig.fused_period but drops to the
    per-step oracle when an injection hook is supplied."""
    model, prof, opt, data = setup
    scfg = StepConfig()
    r, _ = _runner(setup, 2, scfg=scfg, fused=True)
    state = init_train_state(model, opt, jax.random.PRNGKey(0), W,
                             cfg=scfg)
    state = r.run(state, 4)
    assert len(r.period_times) == 2     # ran fused
    r.run(state, 4, start_step=4, inject_straggler_at=(100, 0.0))
    assert len(r.period_times) == 2     # hook forced the per-step oracle


def test_metrics_drain_cadence(setup):
    """History has one row per step in order under any drain cadence,
    metrics staying device-resident between drains."""
    model, prof, opt, data = setup
    scfg = StepConfig(track_divergence=True)
    r, _ = _runner(setup, 2, scfg=scfg, fused=True, log_every=3)
    state = init_train_state(model, opt, jax.random.PRNGKey(0), W,
                             cfg=scfg)
    r.run(state, 14)
    assert [h["step"] for h in r.history] == list(range(14))
    assert all("loss" in h and "divergence" in h and "time" in h
               for h in r.history)


def test_period_prefetcher_matches_data(setup):
    model, prof, opt, data = setup
    for stacked in (True, False):
        pipe = PeriodPrefetcher(data, 3, stacked=stacked)
        pipe.prefetch(6)
        got = pipe.get(6)               # staged hit
        direct = pipe.get(3)            # cold build
        for start, batch in ((6, got), (3, direct)):
            for h in range(3):
                want = data.batch(start + h)
                have = jax.tree.map(lambda x, hh=h: x[hh], batch) \
                    if stacked else batch[h]
                _assert_tree_equal(want, have, f"period@{start} step {h}")


def test_stack_period_batches_layout(setup):
    model, prof, opt, data = setup
    stacked = stack_period_batches(data, 4, 2)
    assert stacked["tokens"].shape == (2, W, 4, 32)
    _assert_tree_equal(jax.tree.map(lambda x: x[1], stacked),
                       data.batch(5))


def test_run_rejects_unknown_period_exec(setup):
    model, prof, opt, data = setup
    r, scfg = _runner(setup, 2, fused=True)
    r.run_cfg = dataclasses.replace(r.run_cfg, period_exec="bogus")
    state = init_train_state(model, opt, jax.random.PRNGKey(0), W,
                             cfg=scfg)
    with pytest.raises(ValueError, match="period_exec"):
        r.run(state, 2)
