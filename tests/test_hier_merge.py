"""Merge rules + server tier: config validation, mean equivalence,
staleness damping, delayed-Nesterov math, local-server accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partial_sync import UnitEntry, UnitLayout
from repro.hier import (GlobalServer, LocalServer, MergeConfig,
                        staleness_scale)

N_LAYERS = 3
D = 4


def _layout():
    entries = (UnitEntry("emb", "emb", None),) + tuple(
        UnitEntry(f"layer{i}", "layers", i) for i in range(N_LAYERS))
    return UnitLayout(entries)


def _params():
    return {"emb": jnp.zeros((D,), jnp.float32),
            "layers": jnp.zeros((N_LAYERS, D), jnp.float32)}


def _delta(value):
    return {"emb": jnp.full((D,), value, jnp.float32),
            "layers": jnp.full((N_LAYERS, D), value, jnp.float32)}


ALL_UNITS = tuple(range(N_LAYERS + 1))


# ------------------------------------------------------------- MergeConfig

def test_config_rejects_unknown_rule():
    with pytest.raises(ValueError, match="merge rule"):
        MergeConfig(rule="adamw")


@pytest.mark.parametrize("beta", [0.0, -0.1, 1.5])
def test_config_rejects_bad_beta(beta):
    with pytest.raises(ValueError, match="staleness_beta"):
        MergeConfig(staleness_beta=beta)


def test_config_rejects_negative_clamp():
    with pytest.raises(ValueError, match="max_staleness"):
        MergeConfig(max_staleness=-1)


def test_resolve_fills_fleet_defaults():
    cfg = MergeConfig().resolve(8)
    assert cfg.lr == pytest.approx(1.0 / 8)
    assert cfg.dn_delay == 8
    explicit = MergeConfig(lr=0.25, dn_delay=3).resolve(8)
    assert explicit.lr == 0.25 and explicit.dn_delay == 3


def test_staleness_scale_clamps():
    cfg = MergeConfig(staleness_beta=0.5, max_staleness=3)
    assert staleness_scale(cfg, 0) == 1.0
    assert staleness_scale(cfg, 2) == pytest.approx(0.25)
    # beyond the clamp every delta gets the same floor weight
    assert staleness_scale(cfg, 3) == staleness_scale(cfg, 100) \
        == pytest.approx(0.125)


# ------------------------------------------------------------ GlobalServer

def test_halos_round_of_fresh_deltas_is_worker_mean():
    """With momentum off and tau=0 everywhere, one round of W deltas at
    lr=1/W advances the model by exactly the worker-mean delta — the
    async analogue of the synchronous parameter average."""
    W = 4
    server = GlobalServer(_params(), _layout(),
                          MergeConfig(momentum=0.0), n_workers=W)
    deltas = [float(w + 1) for w in range(W)]
    for d in deltas:
        tau = server.merge(_delta(d), server.version, ALL_UNITS)
        assert tau == 0
    want = sum(deltas) / W
    np.testing.assert_allclose(np.asarray(server.params["emb"]), want,
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(server.params["layers"]), want,
                               rtol=1e-6)
    assert server.version == W
    assert server.staleness_hist == {0: W}


def test_halos_staleness_damps_update():
    cfg = MergeConfig(momentum=0.0, lr=1.0, staleness_beta=0.5,
                      max_staleness=8)
    server = GlobalServer(_params(), _layout(), cfg, n_workers=1)
    server.merge(_delta(0.0), 0, ALL_UNITS)     # version -> 1
    server.merge(_delta(0.0), 1, ALL_UNITS)     # version -> 2
    tau = server.merge(_delta(1.0), 0, ALL_UNITS)
    assert tau == 2
    # first two deltas were zero; the stale one lands at beta**2
    np.testing.assert_allclose(np.asarray(server.params["emb"]), 0.25,
                               rtol=1e-6)


def test_merge_touches_only_named_units():
    server = GlobalServer(_params(), _layout(),
                          MergeConfig(momentum=0.0, lr=1.0), n_workers=1)
    server.merge(_delta(1.0), 0, (0, 2))        # emb + layer index 1
    emb = np.asarray(server.params["emb"])
    layers = np.asarray(server.params["layers"])
    np.testing.assert_allclose(emb, 1.0)
    np.testing.assert_allclose(layers[1], 1.0)
    np.testing.assert_allclose(layers[0], 0.0)
    np.testing.assert_allclose(layers[2], 0.0)


def test_delayed_nesterov_immediate_then_flush():
    cfg = MergeConfig(rule="delayed-nesterov", momentum=0.9, lr=1.0,
                      dn_delay=2)
    server = GlobalServer(_params(), _layout(), cfg, n_workers=2)
    server.merge(_delta(1.0), server.version, ALL_UNITS)
    # first merge applies immediately, no momentum yet
    np.testing.assert_allclose(np.asarray(server.params["emb"]), 1.0,
                               rtol=1e-6)
    assert server.dn_count == 1
    server.merge(_delta(3.0), server.version, ALL_UNITS)
    # second merge triggers the flush: m = 0.9*0 + (1+3)/2 = 2,
    # w = (1 + 3) + lr * 0.9 * m = 4 + 1.8
    np.testing.assert_allclose(np.asarray(server.params["emb"]), 5.8,
                               rtol=1e-6)
    assert server.dn_count == 0
    np.testing.assert_allclose(np.asarray(server.buffer["emb"]), 0.0)


def test_server_state_roundtrip():
    server = GlobalServer(_params(), _layout(), MergeConfig(),
                          n_workers=2)
    server.merge(_delta(1.0), 0, ALL_UNITS)
    server.merge(_delta(2.0), 0, (1,))
    other = GlobalServer(_params(), _layout(), MergeConfig(),
                         n_workers=2)
    other.load(server.state(), server.meta())
    assert other.version == server.version
    assert other.staleness_hist == server.staleness_hist
    for key in ("emb", "layers"):
        np.testing.assert_array_equal(np.asarray(other.params[key]),
                                      np.asarray(server.params[key]))
        np.testing.assert_array_equal(np.asarray(other.momentum[key]),
                                      np.asarray(server.momentum[key]))


# ------------------------------------------------------------- LocalServer

def test_local_server_take_in_op_order_and_average():
    srv = LocalServer(dc=0)
    srv.push(_delta(1.0), (0, 1), 0, worker=0, period=0, phase=0)
    srv.push(_delta(3.0), (1, 2), 1, worker=1, period=0, phase=0)
    srv.push(_delta(9.0), (3,), 2, worker=0, period=1, phase=1)
    entries = srv.take([(1, 0, 0), (0, 0, 0)])
    assert [e.worker for e in entries] == [1, 0]
    delta, units, base = LocalServer.merged_delta(entries)
    np.testing.assert_allclose(np.asarray(delta["emb"]), 2.0)
    assert units == (0, 1, 2)
    assert base == 0
    # taken entries are gone; the third is still queued
    assert [e.key for e in srv.entries] == [(0, 1, 1)]
    with pytest.raises(KeyError):
        srv.take([(1, 0, 0)])


def test_merged_delta_single_entry_passthrough():
    srv = LocalServer(dc=0)
    srv.push(_delta(5.0), (2,), 7, worker=3, period=4, phase=1)
    delta, units, base = LocalServer.merged_delta(
        srv.take([(3, 4, 1)]))
    np.testing.assert_allclose(np.asarray(delta["layers"]), 5.0)
    assert units == (2,) and base == 7
