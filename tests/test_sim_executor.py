"""SimExecutor: conformance with time_model + byte-identical determinism."""

import pytest

from repro.core.plans import build_plan
from repro.core.schedule import dreamddp_schedule
from repro.core.time_model import simulate_period
from repro.sim import (LinkSpec, NetworkModel, Scenario, SimExecutor,
                       StragglerOnset, Topology, Trace, VirtualCluster,
                       synthetic_profile)

from conftest import random_profile


def _static_cluster(profile, H, *, n=8, jitter=0.0, seed=0):
    net = NetworkModel(
        Topology(n), LinkSpec(bandwidth=profile.hw.bandwidth,
                              latency=profile.hw.latency, jitter=jitter))
    return VirtualCluster(net, (), H=H, seed=seed)


# ------------------------------------------------------------ conformance

@pytest.mark.parametrize("algo", ["dreamddp", "plsgd-enp", "flsgd"])
@pytest.mark.parametrize("seed", range(3))
def test_static_run_matches_time_model_exactly(algo, seed):
    """On a static flat network the executor IS the tau-recursion: every
    iteration time equals simulate_period's, to float round-off."""
    H = 4
    prof = random_profile(12, seed=seed)
    plan = build_plan(algo, prof, H)
    ex = SimExecutor(prof, plan,
                     _static_cluster(prof, plan.H, n=prof.hw.n_workers))
    trace = ex.run(2)

    from repro.core.time_model import simulate_phase
    n = plan.n_units
    for r in range(trace.n_iterations):
        h = plan.phase_of_iteration(r)
        positions = sorted(n - 1 - u for u in plan.phase_units[h])
        expected = simulate_phase(prof, positions).iteration_time
        assert trace.iteration_time(r) == pytest.approx(expected,
                                                        rel=1e-12)


def test_dreamddp_fills_reproduced_in_sim():
    """Plan fills (§3.4) flow through phase_units into the replay."""
    H = 4
    prof = random_profile(16, seed=1)
    plan = build_plan("dreamddp", prof, H)
    res = dreamddp_schedule(prof, H)
    n = plan.n_units
    fills = [[n - 1 - u for u in f] for f in plan.fill_units]
    ex = SimExecutor(prof, plan, _static_cluster(prof, H))
    trace = ex.run(1)
    tls = simulate_period(prof, res.partition, fills)
    assert trace.period_time(0) == pytest.approx(
        sum(t.iteration_time for t in tls), rel=1e-12)


def test_multi_channel_comm():
    prof = random_profile(10, seed=2)
    plan = build_plan("wfbp", prof, 1)
    one = SimExecutor(prof, plan, _static_cluster(prof, 1)).run(3)
    four = SimExecutor(prof, plan, _static_cluster(prof, 1),
                       n_channels=4).run(3)
    assert four.makespan <= one.makespan + 1e-12


# ------------------------------------------------------------ determinism

def _run_scenario(scenario, seed_override=None, periods=2):
    import dataclasses
    sc = scenario if seed_override is None else \
        dataclasses.replace(scenario, seed=seed_override)
    prof = synthetic_profile()
    cluster = sc.build(4)
    plan = build_plan("dreamddp", cluster.effective_profile(prof, 0.0), 4)
    return SimExecutor(prof, plan, cluster).run(periods)


def test_identical_seeds_byte_identical_traces():
    sc = Scenario(name="jittered", description="",
                  intra=LinkSpec(bandwidth=1e9, latency=1e-4, jitter=0.1),
                  events=(StragglerOnset(period=1, worker=2,
                                         slowdown=2.0),),
                  periods=2, seed=7)
    a, b = _run_scenario(sc), _run_scenario(sc)
    assert a.to_json() == b.to_json()
    assert a.fingerprint() == b.fingerprint()


def test_different_seed_changes_jittered_trace():
    sc = Scenario(name="jittered", description="",
                  intra=LinkSpec(bandwidth=1e9, latency=1e-4, jitter=0.1),
                  periods=2, seed=7)
    assert _run_scenario(sc).fingerprint() != \
        _run_scenario(sc, seed_override=8).fingerprint()


def test_zero_jitter_seed_invariant():
    """Without jitter the replay is seed-independent by construction."""
    sc = Scenario(name="plain", description="", periods=2, seed=0)
    assert _run_scenario(sc).fingerprint() == \
        _run_scenario(sc, seed_override=99).fingerprint()


def test_trace_json_roundtrip():
    sc = Scenario(name="plain", description="", periods=2,
                  events=(StragglerOnset(period=1, worker=0,
                                         slowdown=3.0,
                                         duration_periods=1),))
    tr = _run_scenario(sc)
    tr2 = Trace.from_json(tr.to_json())
    assert tr2.to_json() == tr.to_json()
    assert tr2.period_times() == tr.period_times()
    assert len(tr2.events) == 1


# ----------------------------------------------------------------- guards

def test_plan_profile_unit_mismatch_rejected():
    prof = random_profile(10)
    plan = build_plan("dreamddp", random_profile(8), 4)
    with pytest.raises(ValueError):
        SimExecutor(prof, plan, _static_cluster(prof, 4))
