"""int8 + error-feedback compression semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.compression import (compressed_worker_mean,
                                        dequantize_int8, quantize_int8)


def test_quant_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 3
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float((err <= s * 0.5 + 1e-9).mean()) == 1.0


def test_compressed_mean_close_to_exact():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    res = jnp.zeros_like(x)
    synced, new_res = compressed_worker_mean(x, res)
    exact = jnp.broadcast_to(x.mean(0, keepdims=True), x.shape)
    # one-shot error bounded by the quantization step
    assert float(jnp.abs(synced - exact).max()) < 0.1
    # synced identical across workers
    np.testing.assert_allclose(np.asarray(synced - synced[:1]), 0.0,
                               atol=1e-7)


def test_error_feedback_corrects_over_rounds():
    """Repeated syncs of a CONSTANT tensor: with EF the running average of
    transmitted values converges to the true mean (bias is absorbed)."""
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 8, 8)) * 0.37
    exact = x.mean(0)
    res = jnp.zeros_like(x)
    acc = jnp.zeros_like(exact)
    n = 30
    for _ in range(n):
        synced, res = compressed_worker_mean(x, res)
        acc = acc + synced[0]
    err_avg = float(jnp.abs(acc / n - exact).max())
    one, _ = compressed_worker_mean(x, jnp.zeros_like(x))
    err_one = float(jnp.abs(one[0] - exact).max())
    assert err_avg < err_one * 0.5
