"""HLO collective parser on representative optimized-HLO lines."""

from repro.analysis.hlo import parse_collectives

HLO = """
HloModule jit_step
  %ar = bf16[128,1024]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag.1 = f32[64,512]{1,0} all-gather-start(%y), replica_groups={{0,1},{2,3}}, dimensions={0}
  %ag.done = f32[64,512]{1,0} all-gather-done(%ag.1)
  %rs = bf16[32]{0} reduce-scatter(%z), replica_groups=[2,8]<=[16], dimensions={0}
  %a2a = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(%p, %q), replica_groups={{0,1,2,3,4,5,6,7}}
  %cp = u32[4]{0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
  %dot = f32[8,8]{1,0} dot(%a, %b)
"""


def test_parses_all_collective_kinds():
    s = parse_collectives(HLO)
    kinds = sorted(o.kind for o in s.ops)
    assert kinds == ["all-gather", "all-reduce", "all-to-all",
                     "collective-permute", "reduce-scatter"]


def test_done_ops_not_double_counted():
    s = parse_collectives(HLO)
    assert sum(1 for o in s.ops if o.kind == "all-gather") == 1


def test_bytes_and_groups():
    s = parse_collectives(HLO)
    by = {o.kind: o for o in s.ops}
    ar = by["all-reduce"]
    assert ar.result_bytes == 128 * 1024 * 2 and ar.group_size == 4
    assert ar.wire_bytes == 2 * 3 / 4 * ar.result_bytes
    ag = by["all-gather"]
    assert ag.group_size == 2
    rs = by["reduce-scatter"]
    assert rs.group_size == 8 and rs.result_bytes == 32 * 2
    assert rs.wire_bytes == 7 * rs.result_bytes
    a2a = by["all-to-all"]
    assert a2a.result_bytes == 2 * 16 * 16 * 4     # tuple shape summed
    cp = by["collective-permute"]
    assert cp.wire_bytes == cp.result_bytes == 16


def test_summary_aggregation():
    s = parse_collectives(HLO)
    agg = s.by_kind()
    assert agg["all-reduce"]["count"] == 1
    assert s.total_wire_bytes > 0
    assert s.to_dict()["n_ops"] == 5
