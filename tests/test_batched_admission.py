"""Batched admission: one prefill launch per shape bucket, one host sync
per tick — bitwise-equal to the serial admission oracle.

PR 7's regression fix: per-request prefill dispatch (one executable
launch + one blocking first-token sync each) serialized admission-heavy
traffic below the naive loop's length-grouped batching.  These tests pin
the fix's contract:

* **equivalence** — ``batched_admission=True`` emits token-for-token the
  same greedy streams as the serial path for every KV family, on both
  backends, with mixed buckets in one tick and midstream admission;
* **dispatch accounting** — K same-bucket admissions cost ONE launch and
  the tick ONE sync (``prefill_batches`` / ``admit_ticks``), and hit one
  executable (compile-stats pinned across rounds);
* **latency semantics** — first tokens share the tick's sync timestamp
  but TTFT stays per-request from ``submit_t``;
* the satellite bugfixes: completion-history drain/cap, duplicate
  in-flight id rejection, and the paged footprint commitment at the
  chunk-padding boundary.
"""

import time

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.serve import EngineConfig, Request, ServeEngine

# (arch_id, family, backend): every KV family on every backend it supports
SWEEP = [
    ("qwen3-1.7b", "transformer", "contiguous"),
    ("qwen3-1.7b", "transformer", "paged"),
    ("qwen3-moe-30b-a3b", "moe", "contiguous"),
    ("qwen3-moe-30b-a3b", "moe", "paged"),
    ("deepseek-v3-671b", "mla", "paged"),
    ("mamba2-780m", "mamba2", "contiguous"),
]

# 5 requests over 4 slots: the first tick admits three distinct buckets
# (lengths {6, 9, 12}) with one bucket holding two requests, and the
# fifth request is admitted midstream into a freed slot.
_PROMPT_LENS = (6, 6, 9, 12, 6)
_BUDGETS = (5, 3, 7, 2, 6)


def _setup(arch_id):
    arch = get_arch(arch_id)
    model = arch.make_smoke()
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, model.cfg.vocab, size=n).tolist()
               for n in _PROMPT_LENS]
    return arch, model, params, prompts


def _cfg(backend, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 64)
    kw.setdefault("decode_block", 4)
    if backend == "paged":
        kw.setdefault("kv_backend", "paged")
        kw.setdefault("page_size", 8)
    return EngineConfig(**kw)


def _tokens(model, params, cfg, prompts, frontend=None, extras=None,
            budgets=_BUDGETS):
    eng = ServeEngine(model, params, cfg, frontend=frontend)
    comps = eng.generate([
        Request(tokens=p, max_new_tokens=g, extra=e)
        for p, g, e in zip(prompts, budgets,
                           extras or [()] * len(prompts), strict=True)])
    return [c.tokens for c in comps], eng


# ---------------------------------------------------------------- equivalence

@pytest.mark.parametrize("arch_id,family,backend",
                         SWEEP, ids=[f"{f}-{b}" for _, f, b in SWEEP])
def test_batched_matches_serial_token_for_token(arch_id, family, backend):
    """Mixed buckets in one tick + midstream admission: batched admission
    must reproduce the serial oracle's greedy streams exactly."""
    _, model, params, prompts = _setup(arch_id)
    batched, eng = _tokens(model, params, _cfg(backend), prompts)
    serial, _ = _tokens(model, params,
                        _cfg(backend, batched_admission=False), prompts)
    assert batched == serial
    # 3 buckets in tick 1 (one of size 2), 1 more for the midstream admit
    assert eng.stats.prefill_batches == 4
    assert eng.stats.admit_ticks == 2


@pytest.mark.parametrize("backend", ["contiguous", "paged"])
def test_batched_matches_serial_chunked(backend):
    """Chunk padding: refeed groups batch too, and never mix with
    exact-length groups (prompt 6 pads to 8 and refeeds; prompt 9 and 12
    pad to 16)."""
    _, model, params, prompts = _setup("qwen3-1.7b")
    cfg = _cfg(backend, prefill_chunk=8)
    batched, _ = _tokens(model, params, cfg, prompts)
    serial, _ = _tokens(
        model, params, _cfg(backend, prefill_chunk=8,
                            batched_admission=False), prompts)
    assert batched == serial


def test_batched_matches_serial_vision_frontend():
    """Frontend extras ride along stacked [K, n, d]; the vision prefix
    shifts every position the same way it does serially."""
    arch, model, params, prompts = _setup("llava-next-34b")
    rng = np.random.RandomState(1)
    extras = [(np.asarray(rng.standard_normal((8, model.cfg.d_model)),
                          np.float32),) for _ in prompts]
    batched, _ = _tokens(model, params, _cfg("contiguous"), prompts,
                         frontend=arch.frontend, extras=extras)
    serial, _ = _tokens(model, params,
                        _cfg("contiguous", batched_admission=False),
                        prompts, frontend=arch.frontend, extras=extras)
    assert batched == serial


def test_batched_seeded_sampling_is_batch_independent():
    """A sampling request's stream must not depend on what shares its
    admission group: same request alone vs in a full tick, same tokens
    (per-lane PRNG streams are derived exactly as the serial path's)."""
    from repro.serve import SamplingParams
    _, model, params, prompts = _setup("qwen3-1.7b")
    sp = SamplingParams(temperature=0.8, top_k=5, seed=42)
    probe = Request(tokens=prompts[0], max_new_tokens=5, sampling=sp)

    alone = ServeEngine(model, params, _cfg("contiguous")).generate(
        [Request(tokens=prompts[0], max_new_tokens=5, sampling=sp)])
    crowd = ServeEngine(model, params, _cfg("contiguous")).generate(
        [probe] + [Request(tokens=prompts[0], max_new_tokens=5)
                   for _ in range(3)])
    assert crowd[0].tokens == alone[0].tokens


# ----------------------------------------------------------------- dispatch

def test_same_bucket_tick_is_one_launch_one_sync():
    """K equal-length admissions in one tick: ONE batched prefill launch,
    ONE executable, ONE admit sync — and a second same-shape round
    recompiles nothing."""
    _, model, params, _ = _setup("qwen3-1.7b")
    rng = np.random.RandomState(2)
    eng = ServeEngine(model, params, _cfg("contiguous"))
    reqs = lambda: [Request(tokens=rng.randint(
        0, model.cfg.vocab, size=8).tolist(), max_new_tokens=4)
        for _ in range(4)]
    eng.generate(reqs())
    assert eng.stats.prefill_batches == 1
    assert eng.stats.admit_ticks == 1
    misses = eng.compile_stats()
    assert misses["prefill_batched"] == 1      # one (K=4, S=8) executable
    assert misses["prefill"] == 0              # serial path never ran
    eng.generate(reqs())
    assert eng.compile_stats() == misses, "same-shape round recompiled"
    assert eng.stats.prefill_batches == 2


def test_serial_path_unused_under_batched_admission():
    _, model, params, prompts = _setup("qwen3-1.7b")
    _, eng = _tokens(model, params, _cfg("contiguous"), prompts)
    stats = eng.compile_stats()
    assert stats["prefill"] == 0 and stats["refeed"] == 0
    assert stats["first_sample"] == 0
    assert stats["prefill_batched"] > 0


# ------------------------------------------------------------ TTFT semantics

def test_ttft_is_per_request_under_shared_sync():
    """Requests admitted in the same tick share one first-token timestamp
    but keep their own submit time: backdating one submission by 1s must
    show up as exactly +1s of TTFT relative to its tickmate."""
    _, model, params, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params, _cfg("contiguous"))
    now = time.perf_counter()
    toks = list(range(1, 9))
    eng.submit(Request(tokens=toks, max_new_tokens=3, request_id="early"),
               submit_t=now - 1.0)
    eng.submit(Request(tokens=toks, max_new_tokens=3, request_id="late"),
               submit_t=now)
    comps = {c.request_id: c for c in eng.drain()}
    assert eng.stats.admit_ticks == 1          # same tick, shared sync
    delta = comps["early"].ttft_s - comps["late"].ttft_s
    assert abs(delta - 1.0) < 1e-6
    assert comps["late"].ttft_s > 0


def test_prefill_time_attributed_once_per_tick():
    """prefill_time_s is measured tick-wide (admission start -> shared
    sync), not summed per request: admitting K at once must not count
    the wall K times, so the mean TTFT can't exceed tick wall time."""
    _, model, params, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params, _cfg("contiguous"))
    t0 = time.perf_counter()
    for i in range(4):
        eng.submit(Request(tokens=list(range(1, 9)), max_new_tokens=2))
    eng.drain()
    wall = time.perf_counter() - t0
    assert eng.stats.admit_ticks == 1
    assert eng.stats.prefill_time_s <= wall


# ------------------------------------------------------- completion history

def test_take_completed_drains_and_caps():
    _, model, params, prompts = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params,
                      _cfg("contiguous", completed_cap=2))
    comps = eng.generate([Request(tokens=p, max_new_tokens=2)
                          for p in prompts])
    assert len(comps) == len(prompts)
    kept = eng.take_completed()
    assert [c.request_id for c in kept] == \
        [c.request_id for c in comps[-2:]], \
        "history must keep the newest completed_cap completions"
    assert eng.take_completed() == [], "drain must transfer ownership"


def test_completed_history_bounded_without_drain():
    _, model, params, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params,
                      _cfg("contiguous", completed_cap=3))
    for i in range(7):
        eng.generate([Request(tokens=list(range(1, 7)),
                              max_new_tokens=1)])
    assert len(eng.take_completed()) == 3


# ------------------------------------------------------------- duplicate ids

def test_duplicate_in_flight_request_id_rejected_on_submit():
    _, model, params, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params, _cfg("contiguous"))
    eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=4,
                       request_id="dup"))
    with pytest.raises(ValueError, match="already in flight"):
        eng.submit(Request(tokens=[4, 5, 6], max_new_tokens=4,
                           request_id="dup"))
    eng.drain()
    # retired ids may be reused — only *concurrent* duplicates collide
    eng.submit(Request(tokens=[7, 8, 9], max_new_tokens=2,
                       request_id="dup"))
    assert len(eng.drain()) == 1


def test_duplicate_request_id_rejected_in_generate():
    """generate() keys its completion map by id, so a concurrent
    duplicate would silently drop a result — it must raise instead."""
    _, model, params, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params, _cfg("contiguous"))
    reqs = [Request(tokens=[1, 2, 3], max_new_tokens=2, request_id=9),
            Request(tokens=[4, 5, 6], max_new_tokens=2, request_id=9)]
    with pytest.raises(ValueError, match="already in flight"):
        eng.generate(reqs)


# -------------------------------------------------------- footprint boundary

def test_contiguous_admission_exactly_at_max_seq():
    """prefix-less request with s + max_new == max_seq is admissible;
    one more token is not."""
    _, model, params, _ = _setup("qwen3-1.7b")
    eng = ServeEngine(model, params,
                      _cfg("contiguous", max_batch=1, max_seq=16))
    comp = eng.generate([Request(tokens=list(range(1, 13)),
                                 max_new_tokens=4)])[0]
    assert len(comp.tokens) == 4
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(Request(tokens=list(range(1, 14)), max_new_tokens=4))


def test_paged_commitment_is_real_footprint_not_padded_depth():
    """Chunk padding must not inflate the page commitment: pad positions
    scatter to the trash page and never need real pages, so a pool with
    exactly ceil((s + max_new) / page) usable pages admits a request
    whose *padded* depth would not fit.  (The old worst-case formula
    committed the padded depth and deferred this admission forever.)"""
    _, model, params, _ = _setup("qwen3-1.7b")
    # s=5 pads to 16, but the real footprint is 5 + 2 = 7 -> one 16-token
    # page; kv_pages=2 is that page plus the trash page.
    eng = ServeEngine(model, params, EngineConfig(
        max_batch=1, max_seq=32, decode_block=2, prefill_chunk=16,
        kv_backend="paged", page_size=16, kv_pages=2))
    serial = ServeEngine(model, params, EngineConfig(
        max_batch=1, max_seq=32, decode_block=2, prefill_chunk=16,
        kv_backend="paged", page_size=16, kv_pages=2,
        batched_admission=False))
    req = lambda: Request(tokens=[3, 1, 4, 1, 5], max_new_tokens=2)
    comp = eng.generate([req()])[0]
    assert len(comp.tokens) == 2
    assert comp.tokens == serial.generate([req()])[0].tokens


def test_paged_vision_chunked_admission_at_capacity():
    """The vision prefix counts toward both bounds, once: prefix + padded
    fills the lane exactly, and the commitment is prefix + s + max_new."""
    arch, model, params, _ = _setup("llava-next-34b")
    rng = np.random.RandomState(3)
    extra = (np.asarray(rng.standard_normal((8, model.cfg.d_model)),
                        np.float32),)
    # lane: 8 + max(5 + 3, 16) = 24 == max_seq; commitment: 8 + 5 + 3 =
    # 16 -> two 8-token pages (+ trash)
    cfg = EngineConfig(max_batch=1, max_seq=24, decode_block=2,
                       prefill_chunk=16, kv_backend="paged", page_size=8,
                       kv_pages=3)
    eng = ServeEngine(model, params, cfg, frontend=arch.frontend)
    comp = eng.generate([Request(tokens=[3, 1, 4, 1, 5],
                                 max_new_tokens=3, extra=extra)])[0]
    assert len(comp.tokens) == 3
    with pytest.raises(ValueError, match="max_seq"):
        # s + max_new exceeds the padded bucket: lane needs
        # 8 + max(13 + 4, 16) = 25 > 24
        eng.submit(Request(tokens=list(range(1, 14)), max_new_tokens=4,
                           extra=extra))
