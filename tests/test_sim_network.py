"""SimNet network model: drift integration, degradation, topology."""

import pytest

from repro.core.profiler import HardwareSpec, ring_allreduce_time
from repro.sim import DriftTrace, LinkSpec, NetworkModel, Topology
from repro.sim.network import ring_factor


def _flat(bw=1e9, lat=0.0, n=8, drift=None):
    return NetworkModel(Topology(n), LinkSpec(bandwidth=bw, latency=lat),
                        drift=drift)


# ------------------------------------------------------------- transfers

def test_static_transfer_time():
    net = _flat(bw=1e9)
    assert net.transfer_time("intra", 1e9, 0.0) == pytest.approx(1.0)
    assert net.transfer_time("intra", 0.0, 5.0) == 0.0


def test_transfer_integrates_across_drift_breakpoint():
    """1 GB at 1 GB/s from t=0, but bandwidth halves at t=0.5: the first
    0.5 s ships 0.5 GB, the rest takes 1.0 s at 0.5 GB/s -> 1.5 s."""
    net = _flat(bw=1e9, drift={"intra": DriftTrace(((0.5, 5e8),))})
    assert net.transfer_time("intra", 1e9, 0.0) == pytest.approx(1.5)
    # started after the breakpoint: pure 0.5 GB/s
    assert net.transfer_time("intra", 1e9, 1.0) == pytest.approx(2.0)


def test_transfer_stalls_through_outage_window():
    """A factor-0 degradation is an outage: bytes flow only outside it."""
    net = _flat(bw=1e9)
    h = net.degrade("intra", 0.0, 1.0)
    net.end_degradation(h, 2.0)
    # 1.5 GB from t=0: 1 GB ships in [0,1), stall [1,2), 0.5 GB in [2,2.5)
    assert net.transfer_time("intra", 1.5e9, 0.0) == pytest.approx(2.5)


def test_permanent_zero_bandwidth_raises():
    net = _flat(bw=1e9)
    net.set_bandwidth("intra", 0.0, 1.0)
    with pytest.raises(RuntimeError):
        net.transfer_time("intra", 2e9, 0.0)


def test_degradation_multiplies_drifted_bandwidth():
    net = _flat(bw=1e9)
    net.set_bandwidth("intra", 4e8, 10.0)
    h = net.degrade("intra", 0.5, 20.0)
    assert net.bandwidth_at("intra", 0.0) == 1e9
    assert net.bandwidth_at("intra", 15.0) == 4e8
    assert net.bandwidth_at("intra", 25.0) == 2e8
    net.end_degradation(h, 30.0)
    assert net.bandwidth_at("intra", 35.0) == 4e8


# ------------------------------------------------------------ collectives

def test_flat_collective_matches_profiler_ring():
    """The conformance bedrock: a static flat network reproduces
    ring_allreduce_time bit-for-bit (incl. the K >= 2 clamp)."""
    for k in (1, 2, 5, 8):
        hw = HardwareSpec(bandwidth=1e9, latency=3e-4, n_workers=k)
        net = NetworkModel(Topology(max(k, 1)),
                           LinkSpec(bandwidth=1e9, latency=3e-4))
        got = net.collective_time(12345678.0, 0.0,
                                  workers_by_dc=[k])
        assert got == ring_allreduce_time(12345678.0, hw)


def test_two_tier_collective_decomposition():
    net = NetworkModel(Topology(8, 2), LinkSpec(bandwidth=1e10, latency=1e-4),
                       LinkSpec(bandwidth=1e8, latency=1e-2))
    nbytes = 1e8
    got = net.collective_time(nbytes, 0.0, workers_by_dc=[4, 4])
    intra = ring_factor(4) * nbytes / 1e10 + 1e-4
    inter = ring_factor(2) * nbytes / 1e8 + 1e-2
    assert got == pytest.approx(intra + inter)
    # a single populated DC skips the inter ring entirely
    solo = net.collective_time(nbytes, 0.0, workers_by_dc=[4, 0])
    assert solo == pytest.approx(intra)


def test_collective_requires_active_workers():
    net = _flat()
    with pytest.raises(ValueError):
        net.collective_time(1e6, 0.0, workers_by_dc=[0, 0])


# --------------------------------------------------------------- topology

def test_topology_round_robin_balanced():
    topo = Topology(8, 2)
    assert topo.workers_by_dc(range(8)) == [4, 4]
    # churn removes the highest ids -> stays balanced
    assert topo.workers_by_dc(range(6)) == [3, 3]


def test_multi_dc_without_inter_link_rejected():
    with pytest.raises(ValueError):
        NetworkModel(Topology(4, 2), LinkSpec(bandwidth=1e9))


def test_drift_trace_validates_ordering():
    with pytest.raises(ValueError):
        DriftTrace(((2.0, 1e9), (1.0, 5e8)))
    tr = DriftTrace(((1.0, 5e8), (2.0, 2e8)))
    assert tr.value_at(0.5, 1e9) == 1e9
    assert tr.value_at(1.5, 1e9) == 5e8
    assert tr.value_at(2.5, 1e9) == 2e8
