"""repro.api: Session facade + SyncStrategy registry."""

import jax
import jax.numpy as jnp
import pytest

from repro.api import (JobConfig, Session, SyncStrategy,
                       available_strategies, get_strategy,
                       register_strategy, unregister_strategy)
from repro.core.plans import SyncPlan, build_plan
from repro.models.transformer import DecoderLM, LMConfig

from conftest import random_profile

_CFG = LMConfig(name="t", n_layers=4, d_model=48, n_heads=4, n_kv_heads=2,
                d_ff=96, vocab=64, param_dtype="float32", remat=False)

SEED_ALGOS = ("ssgd", "wfbp", "ascwfbp", "flsgd", "plsgd-enp", "dreamddp")


def _tiny_session(algo, *, workers=4, H=4, track=False, **job_kw):
    cfg = JobConfig(algo=algo, workers=workers, period=H, bandwidth=1e9,
                    seq=32, batch_per_worker=2, lr=3e-3, warmup_steps=2,
                    decay_steps=200, track_divergence=track, **job_kw)
    return Session(cfg, model=DecoderLM(_CFG))


# ---------------------------------------------------------------- registry

def test_builtin_strategies_registered():
    names = available_strategies()
    for algo in SEED_ALGOS + ("dreamddp-bf", "dreamddp-int8", "hier-2tier"):
        assert algo in names
    assert get_strategy("dreamddp").name == "dreamddp"


def test_registry_round_trip_and_fingerprint_stable():
    """register_strategy -> Session -> plan, fingerprint deterministic."""

    @register_strategy("test-sync-all")
    class SyncAll(SyncStrategy):
        def build_plan(self, profile, H, *, fill_mode="exact"):
            n = len(profile)
            return SyncPlan(algo=self.name, comm="parameters", H=1,
                            n_units=n, phase_units=(tuple(range(n)),))

    try:
        assert "test-sync-all" in available_strategies()
        s1 = _tiny_session("test-sync-all")
        s2 = _tiny_session("test-sync-all")
        assert s1.plan.fingerprint() == s2.plan.fingerprint()
        assert s1.plan.algo == "test-sync-all"
        # the shimmed core entry point dispatches through the registry too
        prof = random_profile(6, seed=0)
        assert build_plan("test-sync-all", prof, 3).H == 1
    finally:
        unregister_strategy("test-sync-all")
    with pytest.raises(KeyError):
        get_strategy("test-sync-all")


def test_register_rejects_non_strategy():
    with pytest.raises(TypeError):
        register_strategy("bogus", object())


@pytest.mark.parametrize("algo", sorted(set(available_strategies())))
def test_plan_json_roundtrip_every_strategy(algo):
    prof = random_profile(11, seed=7)
    plan = get_strategy(algo).build_plan(prof, 4)
    plan2 = SyncPlan.from_json(plan.to_json())
    assert plan2 == plan
    assert plan2.fingerprint() == plan.fingerprint()
    assert plan2.comm in ("gradients", "parameters")


def test_comm_mode_is_data_not_algo_strings():
    prof = random_profile(8, seed=1)
    assert not build_plan("ssgd", prof, 1).is_parameter_sync
    assert build_plan("hier-2tier", prof, 4).is_parameter_sync
    # legacy JSON without a comm field derives it from the algo name
    legacy = SyncPlan.from_json(
        '{"algo": "ssgd", "H": 1, "n_units": 2, "phase_units": [[0, 1]]}')
    assert legacy.comm == "gradients"


# ----------------------------------------------------------------- session

@pytest.mark.parametrize("algo", SEED_ALGOS)
def test_session_fit_every_seed_algo(algo):
    """Session(JobConfig(...)).fit reproduces the quickstart wire-up."""
    sess = _tiny_session(algo, H=1 if algo in ("ssgd", "wfbp", "ascwfbp")
                         else 4)
    sess.fit(6)
    losses = [h["loss"] for h in sess.history]
    assert len(losses) == 6
    assert losses[-1] < losses[0]  # six steps of warmup already descend


@pytest.mark.parametrize("algo", ["hier-2tier", "dreamddp-int8"])
def test_new_strategies_train_to_convergence(algo):
    """Beyond-seed strategies converge through the registry path."""
    sess = _tiny_session(algo, workers=8, H=4, track=True)
    sess.fit(40)
    losses = [h["loss"] for h in sess.history]
    assert losses[-1] < losses[0] - 0.3, algo
    # hot tier of hier-2tier syncs every phase; dreamddp-int8 carries EF
    if algo == "dreamddp-int8":
        assert sess.state.ef is not None
    else:
        freq = sess.plan.sync_frequency()
        hot = sess.plan.meta["hot_units"]
        assert all(freq[u] == sess.plan.H for u in hot)
        assert all(f >= 1 for f in freq)


def test_session_lazy_plan_without_training_state():
    sess = _tiny_session("dreamddp")
    plan = sess.plan                       # no runner/state built
    assert plan.H == 4 and sess._runner is None
    assert sess.profile().comm_compute_ratio() > 0


def test_replan_rebuilds_phase_steps_with_new_partition():
    sess = _tiny_session("dreamddp", workers=4, H=4)
    sess.fit(4)
    old_plan = sess.plan
    old_steps = list(sess.runner._steps)
    new_plan = sess.replan(bandwidth=1e7, period=3)
    assert new_plan.H == 3
    assert new_plan.fingerprint() != old_plan.fingerprint()
    # the runner executes the new plan through rebuilt executables
    assert sess.runner.plan is new_plan
    assert len(sess.runner._steps) == 3
    assert all(s not in old_steps for s in sess.runner._steps)
    sess.fit(3)
    assert len(sess.history) == 7


def test_replan_elastic_worker_change_reshards_state():
    sess = _tiny_session("dreamddp", workers=4, H=4)
    sess.fit(4)
    sess.replan(workers=2)
    assert jax.tree_util.tree_leaves(sess.state.params)[0].shape[0] == 2
    sess.fit(4)
    assert len(sess.history) == 8


def test_session_serve_generates():
    sess = _tiny_session("dreamddp")
    sess.fit(2)
    handle = sess.serve()
    tokens = jax.random.randint(jax.random.PRNGKey(0), (2, 8), 0,
                                _CFG.vocab)
    out = handle.generate(tokens, max_new_tokens=4)
    assert out.shape == (2, 4)
    assert jnp.all(out >= 0) and jnp.all(out < _CFG.vocab)


# ----------------------------------------------------------------- serving

def test_session_serve_returns_engine_and_memoizes():
    """serve() returns a ServeEngine; repeated serve() (same frontend +
    engine config) after more fit() reuses the compiled steps."""
    from repro.serve import EngineConfig, ServeEngine

    sess = _tiny_session("dreamddp")
    sess.fit(2)
    cfg = EngineConfig(max_batch=2, max_seq=64)
    eng = sess.serve(config=cfg)
    assert isinstance(eng, ServeEngine)
    tokens = jax.random.randint(jax.random.PRNGKey(0), (2, 8), 0,
                                _CFG.vocab)
    eng.generate(tokens, 4)
    misses = eng.compile_stats()
    sess.fit(2)
    eng2 = sess.serve(config=cfg)
    assert eng2 is eng                     # memoized: no re-jit
    out = eng2.generate(tokens, 4)
    assert out.shape == (2, 4)
    assert eng2.compile_stats() == misses  # warm across serve() calls
    # a different config is a different engine
    assert sess.serve(config=EngineConfig(max_batch=4, max_seq=64)) \
        is not eng


def test_session_serve_refuses_to_reset_busy_engine():
    from repro.serve import EngineConfig, Request

    sess = _tiny_session("dreamddp")
    cfg = EngineConfig(max_batch=2, max_seq=64)
    eng = sess.serve(config=cfg)
    eng.submit(Request(tokens=[1, 2, 3], max_new_tokens=4))
    with pytest.raises(RuntimeError, match="drain"):
        sess.serve(config=cfg)
    eng.drain()
    assert sess.serve(config=cfg) is eng     # idle again: safe to reuse


def test_inference_session_shim_grows_cache_like_old_loop():
    """The old loop sized its KV cache per call; the shim must not cap
    requests at the engine default max_seq."""
    from repro.api import InferenceSession
    from repro.serve import EngineConfig

    sess = _tiny_session("dreamddp")
    with pytest.warns(DeprecationWarning):
        shim = InferenceSession(sess.model,
                                sess.model.init(jax.random.PRNGKey(0)),
                                config=EngineConfig(max_batch=2,
                                                    max_seq=16))
    tokens = jax.random.randint(jax.random.PRNGKey(0), (2, 14), 0,
                                _CFG.vocab)
    out = shim.generate(tokens, max_new_tokens=8)   # needs 22 > 16
    assert out.shape == (2, 8)


def test_inference_session_shim_deprecated_but_equivalent():
    from repro.api import InferenceSession

    sess = _tiny_session("dreamddp")
    sess.fit(2)
    eng = sess.serve()
    tokens = jax.random.randint(jax.random.PRNGKey(0), (2, 8), 0,
                                _CFG.vocab)
    with pytest.warns(DeprecationWarning, match="ServeEngine"):
        shim = InferenceSession(sess.model, eng.params)
    assert jnp.array_equal(shim.generate(tokens, 4),
                           eng.reset(params=eng.params).generate(tokens, 4))


def test_legacy_compress_outer_flags_deprecated_not_threaded():
    sess = _tiny_session("dreamddp", compress="int8_ef")
    with pytest.warns(DeprecationWarning, match="algo registry"):
        scfg = sess.step_config
    # the flag resolved into the policy and was dropped from the config
    from repro.core.sync_policies import Int8EFSync
    assert isinstance(scfg.policy, Int8EFSync)
    assert scfg.compress is None and scfg.outer is False

    sess_outer = _tiny_session("flsgd", outer=True)
    with pytest.warns(DeprecationWarning):
        scfg = sess_outer.step_config
    from repro.core.sync_policies import OuterOptSync
    assert isinstance(scfg.policy, OuterOptSync)
    assert scfg.outer is False


def test_step_config_no_warning_without_legacy_flags():
    import warnings as _warnings

    sess = _tiny_session("dreamddp")
    with _warnings.catch_warnings():
        _warnings.simplefilter("error", DeprecationWarning)
        scfg = sess.step_config
    assert scfg.policy is not None


# ----------------------------------------------------------- async sessions

def test_session_async_fit_and_simulate():
    """`hier-async` flips the whole session onto the two-tier runtime:
    simulate defaults to the async executor, fit runs whole periods
    through the op-log runner, state stays worker-stacked for serve."""
    sess = _tiny_session("hier-async", workers=2, H=4)
    assert sess.use_async

    report = sess.simulate("straggler")
    assert report.trace.meta["mode"] == "async"
    sync = sess.simulate("straggler", mode="sync")
    assert "mode" not in sync.trace.meta or \
        sync.trace.meta.get("mode") != "async"

    with pytest.raises(ValueError, match="whole periods"):
        sess.fit(6)                       # not a multiple of H
    sess.fit(8)
    losses = [h["loss"] for h in sess.history]
    assert losses and losses[-1] < losses[0]
    flat = jax.tree_util.tree_leaves(sess.state.params)
    assert all(leaf.shape[0] == 2 for leaf in flat)

    # op-log replay is single-shot: a second fit cannot extend it
    with pytest.raises(ValueError):
        sess.fit(4)


def test_session_async_mode_flag_on_plain_strategy():
    sess = _tiny_session("dreamddp", workers=2, H=4, async_mode=True)
    assert sess.use_async
    assert sess.merge_config.rule == "halos"
    report = sess.simulate("homogeneous")
    assert report.trace.meta["mode"] == "async"


def test_session_async_replan_rejected():
    sess = _tiny_session("hier-async", workers=2, H=4)
    sess.fit(4)
    with pytest.raises(ValueError, match="replan"):
        sess.replan(bandwidth=1e8)
