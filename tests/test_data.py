"""Synthetic data pipeline: determinism, sharding, learnability."""

import jax.numpy as jnp
import numpy as np

from repro.data import MarkovCorpus, TeacherImages


def test_deterministic_per_step():
    d1 = MarkovCorpus(vocab=32, seq_len=16, batch_per_worker=2,
                      n_workers=4, seed=7)
    d2 = MarkovCorpus(vocab=32, seq_len=16, batch_per_worker=2,
                      n_workers=4, seed=7)
    np.testing.assert_array_equal(np.asarray(d1.batch(3)["tokens"]),
                                  np.asarray(d2.batch(3)["tokens"]))


def test_workers_get_different_shards():
    d = MarkovCorpus(vocab=32, seq_len=16, batch_per_worker=2,
                     n_workers=4, seed=7)
    t = np.asarray(d.batch(0)["tokens"])
    assert t.shape == (4, 2, 16)
    assert not np.array_equal(t[0], t[1])


def test_steps_differ():
    d = MarkovCorpus(vocab=32, seq_len=16, batch_per_worker=2,
                     n_workers=2, seed=7)
    assert not np.array_equal(np.asarray(d.batch(0)["tokens"]),
                              np.asarray(d.batch(1)["tokens"]))


def test_entropy_floor_below_uniform():
    d = MarkovCorpus(vocab=64, seq_len=8, batch_per_worker=1, n_workers=1)
    assert 0.0 < d.entropy_floor() < np.log(64)


def test_tokens_in_range():
    d = MarkovCorpus(vocab=17, seq_len=9, batch_per_worker=3, n_workers=2)
    t = np.asarray(d.batch(0)["tokens"])
    assert t.min() >= 0 and t.max() < 17


def test_teacher_images():
    d = TeacherImages(n_classes=10, image_dim=64, batch_per_worker=4,
                      n_workers=2)
    b = d.batch(0)
    assert b["images"].shape == (2, 4, 64)
    assert b["labels"].shape == (2, 4)
    assert int(b["labels"].max()) < 10
