"""End-to-end training behaviour (paper's empirical claims, miniaturized).

These are the system's acceptance tests: convergence of every algorithm,
the divergence ordering of Fig. 5, fault tolerance, straggler requeue and
elastic membership changes.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import HardwareSpec, analytic_profile, build_plan
from repro.data import MarkovCorpus
from repro.models.transformer import DecoderLM, LMConfig
from repro.optim import make_optimizer
from repro.runtime import (Runner, RunnerConfig, StepConfig,
                           init_train_state)

W = 8


@pytest.fixture(scope="module")
def setup():
    cfg = LMConfig(name="t", n_layers=4, d_model=48, n_heads=4,
                   n_kv_heads=2, d_ff=96, vocab=64, param_dtype="float32",
                   remat=False)
    model = DecoderLM(cfg)
    hw = HardwareSpec(bandwidth=1e9, n_workers=W)
    prof = analytic_profile(model.layer_costs(4, 32), hw)
    opt = make_optimizer("adam", lr=3e-3, warmup_steps=5, decay_steps=400)
    data = MarkovCorpus(vocab=64, seq_len=32, batch_per_worker=4,
                        n_workers=W, seed=0)
    return model, prof, opt, data


def _train(setup, algo, H, n=40, **kw):
    model, prof, opt, data = setup
    plan = build_plan(algo, prof, H)
    scfg = StepConfig(track_divergence=True, **kw)
    state = init_train_state(model, opt, jax.random.PRNGKey(0), W,
                             cfg=scfg)
    r = Runner(model, opt, plan, data, step_cfg=scfg)
    r.run(state, n)
    return r


@pytest.mark.parametrize("algo,H", [("ssgd", 1), ("flsgd", 4),
                                    ("plsgd-enp", 4), ("dreamddp", 4)])
def test_all_algorithms_converge(setup, algo, H):
    r = _train(setup, algo, H)
    losses = [h["loss"] for h in r.history]
    assert losses[-1] < losses[0] - 0.3, algo


def test_divergence_ordering(setup):
    """Paper Fig. 5: ssgd ~ 0; partial sync < full sync."""
    d_ssgd = max(h["divergence"] for h in _train(setup, "ssgd", 1).history)
    d_full = max(h["divergence"] for h in _train(setup, "flsgd", 4).history)
    d_part = max(h["divergence"]
                 for h in _train(setup, "plsgd-enp", 4).history)
    assert d_ssgd < 1e-8
    assert d_part < d_full


def test_compressed_and_outer_variants_converge(setup):
    for kw in ({"compress": "int8_ef"}, {"outer": True}):
        r = _train(setup, "dreamddp", 4, **kw)
        losses = [h["loss"] for h in r.history]
        assert losses[-1] < losses[0] - 0.3, kw


def test_failure_recovery(setup, tmp_path):
    model, prof, opt, data = setup
    plan = build_plan("dreamddp", prof, 4)
    scfg = StepConfig()
    state = init_train_state(model, opt, jax.random.PRNGKey(0), W,
                             cfg=scfg)
    ck = CheckpointManager(str(tmp_path))
    r = Runner(model, opt, plan, data, ckpt=ck, step_cfg=scfg,
               run_cfg=RunnerConfig(ckpt_every=8))
    ck.save(0, state, block=True)
    r.run(state, 20, inject_failure_at=11)
    assert r.retries == 1
    assert len(r.history) >= 20


def test_straggler_requeues_sync(setup):
    model, prof, opt, data = setup
    plan = build_plan("dreamddp", prof, 4)
    scfg = StepConfig()
    state = init_train_state(model, opt, jax.random.PRNGKey(0), W,
                             cfg=scfg)
    r = Runner(model, opt, plan, data, step_cfg=scfg,
               run_cfg=RunnerConfig(deadline_factor=2.0, min_history=4))
    # find a sync phase occurrence late enough to have timing history
    sync_phase = next(h for h in range(plan.H)
                      if plan.units_for_phase(h))
    step_at = 12 + (sync_phase - 12) % plan.H
    r.run(state, 24, inject_straggler_at=(step_at, 100.0))
    assert r.skipped_syncs >= 1
    # the makeup step ran at a later period boundary (pending cleared)
    assert not r.pending_units


def test_elastic_restore(setup, tmp_path):
    model, prof, opt, data = setup
    plan = build_plan("dreamddp", prof, 4)
    scfg = StepConfig()
    state = init_train_state(model, opt, jax.random.PRNGKey(0), W,
                             cfg=scfg)
    ck = CheckpointManager(str(tmp_path))
    r = Runner(model, opt, plan, data, ckpt=ck, step_cfg=scfg,
               run_cfg=RunnerConfig(ckpt_every=8))
    state = r.run(state, 8)
    r.ckpt.wait()

    plan4 = build_plan("dreamddp",
                       prof.with_bandwidth(1e9, n_workers=4), 4)
    tmpl = init_train_state(model, opt, jax.random.PRNGKey(0), 4, cfg=scfg)
    step, state4 = r.restore_elastic(tmpl, 4, plan4)
    assert jax.tree_util.tree_leaves(state4.params)[0].shape[0] == 4
    r.data = MarkovCorpus(vocab=64, seq_len=32, batch_per_worker=4,
                          n_workers=4, seed=0)
    r.run(state4, 4, start_step=step)
