"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(only launch/dryrun.py forces 512)."""

import random

import pytest

from repro.core.profiler import HardwareSpec, analytic_profile


def random_profile(n_layers: int, *, seed: int = 0, bandwidth: float = 1e9,
                   n_workers: int = 8, flop_lo: float = 1e9,
                   flop_hi: float = 8e10, par_lo: float = 1e6,
                   par_hi: float = 5e7):
    rng = random.Random(seed)
    hw = HardwareSpec(bandwidth=bandwidth, n_workers=n_workers,
                      latency=1e-4)
    layers = [(f"l{i}", rng.uniform(par_lo, par_hi),
               rng.uniform(flop_lo, flop_hi)) for i in range(n_layers)]
    return analytic_profile(layers, hw)


@pytest.fixture
def profile12():
    return random_profile(12)
