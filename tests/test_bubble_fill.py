"""§3.4 bubble filling: Eq. 12 admission + no-slowdown guarantee."""

import pytest

from repro.core.bubble_fill import fill_bubbles
from repro.core.schedule import dreamddp_schedule
from repro.core.time_model import simulate_period, simulate_phase

from conftest import random_profile


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("mode", ["eq12", "exact"])
@pytest.mark.parametrize("bandwidth", [1e9, 2e10])
def test_fills_never_slow_down_period(seed, mode, bandwidth):
    prof = random_profile(16, seed=seed, bandwidth=bandwidth)
    res = dreamddp_schedule(prof, 4)
    fills = fill_bubbles(prof, res.partition, mode=mode)
    base = sum(t.iteration_time
               for t in simulate_period(prof, res.partition))
    filled = sum(t.iteration_time
                 for t in simulate_period(prof, res.partition, fills.fills))
    assert filled <= base + 1e-9


def test_fills_are_late_layers():
    """The supplement targets output-most layers (paper: late layers
    converge last and benefit most)."""
    prof = random_profile(16, seed=1, bandwidth=5e10)
    res = dreamddp_schedule(prof, 4)
    fills = fill_bubbles(prof, res.partition, mode="exact")
    for extra in fills.fills:
        # BP positions form a prefix (0 = output-most), possibly with the
        # phase's own interval skipped
        if extra:
            assert extra == sorted(extra)
            assert extra[0] <= 2


def test_sync_counts_at_least_one():
    prof = random_profile(10, seed=2, bandwidth=2e10)
    res = dreamddp_schedule(prof, 5)
    fills = fill_bubbles(prof, res.partition)
    counts = fills.sync_counts(res.partition)
    assert all(c >= 1 for c in counts)
    assert sum(counts) == 10 + fills.extra_syncs


@pytest.mark.parametrize("seed", range(10))
@pytest.mark.parametrize("mode", ["eq12", "exact"])
@pytest.mark.parametrize("bandwidth", [1e8, 1e9, 5e9, 2e10])
def test_fills_never_slow_down_any_phase(seed, mode, bandwidth):
    """Per-phase invariant (stronger than the period-level check): each
    admitted fill leaves that phase's exact event timeline no slower —
    for BOTH admission modes, even though eq12 only reasons about the
    closed-form budget."""
    prof = random_profile(14, seed=seed, bandwidth=bandwidth)
    res = dreamddp_schedule(prof, 4)
    fills = fill_bubbles(prof, res.partition, mode=mode)
    for h, (s, e) in enumerate(res.partition.bp_intervals()):
        own = set(range(s, e))
        base = simulate_phase(prof, sorted(own)).iteration_time
        filled = simulate_phase(
            prof, sorted(own | set(fills.fills[h]))).iteration_time
        assert filled <= base + 1e-9, (h, mode, fills.fills[h])


@pytest.mark.parametrize("seed", range(10))
@pytest.mark.parametrize("mode", ["eq12", "exact"])
def test_fill_sync_counts_cover_every_position(seed, mode):
    """FillResult.sync_counts >= 1 everywhere, and bookkeeping matches
    the per-phase fill lists exactly."""
    prof = random_profile(12, seed=seed, bandwidth=10 ** (9 + seed % 2))
    res = dreamddp_schedule(prof, 4)
    fills = fill_bubbles(prof, res.partition, mode=mode)
    counts = fills.sync_counts(res.partition)
    assert len(counts) == 12
    assert all(c >= 1 for c in counts)
    assert sum(counts) == 12 + sum(len(f) for f in fills.fills)
    assert fills.extra_syncs == sum(len(f) for f in fills.fills)
    # fills are disjoint from the phase's own interval
    for (s, e), extra in zip(res.partition.bp_intervals(), fills.fills, strict=True):
        assert not (set(range(s, e)) & set(extra))
