"""PeriodPrefetcher: depth-k / background staging is bitwise identical
to the depth-1 inline double buffer — the knobs change only *when*
batches are built, never *what* they contain."""

import threading

import jax
import numpy as np
import pytest

from repro.core import HardwareSpec, analytic_profile, build_plan
from repro.data import MarkovCorpus
from repro.models.transformer import DecoderLM, LMConfig
from repro.optim import make_optimizer
from repro.runtime import (PeriodPrefetcher, Runner, RunnerConfig,
                           StepConfig, init_train_state,
                           stack_period_batches)

W = 4
H = 4


@pytest.fixture(scope="module")
def data():
    return MarkovCorpus(vocab=64, seq_len=32, batch_per_worker=4,
                        n_workers=W, seed=0)


def _assert_tree_equal(a, b, what=""):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb)
    for (pa, la), (_, lb) in zip(fa, fb, strict=True):
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb),
            err_msg=f"{what}{jax.tree_util.keystr(pa)}")


@pytest.mark.parametrize("depth,background",
                         [(1, False), (3, False), (1, True), (3, True)])
@pytest.mark.parametrize("stacked", [True, False])
def test_staged_batches_bitwise_identical(data, depth, background,
                                          stacked):
    """Every (depth, background, stacked) combination yields the same
    bytes as building each period on the spot."""
    pipe = PeriodPrefetcher(data, H, stacked=stacked, depth=depth,
                            background=background)
    starts = list(range(0, 5 * H, H))
    pipe.prefetch(starts[0], last=starts[-1])
    for s in starts:
        got = pipe.get(s)
        pipe.prefetch(s + H, last=starts[-1])
        if stacked:
            want = stack_period_batches(data, s, H)
            _assert_tree_equal(got, want, f"period@{s}")
        else:
            assert len(got) == H
            for h, b in enumerate(got):
                _assert_tree_equal(b, data.batch(s + h), f"step@{s + h}")
    assert not pipe._staged


def test_prefetch_respects_depth_and_last(data):
    pipe = PeriodPrefetcher(data, H, depth=3)
    pipe.prefetch(0)
    assert sorted(pipe._staged) == [0, H, 2 * H]
    pipe.invalidate()
    pipe.prefetch(0, last=H)          # clamp: the run ends at period 2
    assert sorted(pipe._staged) == [0, H]


def test_get_drops_stale_periods_after_rollback(data):
    """A restore rolls the step counter back; get() must drop staged
    periods before the new start and rebuild on the miss."""
    pipe = PeriodPrefetcher(data, H, depth=2)
    pipe.prefetch(0)
    assert sorted(pipe._staged) == [0, H]
    got = pipe.get(2 * H)             # jumped past everything staged
    assert not pipe._staged
    _assert_tree_equal(got, stack_period_batches(data, 2 * H, H))


def test_invalidate_orphans_background_work(data):
    pipe = PeriodPrefetcher(data, H, depth=2, background=True)
    pipe.prefetch(0)
    staged = dict(pipe._staged)
    pipe.invalidate()
    assert not pipe._staged
    # orphaned slots resolve (as failures) instead of hanging a taker
    for slot in staged.values():
        assert slot.ready.wait(timeout=10.0)
    fresh = pipe.get(0)
    _assert_tree_equal(fresh, stack_period_batches(data, 0, H))


def test_background_build_errors_surface_in_get():
    class Exploding:
        n_workers = W

        def batch(self, step):
            raise RuntimeError("boom at step %d" % step)

    pipe = PeriodPrefetcher(Exploding(), H, background=True)
    pipe.prefetch(0)
    with pytest.raises(RuntimeError, match="boom"):
        pipe.get(0)


@pytest.mark.parametrize("depth,background", [(3, False), (3, True)])
def test_fused_runner_state_bitwise_across_prefetch_modes(
        data, depth, background):
    """End to end: the fused runner with a deep/background pipeline
    produces the exact TrainState of the default double buffer."""
    cfg = LMConfig(name="t", n_layers=4, d_model=48, n_heads=4,
                   n_kv_heads=2, d_ff=96, vocab=64,
                   param_dtype="float32", remat=False)
    model = DecoderLM(cfg)
    prof = analytic_profile(model.layer_costs(4, 32),
                            HardwareSpec(bandwidth=1e9, n_workers=W))
    opt = make_optimizer("adam", lr=3e-3, warmup_steps=5,
                         decay_steps=400)
    plan = build_plan("dreamddp", prof, H)
    scfg = StepConfig()
    n = 4 * H

    def run(**pf_kw):
        r = Runner(model, opt, plan, data, step_cfg=scfg,
                   run_cfg=RunnerConfig(fused_period=True, **pf_kw))
        s = init_train_state(model, opt, jax.random.PRNGKey(0), W,
                             cfg=scfg)
        return r.run(s, n), r

    base_state, base_runner = run()
    deep_state, deep_runner = run(prefetch_depth=depth,
                                  prefetch_background=background)
    _assert_tree_equal(base_state, deep_state, "state")
    assert [h["loss"] for h in base_runner.history] == \
        [h["loss"] for h in deep_runner.history]
