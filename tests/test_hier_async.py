"""AsyncSimExecutor: conformance against the heap-free reference,
bitwise-deterministic traces, work accounting, and the makespan win
over the barriered executor (the paper-level acceptance criterion)."""

import pytest

from repro.api.registry import get_strategy
from repro.hier import (AsyncConfig, AsyncSimExecutor, MergeConfig,
                        MergeOp, PushOp, check_async_library,
                        check_async_scenario)
from repro.sim import (SimExecutor, available_scenarios, get_scenario,
                       prepare_run, synthetic_profile)

H = 4
LIBRARY = available_scenarios()


def _jitter_free(name):
    sc = get_scenario(name)
    return not any(spec.jitter > 0 for spec in (sc.intra, sc.inter)
                   if spec is not None)


def _async_trace(name, periods=None, cfg=None):
    sc = get_scenario(name)
    profile = synthetic_profile()
    cluster, plan = prepare_run(sc, get_strategy("dreamddp"), H, profile)
    ex = AsyncSimExecutor(profile, plan, cluster, cfg=cfg)
    return ex, ex.run(sc.periods if periods is None else periods)


def _sync_makespan(name):
    sc = get_scenario(name)
    profile = synthetic_profile()
    cluster, plan = prepare_run(sc, get_strategy("dreamddp"), H, profile)
    return SimExecutor(profile, plan, cluster).run(sc.periods).makespan


# ---------------------------------------------------------- conformance

@pytest.mark.parametrize("name",
                         [n for n in LIBRARY if _jitter_free(n)])
def test_library_async_conformance(name):
    """Acceptance criterion: every jitter-free scenario's async spans
    agree with the heap-free greedy reference."""
    report = check_async_scenario(get_scenario(name), H=H)
    assert report.checks, f"{name}: nothing was checkable"
    assert report.ok, report.summary()
    assert report.max_rel_err < 1e-9            # stated tol is 1e-6


def test_library_sweep_helper_covers_jitter_free_scenarios():
    reports = check_async_library(H=H)
    names = {r.scenario for r in reports}
    assert names == {n for n in LIBRARY if _jitter_free(n)}
    assert all(r.ok for r in reports)


def test_jittered_scenario_rejected():
    from repro.sim import Scenario
    from repro.sim.network import LinkSpec
    sc = Scenario(name="jittery", description="", n_workers=4,
                  intra=LinkSpec(bandwidth=1e9, latency=1e-4,
                                 jitter=0.1))
    with pytest.raises(ValueError, match="jitter"):
        check_async_scenario(sc, H=H)


# ---------------------------------------------------------- determinism

@pytest.mark.parametrize("name", LIBRARY)
def test_library_async_determinism(name):
    """Acceptance criterion: identical seeds -> byte-identical traces
    (jittered scenarios included — their noise is seeded)."""
    fps = [_async_trace(name)[1].fingerprint() for _ in range(2)]
    assert fps[0] == fps[1]


# ------------------------------------------------------ work accounting

@pytest.mark.parametrize("name", LIBRARY)
def test_work_conserving_quota(name):
    ex, trace = _async_trace(name)
    meta = trace.meta
    done = sum(meta["worker_periods"].values())
    assert done == meta["target_periods"]
    # every claimed period pushed all its phase groups, and every push
    # eventually merged (single-DC scenarios merge per push batch)
    pushes = sum(isinstance(o, PushOp) for o in ex.ops)
    merged = sum(len(o.contributors) for o in ex.ops
                 if isinstance(o, MergeOp))
    assert merged == pushes
    assert meta["merges"] == sum(meta["staleness_hist"].values())
    assert meta["final_merge_time"] >= 0.0


def test_staleness_clamp_reported():
    cfg = AsyncConfig(merge=MergeConfig(staleness_beta=0.5,
                                        max_staleness=4))
    _, trace = _async_trace("straggler", cfg=cfg)
    # deep staleness occurs at W=8 x H phases; the clamp engages and the
    # reported minimum scale is exactly the floor
    assert max(int(k) for k in trace.meta["staleness_hist"]) > 4
    assert trace.meta["staleness_scale_min"] == pytest.approx(0.5 ** 4)


# --------------------------------------------------- async vs sync wins

@pytest.mark.parametrize("name", ["straggler", "churn"])
def test_async_beats_sync_on_acceptance_scenarios(name):
    """Acceptance criterion: lower simulated makespan than the
    barriered dreamddp executor at equal sample budget."""
    _, trace = _async_trace(name)
    async_makespan = max(trace.makespan, trace.meta["final_merge_time"])
    assert async_makespan < _sync_makespan(name)
