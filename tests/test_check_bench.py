"""scripts/check_bench.py: passes on the committed baselines, fails on
injected regressions (pure comparison — the fresh bench run itself is
exercised by `make ci` / the CI bench job, not tier-1)."""

import copy
import json
import os
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "scripts"))

import check_bench  # noqa: E402


@pytest.fixture
def baseline():
    with open(check_bench.BASELINE) as fh:
        return json.load(fh)


def test_committed_baseline_passes_against_itself(baseline):
    assert check_bench.compare(baseline, copy.deepcopy(baseline),
                               tol=0.5) == []


def test_improvements_pass(baseline):
    fresh = copy.deepcopy(baseline)
    for row in fresh["rows"]:
        row["speedup"] *= 3.0
    for row in fresh["paged_rows"]:
        row["goodput_ratio"] *= 2.0
    assert check_bench.compare(baseline, fresh, tol=0.5) == []


def test_injected_wallclock_regression_fails(baseline):
    fresh = copy.deepcopy(baseline)
    fresh["rows"][0]["speedup"] *= 0.3          # below the 50% band
    problems = check_bench.compare(baseline, fresh, tol=0.5)
    assert len(problems) == 1 and "speedup" in problems[0]


def test_injected_paging_regression_fails(baseline):
    fresh = copy.deepcopy(baseline)
    fresh["paged_rows"][0]["kv_bytes_ratio"] += 0.2
    fresh["paged_rows"][0]["paged"]["peak_kv_bytes"] *= 2
    problems = check_bench.compare(baseline, fresh, tol=0.5)
    assert any("kv_bytes_ratio" in p for p in problems)
    assert any("peak_kv_bytes" in p for p in problems)


def test_token_accounting_drift_fails(baseline):
    # paged decode_tokens is EOS-independent: near-exact, one token off
    # is a failure
    fresh = copy.deepcopy(baseline)
    fresh["paged_rows"][0]["decode_tokens"] += 1
    problems = check_bench.compare(baseline, fresh, tol=0.5)
    assert any("decode_tokens" in p for p in problems)
    # the EOS-picking workload's useful_tokens is banded: a tie-flip
    # nudge passes, a collapse fails
    fresh = copy.deepcopy(baseline)
    fresh["rows"][0]["useful_tokens"] += 1
    assert check_bench.compare(baseline, fresh, tol=0.5) == []
    fresh["rows"][0]["useful_tokens"] = \
        int(baseline["rows"][0]["useful_tokens"] * 0.3)
    problems = check_bench.compare(baseline, fresh, tol=0.5)
    assert any("useful_tokens" in p for p in problems)


def test_workload_change_flags_stale_baseline(baseline):
    fresh = copy.deepcopy(baseline)
    fresh["paged_rows"][0]["page_size"] *= 2
    problems = check_bench.compare(baseline, fresh, tol=0.5)
    assert any("regenerate the baseline" in p for p in problems)


def test_cli_fresh_path(tmp_path, baseline):
    good = tmp_path / "good.json"
    good.write_text(json.dumps(baseline))
    assert check_bench.main(["--only", "serve",
                             "--fresh", str(good)]) == 0
    bad = copy.deepcopy(baseline)
    bad["rows"][0]["speedup"] *= 0.1
    badf = tmp_path / "bad.json"
    badf.write_text(json.dumps(bad))
    assert check_bench.main(["--only", "serve",
                             "--fresh", str(badf)]) == 1


# ------------------------------------------------------------- traffic gate

@pytest.fixture
def traffic_baseline():
    with open(check_bench.BASELINE_TRAFFIC) as fh:
        return json.load(fh)


def test_traffic_baseline_passes_against_itself(traffic_baseline):
    assert check_bench.compare_traffic(
        traffic_baseline, copy.deepcopy(traffic_baseline), tol=0.5) == []


def test_traffic_improvements_pass(traffic_baseline):
    # faster AND lower-latency fresh runs never fail the gate
    fresh = copy.deepcopy(traffic_baseline)
    for row in fresh["rows"]:
        row["requests_per_s"] *= 2.0
        row["wall_speedup"] *= 2.0
        for key in ("ttft_p50_s", "ttft_p99_s",
                    "tpot_p50_s", "tpot_p99_s"):
            row[key] *= 0.25
    assert check_bench.compare_traffic(traffic_baseline, fresh,
                                       tol=0.5) == []


def test_traffic_throughput_regression_fails(traffic_baseline):
    fresh = copy.deepcopy(traffic_baseline)
    fresh["rows"][0]["requests_per_s"] *= 0.3
    problems = check_bench.compare_traffic(traffic_baseline, fresh,
                                           tol=0.5)
    assert len(problems) == 1 and "requests_per_s" in problems[0]


def test_traffic_latency_regression_fails(traffic_baseline):
    # latency is banded from ABOVE: tripling p99 TTFT must fail even
    # though every lower-is-worse metric is untouched
    fresh = copy.deepcopy(traffic_baseline)
    fresh["rows"][0]["ttft_p99_s"] *= 3.0
    problems = check_bench.compare_traffic(traffic_baseline, fresh,
                                           tol=0.5)
    assert len(problems) == 1 and "ttft_p99_s" in problems[0]
    assert "lower is better" in problems[0]


def test_traffic_token_counts_are_exact(traffic_baseline):
    # the seeded trace fixes every token: one off is a failure, not noise
    fresh = copy.deepcopy(traffic_baseline)
    fresh["rows"][0]["generated_tokens"] += 1
    problems = check_bench.compare_traffic(traffic_baseline, fresh,
                                           tol=0.5)
    assert any("generated_tokens" in p for p in problems)


def test_traffic_workload_change_flags_stale_baseline(traffic_baseline):
    fresh = copy.deepcopy(traffic_baseline)
    fresh["rows"][0]["rate_rps"] *= 2
    problems = check_bench.compare_traffic(traffic_baseline, fresh,
                                           tol=0.5)
    assert any("regenerate the baseline" in p for p in problems)


def test_traffic_cli_fresh_path(tmp_path, traffic_baseline):
    good = tmp_path / "traffic.json"
    good.write_text(json.dumps(traffic_baseline))
    assert check_bench.main(["--only", "traffic",
                             "--fresh-traffic", str(good)]) == 0
    bad = copy.deepcopy(traffic_baseline)
    bad["rows"][0]["tpot_p50_s"] *= 4.0
    badf = tmp_path / "bad_traffic.json"
    badf.write_text(json.dumps(bad))
    assert check_bench.main(["--only", "traffic",
                             "--fresh-traffic", str(badf)]) == 1


# --------------------------------------------------------------- train gate

@pytest.fixture
def train_baseline():
    with open(check_bench.BASELINE_TRAIN) as fh:
        return json.load(fh)


def test_train_baseline_passes_against_itself(train_baseline):
    assert check_bench.compare_train(train_baseline,
                                     copy.deepcopy(train_baseline),
                                     tol=0.5) == []


def test_train_speedup_regression_fails(train_baseline):
    fresh = copy.deepcopy(train_baseline)
    fresh["rows"][0]["best_speedup"] *= 0.3
    problems = check_bench.compare_train(train_baseline, fresh, tol=0.5)
    assert len(problems) == 1 and "best_speedup" in problems[0]
    # improvements always pass
    fresh = copy.deepcopy(train_baseline)
    for row in fresh["rows"]:
        row["speedup"] *= 2
        row["compiled_speedup"] *= 2
        row["best_speedup"] *= 2
    assert check_bench.compare_train(train_baseline, fresh,
                                     tol=0.5) == []


def test_train_workload_change_flags_stale_baseline(train_baseline):
    fresh = copy.deepcopy(train_baseline)
    fresh["rows"][0]["steps"] += 100
    problems = check_bench.compare_train(train_baseline, fresh, tol=0.5)
    assert any("regenerate the baseline" in p for p in problems)


def test_train_cli_fresh_path(tmp_path, train_baseline):
    good = tmp_path / "train.json"
    good.write_text(json.dumps(train_baseline))
    assert check_bench.main(["--only", "train",
                             "--fresh-train", str(good)]) == 0


# ----------------------------------------------------------- iteration gate

@pytest.fixture
def iter_baseline():
    with open(check_bench.BASELINE_ITER) as fh:
        return json.load(fh)


def test_iteration_baseline_passes_against_itself(iter_baseline):
    assert check_bench.compare_iteration(
        iter_baseline, copy.deepcopy(iter_baseline)) == []


def test_iteration_model_time_drift_fails(iter_baseline):
    # Table 1 is pure analytic model time: ANY drift beyond the exact
    # tolerance is a regression (profiler/scheduler/time model changed)
    fresh = copy.deepcopy(iter_baseline)
    fresh["rows"][0]["dreamddp"] *= 1.02
    problems = check_bench.compare_iteration(iter_baseline, fresh)
    assert any("dreamddp" in p for p in problems)


def test_iteration_h_change_flags_stale_baseline(iter_baseline):
    fresh = copy.deepcopy(iter_baseline)
    fresh["H"] = iter_baseline["H"] + 1
    problems = check_bench.compare_iteration(iter_baseline, fresh)
    assert any("regenerate the baseline" in p for p in problems)


def test_iteration_cli_fresh_path(tmp_path, iter_baseline):
    good = tmp_path / "iter.json"
    good.write_text(json.dumps(iter_baseline))
    assert check_bench.main(["--only", "iteration",
                             "--fresh-iteration", str(good)]) == 0


# --------------------------------------------------------------- async gate

@pytest.fixture
def async_baseline():
    with open(check_bench.BASELINE_ASYNC) as fh:
        return json.load(fh)


def test_async_baseline_passes_against_itself(async_baseline):
    assert check_bench.compare_async(
        async_baseline, copy.deepcopy(async_baseline)) == []


def test_async_baseline_beats_sync_on_acceptance_scenarios(
        async_baseline):
    # the committed baseline itself must encode the paper-level claim
    rows = {r["scenario"]: r for r in async_baseline["rows"]}
    for name in async_baseline["must_win"]:
        assert rows[name]["speedup"] > 1.0, name


def test_async_makespan_drift_fails(async_baseline):
    # deterministic SimNet replay: ANY makespan drift beyond the exact
    # tolerance means the async time model changed
    fresh = copy.deepcopy(async_baseline)
    fresh["rows"][0]["async_makespan"] *= 1.02
    problems = check_bench.compare_async(async_baseline, fresh)
    assert any("async_makespan" in p for p in problems)


def test_async_staleness_histogram_is_identity(async_baseline):
    fresh = copy.deepcopy(async_baseline)
    hist = dict(fresh["rows"][0]["staleness_hist"])
    first = next(iter(sorted(hist)))
    hist[first] += 1
    fresh["rows"][0]["staleness_hist"] = hist
    problems = check_bench.compare_async(async_baseline, fresh)
    assert any("staleness_hist" in p
               and "regenerate the baseline" in p for p in problems)


def test_async_merge_count_change_flags_stale_baseline(async_baseline):
    fresh = copy.deepcopy(async_baseline)
    fresh["rows"][0]["merges"] += 1
    problems = check_bench.compare_async(async_baseline, fresh)
    assert any("merges" in p for p in problems)


def test_async_cli_fresh_path(tmp_path, async_baseline):
    good = tmp_path / "async.json"
    good.write_text(json.dumps(async_baseline))
    assert check_bench.main(["--only", "async",
                             "--fresh-async", str(good)]) == 0
    bad = copy.deepcopy(async_baseline)
    bad["rows"][0]["speedup"] *= 1.1
    badf = tmp_path / "bad_async.json"
    badf.write_text(json.dumps(bad))
    assert check_bench.main(["--only", "async",
                             "--fresh-async", str(badf)]) == 1
