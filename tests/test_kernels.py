"""Pallas kernel sweeps (interpret mode) vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.fused_adam_sync import adamw_ref, fused_adamw_step
from repro.kernels.int8_quant import (dequantize, quantize,
                                      quantize_rows_ref)
from repro.kernels.paged_attention import (gather_pages, paged_attention,
                                           paged_attention_ref)
from repro.kernels.ssd_scan import ssd_chunk, ssd_chunk_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,sq,nq,nkv,hd", [
    (1, 128, 4, 2, 32),
    (2, 192, 8, 8, 16),     # MHA
    (1, 256, 4, 1, 64),     # MQA
    (2, 100, 6, 2, 8),      # ragged seq (padding path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, sq, nq, nkv, hd, dtype):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(sq + nq), 3)
    q = jax.random.normal(k0, (b, sq, nq, hd), dtype)
    k = jax.random.normal(k1, (b, sq, nkv, hd), dtype)
    v = jax.random.normal(k2, (b, sq, nkv, hd), dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref = attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_non_causal():
    k0 = jax.random.PRNGKey(0)
    q = jax.random.normal(k0, (1, 128, 2, 16))
    out = flash_attention(q, q, q, causal=False, block_q=64, block_k=64)
    ref = attention_ref(q, q, q, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

def _paged_case(seed, slots, nq, nkv, hd, ps, mb, dtype):
    """Random page pool + disjoint per-slot block tables + ragged
    lengths; page 0 is the (never-referenced-validly) trash page."""
    n_pages = 1 + slots * mb
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k0, (slots, nq, hd), dtype)
    kp = jax.random.normal(k1, (n_pages, ps, nkv, hd), dtype)
    vp = jax.random.normal(k2, (n_pages, ps, nkv, hd), dtype)
    rng = np.random.RandomState(seed)
    bt = rng.permutation(np.arange(1, n_pages)).reshape(slots, mb)
    # ragged valid lengths, incl. a page-boundary and a full-stream slot
    kv_len = rng.randint(1, mb * ps + 1, size=slots)
    kv_len[0] = ps
    kv_len[-1] = mb * ps
    # entries past the allocated blocks point at the trash page, like a
    # real block table (contents there must be masked out by kv_len)
    for s in range(slots):
        bt[s, -(-int(kv_len[s]) // ps):] = 0
    return (q, kp, vp, jnp.asarray(bt, jnp.int32),
            jnp.asarray(kv_len, jnp.int32))


@pytest.mark.parametrize("slots,nq,nkv,hd,ps,mb", [
    (3, 4, 2, 32, 8, 4),      # GQA
    (2, 4, 4, 16, 16, 2),     # MHA
    (4, 8, 1, 8, 8, 8),       # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(slots, nq, nkv, hd, ps, mb, dtype):
    q, kp, vp, bt, kv_len = _paged_case(slots * nq, slots, nq, nkv, hd,
                                        ps, mb, dtype)
    out = paged_attention(q, kp, vp, bt, kv_len, impl="interpret")
    ref = paged_attention_ref(q, kp, vp, bt, kv_len)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_paged_attention_windowed():
    q, kp, vp, bt, kv_len = _paged_case(7, 3, 4, 2, 16, 8, 4,
                                        jnp.float32)
    out = paged_attention(q, kp, vp, bt, kv_len, window=5,
                          impl="interpret")
    ref = paged_attention_ref(q, kp, vp, bt, kv_len, window=5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_ref_matches_contiguous_oracle():
    """Gathering the pages back to a contiguous stream and running the
    flash oracle on the valid prefix must agree with the paged ref —
    the block-table indirection is pure storage layout."""
    slots, nq, nkv, hd, ps, mb = 2, 4, 2, 16, 8, 4
    q, kp, vp, bt, kv_len = _paged_case(11, slots, nq, nkv, hd, ps, mb,
                                        jnp.float32)
    out = paged_attention_ref(q, kp, vp, bt, kv_len)
    k = gather_pages(kp, bt)
    v = gather_pages(vp, bt)
    for s in range(slots):
        n = int(kv_len[s])
        ref = attention_ref(q[s:s + 1, None], k[s:s + 1, :n],
                            v[s:s + 1, :n], causal=False)
        np.testing.assert_allclose(np.asarray(out[s]),
                                   np.asarray(ref[0, 0]),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("slots,nq,nkv,hd,ps,mb", [
    (3, 4, 2, 32, 8, 4),
    (2, 4, 4, 16, 16, 2),
    (4, 8, 1, 8, 8, 8),
])
def test_paged_attention_page_skip_bitwise(slots, nq, nkv, hd, ps, mb):
    """Stopping the innermost page loop at ``ceil(kv_len / page_size)``
    must be BITWISE identical to scanning all ``max_blocks``: a fully
    masked page contributes alpha=1 / p=0 to the online softmax, so
    skipping it (compute + clamped-index DMA) changes nothing.  The
    ``_paged_case`` lengths are ragged and include single-page,
    page-boundary and full-stream slots."""
    from repro.kernels.paged_attention.kernel import paged_attention_fwd
    q, kp, vp, bt, kv_len = _paged_case(29 + slots, slots, nq, nkv, hd,
                                        ps, mb, jnp.float32)
    # sharpen the ragged edge: a one-token slot next to a full stream
    kv_len = kv_len.at[0].set(1)
    skip = paged_attention_fwd(q, kp, vp, bt, kv_len, skip_pages=True,
                               interpret=True)
    full = paged_attention_fwd(q, kp, vp, bt, kv_len, skip_pages=False,
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(skip), np.asarray(full))
    # and the skipping kernel still matches the gather oracle
    ref = paged_attention_ref(q, kp, vp, bt, kv_len)
    np.testing.assert_allclose(np.asarray(skip), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_page_skip_windowed_bitwise():
    """Skip + sliding window compose: trailing pages are skipped, the
    window mask still clips the leading ones."""
    from repro.kernels.paged_attention.kernel import paged_attention_fwd
    q, kp, vp, bt, kv_len = _paged_case(7, 3, 4, 2, 16, 8, 4,
                                        jnp.float32)
    kw = dict(window=5, interpret=True)
    skip = paged_attention_fwd(q, kp, vp, bt, kv_len, skip_pages=True,
                               **kw)
    full = paged_attention_fwd(q, kp, vp, bt, kv_len, skip_pages=False,
                               **kw)
    np.testing.assert_array_equal(np.asarray(skip), np.asarray(full))


def test_paged_trash_page_contents_never_leak():
    """Poisoning the trash page (and every unreferenced page) with huge
    values must not change the output — masking happens before the
    softmax, not after."""
    q, kp, vp, bt, kv_len = _paged_case(13, 3, 4, 2, 16, 8, 4,
                                        jnp.float32)
    base = paged_attention(q, kp, vp, bt, kv_len, impl="ref")
    poisoned_k = kp.at[0].set(1e4)
    poisoned_v = vp.at[0].set(1e4)
    out = paged_attention(q, poisoned_k, poisoned_v, bt, kv_len,
                          impl="ref")
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))
    out_i = paged_attention(q, poisoned_k, poisoned_v, bt, kv_len,
                            impl="interpret")
    np.testing.assert_allclose(np.asarray(out_i), np.asarray(base),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fused adamw
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(64,), (300, 17), (5, 33, 9)])
@pytest.mark.parametrize("pdtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("step", [0, 100])
def test_fused_adamw_sweep(shape, pdtype, step):
    k = jax.random.PRNGKey(42)
    p = jax.random.normal(k, shape, pdtype)
    g = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    m = jax.random.normal(jax.random.PRNGKey(2), shape, jnp.float32) * 0.1
    v = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), shape,
                                  jnp.float32)) * 0.01
    got = fused_adamw_step(p, g, m, v, 1e-3, step, weight_decay=0.1)
    want = adamw_ref(p, g, m, v, lr=1e-3, step=step, weight_decay=0.1)
    for a, b in zip(got, want, strict=True):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# ssd chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,NC,H,cs,p,n", [
    (1, 2, 2, 8, 8, 8),
    (2, 3, 4, 16, 8, 16),
    (1, 1, 8, 32, 16, 8),
])
def test_ssd_chunk_sweep(B, NC, H, cs, p, n):
    k = jax.random.PRNGKey(B * NC * H)
    x = jax.random.normal(k, (B, NC, H, cs, p))
    bb = jax.random.normal(jax.random.PRNGKey(1), (B, NC, H, cs, n))
    cc = jax.random.normal(jax.random.PRNGKey(2), (B, NC, H, cs, n))
    da = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3),
                                            (B, NC, H, cs)))
    y, s = ssd_chunk(x, bb, cc, da)
    yr, sr = ssd_chunk_ref(x, bb, cc, da)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=2e-5,
                               atol=2e-5)


def test_ssd_chunk_matches_model_oracle():
    """Kernel intra-chunk part == models.mamba2.ssd_chunked with a single
    chunk and zero initial state."""
    from repro.models.mamba2 import ssd_chunked
    B, H, cs, p, n = 2, 4, 16, 8, 16
    k = jax.random.PRNGKey(7)
    x = jax.random.normal(k, (B, cs, H, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (B, cs, H)))
    a_log = jnp.log(jnp.linspace(1, 4, H))
    bmat = jax.random.normal(jax.random.PRNGKey(2), (B, cs, 1, n))
    cmat = jax.random.normal(jax.random.PRNGKey(3), (B, cs, 1, n))
    y_full, state = ssd_chunked(x, dt, a_log, bmat, cmat, chunk=cs)

    xdt = (x * dt[..., None]).reshape(B, 1, cs, H, p).swapaxes(2, 3)
    da = (dt * -jnp.exp(a_log)).reshape(B, 1, cs, H).swapaxes(2, 3)
    bq = jnp.repeat(bmat, H, 2).reshape(B, 1, cs, H, n).swapaxes(2, 3)
    cq = jnp.repeat(cmat, H, 2).reshape(B, 1, cs, H, n).swapaxes(2, 3)
    y_k, s_k = ssd_chunk(xdt, bq, cq, da)
    np.testing.assert_allclose(
        np.asarray(y_k[:, 0].swapaxes(1, 2)), np.asarray(y_full),
        rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(s_k[:, 0]), np.asarray(state), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# int8 quant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,c", [(8, 16), (77, 33), (256, 128)])
@pytest.mark.parametrize("scale", [1e-3, 1.0, 100.0])
def test_int8_quant_sweep(r, c, scale):
    x = jax.random.normal(jax.random.PRNGKey(r * c), (r, c)) * scale
    q, s = quantize(x)
    qr, sr = quantize_rows_ref(x)
    # rounding ties may differ by 1 quantum on <0.1% of elements
    # (float associativity between the padded-kernel and ref paths)
    diff = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    assert diff.max() <= 1 and (diff > 0).mean() < 1e-3
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    err = jnp.abs(dequantize(q, s) - x)
    assert float((err <= s * 0.5 + 1e-9).mean()) > 0.999
    assert float((err <= s * 0.51 + 1e-9).mean()) == 1.0


def test_int8_quant_zero_rows():
    x = jnp.zeros((4, 8))
    q, s = quantize(x)
    assert int(jnp.abs(q).max()) == 0
    np.testing.assert_allclose(np.asarray(dequantize(q, s)), 0.0)
