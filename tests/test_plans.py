"""SyncPlan construction, validation and serialization."""

import pytest

from repro.core.plans import ALGOS, SyncPlan, build_plan

from conftest import random_profile


@pytest.mark.parametrize("algo", ["flsgd", "plsgd-enp", "dreamddp"])
def test_every_unit_syncs_once_per_period(algo):
    prof = random_profile(14, seed=3)
    plan = build_plan(algo, prof, 4)
    freq = plan.sync_frequency()
    assert all(f >= 1 for f in freq)
    if algo != "dreamddp":                       # no fills -> exactly once
        assert all(f == 1 for f in freq)


def test_dreamddp_fills_raise_frequency():
    prof = random_profile(14, seed=4, bandwidth=5e10)   # compute-dominated
    plan = build_plan("dreamddp", prof, 5)
    assert plan.meta["extra_syncs"] == sum(plan.sync_frequency()) - 14


def test_ssgd_plan_shape():
    prof = random_profile(6)
    plan = build_plan("ssgd", prof, 5)
    assert plan.H == 1 and plan.phase_units == (tuple(range(6)),)
    assert not plan.is_parameter_sync


def test_flsgd_sync_in_last_phase():
    prof = random_profile(6)
    plan = build_plan("flsgd", prof, 3)
    assert plan.phase_units[0] == () and plan.phase_units[1] == ()
    assert plan.phase_units[2] == tuple(range(6))


def test_json_roundtrip():
    prof = random_profile(9, seed=5)
    plan = build_plan("dreamddp", prof, 3)
    plan2 = SyncPlan.from_json(plan.to_json())
    assert plan2 == plan
    assert plan2.fingerprint() == plan.fingerprint()


def test_missing_unit_rejected():
    with pytest.raises(ValueError, match="never synchronizes"):
        SyncPlan(algo="flsgd", H=2, n_units=3,
                 phase_units=((0,), (1,)), fill_units=((), ()))


def test_unknown_algo():
    prof = random_profile(4)
    with pytest.raises(ValueError):
        build_plan("nope", prof, 2)
    assert "dreamddp" in ALGOS
