"""Smoke test for the batched serving driver (launch/serve.py)."""

import pytest

from repro.launch import serve


def test_serve_main_smoke(capsys):
    rc = serve.main(["--arch", "qwen3-1.7b", "--smoke", "--batch", "2",
                     "--prompt-len", "8", "--gen", "3"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "prefill[2x8]" in out
    assert "ms/tok" in out
    assert "generated:" in out


def test_serve_main_single_token(capsys):
    """gen=1: no decode steps; the ms/tok division must not blow up."""
    rc = serve.main(["--arch", "qwen3-1.7b", "--smoke", "--batch", "1",
                     "--prompt-len", "4", "--gen", "1"])
    assert rc == 0
    assert "decode 0 steps" in capsys.readouterr().out


@pytest.mark.slow
def test_serve_main_audio_frontend(capsys):
    """The audio frontend wires extra inputs through prefill."""
    rc = serve.main(["--arch", "whisper-medium", "--smoke", "--batch", "1",
                     "--prompt-len", "4", "--gen", "2"])
    assert rc == 0
    assert "ms/tok" in capsys.readouterr().out
