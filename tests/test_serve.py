"""Smoke tests for the engine-based serving driver (launch/serve.py)."""

import json

import pytest

from repro.launch import serve


def test_serve_main_smoke(capsys):
    rc = serve.main(["--arch", "qwen3-1.7b", "--smoke", "--batch", "2",
                     "--prompt-len", "8", "--gen", "3"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "requests=2" in out
    assert "ms/tok" in out
    assert "ttft mean=" in out
    assert "generated:" in out


def test_serve_main_single_token(capsys):
    """gen=1: every request finishes at admission; the ms/tok division
    must not blow up."""
    rc = serve.main(["--arch", "qwen3-1.7b", "--smoke", "--batch", "1",
                     "--prompt-len", "4", "--gen", "1"])
    assert rc == 0
    assert "decode 0 steps" in capsys.readouterr().out


def test_serve_main_trace_mode(tmp_path, capsys):
    """--requests: trace-driven mixed workload with early EOS."""
    trace = [
        {"tokens": [1, 2, 3, 4], "max_new_tokens": 4},
        {"prompt_len": 7, "max_new_tokens": 6, "temperature": 0.8,
         "seed": 3},
        {"prompt_len": 4, "max_new_tokens": 8, "eos_id": 0},
    ]
    path = tmp_path / "trace.json"
    path.write_text(json.dumps(trace))
    rc = serve.main(["--arch", "qwen3-1.7b", "--smoke", "--requests",
                     str(path), "--max-batch", "2"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "requests=3" in out
    assert "slot_util=" in out


@pytest.mark.slow
def test_serve_main_audio_frontend(capsys):
    """The audio frontend wires extra inputs through Request.extra."""
    rc = serve.main(["--arch", "whisper-medium", "--smoke", "--batch", "1",
                     "--prompt-len", "4", "--gen", "2"])
    assert rc == 0
    assert "ms/tok" in capsys.readouterr().out
