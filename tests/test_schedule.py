"""Algorithm 2 scheduler: optimality vs brute force + search invariants."""

import random

import pytest

from repro.core.schedule import (brute_force_count, brute_force_schedule,
                                 dreamddp_schedule, enp_schedule)
from repro.core.time_model import Partition, objective

from conftest import random_profile


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("bandwidth", [1e8, 1e9, 2e10])
@pytest.mark.parametrize("H", [2, 3, 5])
def test_dreamddp_matches_brute_force(seed, bandwidth, H):
    """Fig. 15: Algorithm 2 finds (near-)optimal schedules.  We assert
    within 2% of the brute-force optimum across bandwidth regimes."""
    prof = random_profile(10, seed=seed, bandwidth=bandwidth)
    bf = brute_force_schedule(prof, H)
    dd = dreamddp_schedule(prof, H)
    assert dd.objective <= bf.objective * 1.02 + 1e-12
    assert dd.objective >= bf.objective - 1e-12      # bf is the optimum


@pytest.mark.parametrize("H", [2, 4, 7])
def test_partition_covers_all_layers(profile12, H):
    for fn in (dreamddp_schedule, enp_schedule):
        res = fn(profile12, H)
        assert res.partition.n_layers == len(profile12)
        assert res.partition.n_phases == H


def test_enp_equal_counts(profile12):
    res = enp_schedule(profile12, 4)
    counts = res.partition.counts
    assert max(counts) - min(counts) <= 1
    assert sum(counts) == 12


def test_search_space_bound(profile12):
    """|Omega| <= 2^min(L-H, H) (paper complexity claim)."""
    for H in (2, 3, 5, 8):
        res = dreamddp_schedule(profile12, H)
        assert res.stats.solutions <= 2 ** min(12 - H, H) + 1


def test_dreamddp_beats_or_ties_enp(profile12):
    for H in (2, 3, 5):
        dd = dreamddp_schedule(profile12, H)
        enp = enp_schedule(profile12, H)
        assert dd.objective <= enp.objective + 1e-12


def test_brute_force_count():
    assert brute_force_count(5, 2) == 6          # C(6,1)
    assert brute_force_count(10, 3) == 66        # C(12,2)


@pytest.mark.parametrize("seed", range(25))
def test_scheduler_valid_and_bounded(seed):
    """Property (seeded, ex-hypothesis): any random profile yields a valid
    partition whose Eq. 8 value is no worse than ENP and no better than
    brute force."""
    rng = random.Random(seed)
    L, H = rng.randint(2, 14), rng.randint(2, 6)
    prof = random_profile(L, seed=seed,
                          bandwidth=10 ** (8 + seed % 3))
    dd = dreamddp_schedule(prof, H)
    assert sum(dd.counts) == L and len(dd.counts) == H
    assert all(c >= 0 for c in dd.counts)
    enp = enp_schedule(prof, H)
    assert dd.objective <= enp.objective + 1e-12
    if L <= 10:
        bf = brute_force_schedule(prof, H)
        assert dd.objective >= bf.objective - 1e-12


def test_degenerate_cases(profile12):
    one = dreamddp_schedule(profile12, 1)
    assert one.counts == (12,)
    big = dreamddp_schedule(profile12, 20)      # H > L
    assert sum(big.counts) == 12
    with pytest.raises(ValueError):
        dreamddp_schedule(profile12, 0)
