"""Seeded exhaustive optimality sweep: Algorithm 2 vs brute force.

For every (L <= 8, H <= 4) and several seeded profiles/bandwidth regimes,
the pruned DFS solution set must still contain the Eq. 8 optimum
(``refine_exact=False`` returns exactly the brute-force objective), the
default refined search stays within its documented 1% re-rank cutoff, and
ENP never beats either.  Pruning counters are asserted non-zero where the
properties apply.
"""

import itertools

import pytest

from repro.core.schedule import (brute_force_count, brute_force_schedule,
                                 dreamddp_schedule, enp_schedule)

from conftest import random_profile

GRID = list(itertools.product(range(1, 9), range(1, 5)))  # (L, H)
BANDWIDTHS = (1e8, 1e9, 2e10)


@pytest.mark.parametrize("L,H", GRID)
def test_dreamddp_exact_matches_brute_force_optimum(L, H):
    """The pruning properties are lossless: min over Omega == global min."""
    for seed in range(3):
        for bw in BANDWIDTHS:
            prof = random_profile(L, seed=seed, bandwidth=bw)
            bf = brute_force_schedule(prof, H)
            dd = dreamddp_schedule(prof, H, refine_exact=False)
            assert dd.objective == pytest.approx(bf.objective, rel=1e-12), \
                (L, H, seed, bw)
            # the refined default may trade <= 1% of Eq. 8 for a better
            # exact timeline (its documented near-tie cutoff)
            ddr = dreamddp_schedule(prof, H)
            assert ddr.objective <= bf.objective * 1.01 + 1e-12
            assert ddr.objective >= bf.objective - 1e-12


@pytest.mark.parametrize("L,H", GRID)
def test_enp_never_beats_dreamddp(L, H):
    for seed in range(3):
        for bw in BANDWIDTHS:
            prof = random_profile(L, seed=seed, bandwidth=bw)
            dd = dreamddp_schedule(prof, H)
            enp = enp_schedule(prof, H)
            assert dd.objective <= enp.objective + 1e-12, (L, H, seed, bw)


@pytest.mark.parametrize("L,H", [(L, H) for L, H in GRID if H >= 2])
def test_search_stats_counters(L, H):
    for seed in range(3):
        for bw in BANDWIDTHS:
            prof = random_profile(L, seed=seed, bandwidth=bw)
            dd = dreamddp_schedule(prof, H)
            st = dd.stats
            assert st.nodes_visited > 0
            assert st.solutions >= 1
            assert st.solutions <= 2 ** min(L - min(H, L), min(H, L)) + 1
            # Property 3 fires whenever a phase opens empty mid-search
            if L >= 2:
                assert st.aloha_hits >= 1, (L, H, seed, bw)
            # >1 solution can only come from an un-pruned branch
            if st.solutions > 1:
                assert st.branch_hits >= 1


def test_all_properties_fire_somewhere():
    """Across the sweep each pruning property applies at least once —
    the Fig. 16 complexity claim is about all three biting.  Optimal
    Hiding (Property 1) needs comm fully hidden under remaining BP, so
    the sweep includes a very fast 100 GB/s link."""
    totals = {"aloha": 0, "hiding": 0, "delayed": 0, "branch": 0}
    for (L, H), seed, bw in itertools.product(GRID, range(3),
                                              BANDWIDTHS + (1e11,)):
        st = dreamddp_schedule(random_profile(L, seed=seed, bandwidth=bw),
                               H).stats
        totals["aloha"] += st.aloha_hits
        totals["hiding"] += st.optimal_hiding_hits
        totals["delayed"] += st.delayed_co_hits
        totals["branch"] += st.branch_hits
    assert all(v > 0 for v in totals.values()), totals


def test_solution_set_far_below_brute_force():
    """The point of Algorithm 2: |Omega| << C(L+H-1, H-1)."""
    prof = random_profile(8, seed=0, bandwidth=1e9)
    dd = dreamddp_schedule(prof, 4)
    assert dd.stats.solutions < brute_force_count(8, 4)
