"""Loop-aware executed-cost parser on a synthetic HLO module."""

from repro.analysis.hlo_costs import parse_module_costs

# entry -> while(trip=4) -> body contains a dot and an all-reduce;
# plus one top-level dot.
HLO = """
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %c = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups={{0,1,2,3}}, to_apply=%add, metadata={op_name="jit(f)/dot_general"}
  %one = s32[] constant(1)
  %c2 = s32[] add(%c, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%c2, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %c = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(%c, %n), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
  %arg = f32[8,16]{1,0} parameter(0)
  %w2 = f32[16,16]{1,0} constant({...})
  %d0 = f32[8,16]{1,0} dot(%arg, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,16]) tuple(%z, %d0)
  %wh = (s32[], f32[8,16]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"},"known_init_step":{"init":"0","step":"1"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_flops_multiplied_by_trip_count():
    c = parse_module_costs(HLO)
    one_dot = 2 * 8 * 16 * 16          # 4096
    # entry dot once + body dot x4
    assert c.flops == one_dot * 5
    assert c.n_dots == 2
    assert c.unknown_loops == 0


def test_collectives_multiplied():
    c = parse_module_costs(HLO)
    ars = [o for o in c.collectives.ops if o.kind == "all-reduce"]
    assert len(ars) == 4               # one static site x 4 trips
    assert all(o.group_size == 4 for o in ars)
    assert all(o.f32_dot_partial for o in ars)
    # TPU adjustment halves f32 dot-partial all-reduces
    assert c.collectives.total_wire_bytes_tpu == \
        c.collectives.total_wire_bytes / 2


def test_bytes_counts_costed_ops_only():
    c = parse_module_costs(HLO)
    # dots: (operands + result) bytes; tuples/gte/constants free
    dot_bytes = (8 * 16 + 16 * 16 + 8 * 16) * 4
    ar_bytes = 2 * 8 * 16 * 4          # operand + result
    assert c.bytes_accessed == dot_bytes * 5 + ar_bytes * 4
