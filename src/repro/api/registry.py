"""Pluggable :class:`~repro.api.strategies.SyncStrategy` registry.

The synchronization algorithm is an extension point, not a string table:
anything that can build a :class:`~repro.core.plans.SyncPlan` from a
:class:`~repro.core.profiler.LayerProfile` — and optionally pick a
:class:`~repro.core.sync_policies.SyncPolicy` for its syncs — can be
registered and then used anywhere an ``algo`` name is accepted
(:class:`~repro.api.Session`, :func:`repro.core.plans.build_plan`, the
``--algo`` CLI flag, benchmarks).

Register with the decorator form::

    from repro.api import SyncStrategy, register_strategy

    @register_strategy("my-algo")
    class MyAlgo(SyncStrategy):
        def build_plan(self, profile, H, *, fill_mode="exact"):
            ...

or imperatively for parameterized instances::

    register_strategy("dreamddp-lazy", DreamDDP(fill_default="off"))

Built-in strategies (the paper's six plus beyond-paper compositions) are
defined in :mod:`repro.api.strategies` and loaded on first lookup.
"""

from __future__ import annotations

from typing import Callable

__all__ = ["register_strategy", "get_strategy", "unregister_strategy",
           "available_strategies"]

_REGISTRY: dict[str, object] = {}
_builtins_loaded = False


def _ensure_builtins() -> None:
    global _builtins_loaded
    if not _builtins_loaded:
        _builtins_loaded = True
        from . import strategies  # noqa: F401  (registers the built-ins)


def register_strategy(name: str, strategy: object | None = None
                      ) -> object | Callable:
    """Register a strategy under ``name``; decorator and imperative forms.

    Classes are instantiated with no arguments; instances are stored as-is.
    The stored instance's ``name`` attribute is forced to the registered
    name so ``get_strategy(name).name == name`` always holds.
    """
    if not name or not isinstance(name, str):
        raise ValueError(f"strategy name must be a non-empty str: {name!r}")
    if strategy is None:
        def deco(obj):
            register_strategy(name, obj)
            return obj
        return deco

    instance = strategy() if isinstance(strategy, type) else strategy
    if not callable(getattr(instance, "build_plan", None)):
        raise TypeError(f"{instance!r} does not implement build_plan() — "
                        f"not a SyncStrategy")
    if getattr(instance, "name", None) != name:
        try:
            object.__setattr__(instance, "name", name)  # frozen dataclasses
        except (AttributeError, TypeError):
            instance.name = name
    _REGISTRY[name] = instance
    return strategy


def get_strategy(name: str):
    """Look up a registered strategy (KeyError with suggestions if absent)."""
    _ensure_builtins()
    if name not in _REGISTRY:
        raise KeyError(f"unknown sync strategy {name!r}; available: "
                       f"{available_strategies()}")
    return _REGISTRY[name]


def unregister_strategy(name: str) -> None:
    """Remove a strategy (primarily for tests)."""
    _REGISTRY.pop(name, None)


def available_strategies() -> tuple[str, ...]:
    """Sorted names of every registered strategy."""
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))
