"""Declarative Session facade: one object from job spec to trained model.

Replaces the 8-step manual wire-up (arch -> model -> HardwareSpec ->
profile -> plan -> optimizer -> state -> Runner) that every driver used to
duplicate::

    from repro.api import JobConfig, Session

    sess = Session(JobConfig(arch="granite-3-2b", algo="dreamddp",
                             workers=8, period=5, bandwidth=1e9))
    sess.fit(100)                      # profile -> plan -> train
    sess.replan(bandwidth=1e8)         # link drifted: re-solve + hot-swap
    sess.fit(100)                      # continue on the new schedule
    engine = sess.serve()              # continuous-batching ServeEngine
    sess.simulate("churn")             # replay the plan through SimNet

Everything is lazy: ``.plan`` / ``.profile()`` work without ever building
training state (analysis-only usage), and ``.fit`` builds the runner on
first call.  ``.replan(bandwidth=..., workers=..., period=..., algo=...)``
makes elasticity and bandwidth drift first-class: it re-solves the
schedule, reshards the worker axis if the membership changed, and rebuilds
the phase-specialized steps mid-run.
"""

from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Any

import jax

from ..checkpoint import CheckpointManager
from ..core.partial_sync import worker_unstack
from ..core.plans import SyncPlan
from ..core.profiler import HardwareSpec, LayerProfile, analytic_profile
from ..data import MarkovCorpus
from ..optim import make_optimizer
from ..runtime import (Runner, RunnerConfig, StepConfig, TrainState,
                       init_train_state)
from ..runtime.runner import reshard_train_state
from ..serve import EngineConfig, ServeEngine
from .registry import get_strategy

__all__ = ["JobConfig", "Session", "InferenceSession"]

PyTree = Any


@dataclass(frozen=True)
class JobConfig:
    """Declarative description of one training job (pure data)."""

    arch: str = "granite-3-2b"
    algo: str = "dreamddp"
    workers: int = 8
    period: int = 5                    # H, iterations per sync period
    bandwidth: float = 1e9             # bytes/s on the sync (slow/geo) axis
    latency: float = 5e-4
    chips_per_worker: int = 1
    batch_per_worker: int = 4
    seq: int = 64
    smoke: bool = True                 # reduced same-family config
    optimizer: str = "adam"
    lr: float = 3e-3
    warmup_steps: int = 10
    decay_steps: int = 400
    weight_decay: float = 0.0
    n_microbatches: int = 1
    compress: str | None = None        # None | "int8_ef" (legacy flag)
    outer: bool = False                # DiLoCo outer optimizer (legacy flag)
    track_divergence: bool = False
    fill_mode: str = "exact"
    seed: int = 0
    ckpt_dir: str | None = None
    ckpt_every: int = 200
    # period-fused training (runtime/DESIGN.md): execute whole H-step
    # periods with one host sync per period, prefetched data and
    # device-resident metrics.  "pipeline" keeps the per-step oracle's
    # bitwise numerics; "compiled" runs one donated lax.scan executable
    # per period (maximum fusion, ~1-2 ULP re-rounding)
    fused_period: bool = True
    period_exec: str = "pipeline"
    # depth-k data staging for the fused runner (runtime/pipeline.py):
    # batches are bitwise-identical across depths/modes; the knobs only
    # move WHEN the staging work happens
    prefetch_depth: int = 1
    prefetch_background: bool = False
    # asynchronous two-tier execution (hier/DESIGN.md): workers run
    # H-step periods on their own clocks and push layer-wise deltas to a
    # server tier that merges them with staleness-aware momentum — no
    # period-boundary barrier.  Also switched on by strategies that set
    # ``async_runtime`` (e.g. ``algo="hier-async"``).
    async_mode: bool = False
    merge_rule: str = "halos"          # "halos" | "delayed-nesterov"
    staleness_beta: float = 0.9
    merge_lr: float | None = None      # None -> 1/workers (worker mean)
    merge_momentum: float = 0.9
    max_staleness: int = 8
    pushes_per_merge: int = 1

    def replace(self, **kw) -> "JobConfig":
        return dataclasses.replace(self, **kw)


class Session:
    """Facade over profile -> schedule -> phase steps -> runner -> serving.

    ``model`` / ``data`` / ``ckpt`` keyword overrides replace the pieces
    the config would otherwise build (e.g. a custom model with
    ``layer_costs``/``unit_layout``/``loss``, or a real data pipeline).
    """

    def __init__(self, cfg: JobConfig, *, model: Any = None,
                 data: Any = None, ckpt: CheckpointManager | None = None):
        self.cfg = cfg
        self.strategy = get_strategy(cfg.algo)
        self._model = model
        self._frontend: str | None = None
        self._data = data
        self._owns_data = data is None
        self._ckpt = ckpt
        self._profile: LayerProfile | None = None
        self._plan: SyncPlan | None = None
        self._opt = None
        self._runner: Runner | None = None
        self._state: TrainState | None = None
        self._step = 0
        self._engines: dict[tuple, ServeEngine] = {}

    # ------------------------------------------------------------ lazy parts
    @property
    def model(self):
        if self._model is None:
            from ..configs import get_arch
            arch = get_arch(self.cfg.arch)
            self._model = (arch.make_smoke() if self.cfg.smoke
                           else arch.make_model())
            self._frontend = arch.frontend
        return self._model

    @property
    def hardware(self) -> HardwareSpec:
        return HardwareSpec(bandwidth=self.cfg.bandwidth,
                            latency=self.cfg.latency,
                            n_workers=self.cfg.workers,
                            chips_per_worker=self.cfg.chips_per_worker)

    def profile(self, *, refresh: bool = False) -> LayerProfile:
        """The layer-wise comm/compute profile the scheduler consumes."""
        if self._profile is None or refresh:
            costs = self.model.layer_costs(self.cfg.batch_per_worker,
                                           self.cfg.seq)
            self._profile = analytic_profile(costs, self.hardware)
        return self._profile

    @property
    def plan(self) -> SyncPlan:
        """The strategy's SyncPlan (built on first access)."""
        if self._plan is None:
            self._plan = self.strategy.build_plan(
                self.profile(), self.cfg.period,
                fill_mode=self.cfg.fill_mode)
        return self._plan

    @property
    def step_config(self) -> StepConfig:
        if self.cfg.compress is not None or self.cfg.outer:
            warnings.warn(
                "JobConfig.compress/outer are deprecated; pick the policy "
                "through the algo registry instead (algo='dreamddp-int8' "
                "for int8+EF syncs, or a strategy whose sync_policy() "
                "returns OuterOptSync for the DiLoCo outer step)",
                DeprecationWarning, stacklevel=2)
        base = StepConfig(n_microbatches=self.cfg.n_microbatches,
                          compress=self.cfg.compress, outer=self.cfg.outer,
                          track_divergence=self.cfg.track_divergence)
        # once the strategy has resolved a policy the legacy flags have
        # done their job — stop threading them through the step config
        return dataclasses.replace(
            base, policy=self.strategy.sync_policy(base), compress=None,
            outer=False)

    # ----------------------------------------------------------- async parts
    @property
    def use_async(self) -> bool:
        """Whether training runs on the async two-tier runtime."""
        return bool(self.cfg.async_mode
                    or getattr(self.strategy, "async_runtime", False))

    @property
    def merge_config(self):
        from ..hier import MergeConfig
        cfg = self.cfg
        return MergeConfig(rule=cfg.merge_rule, lr=cfg.merge_lr,
                           momentum=cfg.merge_momentum,
                           staleness_beta=cfg.staleness_beta,
                           max_staleness=cfg.max_staleness)

    @property
    def async_config(self):
        from ..hier import AsyncConfig
        return AsyncConfig(pushes_per_merge=self.cfg.pushes_per_merge,
                           merge=self.merge_config)

    def _static_scenario(self):
        """The implicit static single-DC scenario a plain async ``fit``
        runs against (the JobConfig link, no events)."""
        from ..sim.network import LinkSpec
        from ..sim.scenarios import Scenario
        cfg = self.cfg
        return Scenario(
            name="static", description="static cluster from JobConfig",
            n_workers=cfg.workers, n_datacenters=1,
            intra=LinkSpec(bandwidth=cfg.bandwidth, latency=cfg.latency,
                           jitter=0.0),
            inter=None, drift={}, events=(), periods=1, seed=cfg.seed)

    @property
    def state(self) -> TrainState:
        self._ensure_built()
        return self._state

    @property
    def history(self) -> list[dict]:
        return self._runner.history if self._runner is not None else []

    @property
    def runner(self) -> Runner:
        self._ensure_built()
        return self._runner

    # -------------------------------------------------------------- training
    def _make_data(self):
        return MarkovCorpus(vocab=self.model.cfg.vocab,
                            seq_len=self.cfg.seq,
                            batch_per_worker=self.cfg.batch_per_worker,
                            n_workers=self.cfg.workers, seed=self.cfg.seed)

    def _ensure_built(self) -> None:
        if self._runner is not None:
            return
        cfg = self.cfg
        scfg = self.step_config
        opt_kw = dict(lr=cfg.lr, warmup_steps=cfg.warmup_steps,
                      decay_steps=cfg.decay_steps)
        if cfg.weight_decay:
            opt_kw["weight_decay"] = cfg.weight_decay
        self._opt = make_optimizer(cfg.optimizer, **opt_kw)
        if self._data is None:
            self._data = self._make_data()
        if self._ckpt is None and cfg.ckpt_dir:
            self._ckpt = CheckpointManager(cfg.ckpt_dir)
        self._state = init_train_state(self.model, self._opt,
                                       jax.random.PRNGKey(cfg.seed),
                                       cfg.workers, cfg=scfg)
        if self.use_async:
            from ..hier import AsyncHierRunner, AsyncRunnerConfig
            self._runner = AsyncHierRunner(
                self.model, self._opt, self.strategy, self._data,
                profile=self.profile(), scenario=self._static_scenario(),
                H=cfg.period, step_cfg=scfg,
                run_cfg=AsyncRunnerConfig(
                    async_cfg=self.async_config,
                    ckpt_every_merges=(cfg.ckpt_every
                                       if self._ckpt is not None else 0),
                    fill_mode=cfg.fill_mode),
                ckpt=self._ckpt, seed=cfg.seed)
            return
        self._runner = Runner(self.model, self._opt, self.plan, self._data,
                              ckpt=self._ckpt, step_cfg=scfg,
                              run_cfg=RunnerConfig(
                                  ckpt_every=cfg.ckpt_every,
                                  fused_period=cfg.fused_period,
                                  period_exec=cfg.period_exec,
                                  prefetch_depth=cfg.prefetch_depth,
                                  prefetch_background=(
                                      cfg.prefetch_background)))

    def fit(self, steps: int) -> "Session":
        """Train for ``steps`` iterations (resumable; history accumulates).

        With ``JobConfig.fused_period`` (the default) whole H-step
        periods execute with a single host sync each — data prefetched
        one period ahead, metrics drained every ``log_every`` periods —
        falling back to the per-step oracle for partial periods (a
        ``replan()`` or restore landing mid-period).  Set
        ``fused_period=False`` to force the per-step path throughout.

        Under the async runtime (``async_mode`` or an ``async_runtime``
        strategy like ``hier-async``) ``steps`` must be a whole number
        of periods; workers run them on their own virtual clocks and the
        trained artifact is the global server model, broadcast back into
        the worker-stacked ``state`` view for ``serve()``.  The async op
        log is a deterministic function of the total period count, so a
        session runs exactly one async timeline — call ``fit`` once.
        """
        self._ensure_built()
        if self.use_async:
            H = self.cfg.period
            if steps % H:
                raise ValueError(
                    f"async fit advances whole periods: steps={steps} is "
                    f"not a multiple of H={H}")
            self._runner.run((self._step + steps) // H)
            self._step += steps
            self._state = self._state._replace(
                params=self._runner.stacked_params(self.cfg.workers))
            return self
        self._state = self._runner.run(self._state, steps,
                                       start_step=self._step)
        self._step += steps
        return self

    # ------------------------------------------------------------- replan
    def replan(self, *, bandwidth: float | None = None,
               latency: float | None = None, workers: int | None = None,
               period: int | None = None, algo: str | None = None,
               fill_mode: str | None = None, data: Any = None) -> SyncPlan:
        """Re-solve the schedule for a changed link/membership/algorithm.

        The schedule is data: a bandwidth drift or an elastic membership
        change only requires a cheap re-profile and a new partition search.
        If training state exists, the worker axis is resharded (replicas
        averaged and re-broadcast — a synchronization point, so Lemma 4
        survives) and the phase-specialized steps are rebuilt in place.

        A session built with a custom ``data=`` override must supply a
        replacement via ``data=`` here when ``workers`` changes — batch
        shapes carry the worker axis, so keeping the old source would
        feed mis-shaped batches into the resharded steps.
        """
        updates: dict[str, Any] = {}
        for key, val in (("bandwidth", bandwidth), ("latency", latency),
                         ("workers", workers), ("period", period),
                         ("algo", algo), ("fill_mode", fill_mode)):
            if val is not None:
                updates[key] = val
        if self._runner is not None and self.use_async:
            raise ValueError(
                "replan() is not supported on a running async session: "
                "the op-log replay pins one timeline.  Express membership "
                "and bandwidth changes as scenario events instead "
                "(WorkerJoin/WorkerLeave/BandwidthDrift).")
        old_workers = self.cfg.workers
        old_strategy = self.strategy
        workers_changed = workers is not None and workers != old_workers
        # validate before mutating any session state, so a failed replan
        # leaves the session consistent
        new_strategy = get_strategy(algo) if algo is not None \
            else self.strategy
        if workers_changed and data is None and not self._owns_data and \
                self._data is not None:
            raise ValueError(
                "replan(workers=...) on a session with a custom data "
                "source: pass a replacement via replan(..., data=...) "
                "matching the new worker count")
        self.cfg = self.cfg.replace(**updates)
        self.strategy = new_strategy

        # cheap re-profile (paper §6): comm times re-derived for the link
        self._profile = self.profile().with_bandwidth(
            self.cfg.bandwidth, self.cfg.latency, self.cfg.workers)
        self._plan = self.strategy.build_plan(
            self._profile, self.cfg.period, fill_mode=self.cfg.fill_mode)

        if data is not None:
            self._data = data
            self._owns_data = False
            if self._runner is not None:
                self._runner.data = data

        if self._runner is not None:
            scfg = self.step_config
            if workers_changed:
                self._state = reshard_train_state(self._state,
                                                  self.cfg.workers)
                if self._owns_data:
                    self._data = self._make_data()
                    self._runner.data = self._data
            if algo is not None and type(self.strategy) is not \
                    type(old_strategy):
                # the sync policy may differ; re-derive its aux state
                policy = scfg.policy
                ef, outer = policy.init_state(self._state.params)
                self._state = self._state._replace(ef=ef, outer=outer)
            self._runner.step_cfg = scfg
            self._runner.replan(self._plan)
        return self._plan

    # ----------------------------------------------------------- simulation
    def simulate(self, scenario, *, periods: int | None = None,
                 replan: bool = True, n_channels: int = 1,
                 profile: LayerProfile | None = None,
                 mode: str | None = None):
        """Replay this job's schedule through a virtual geo-cluster.

        ``scenario`` is a :class:`repro.sim.Scenario` or a library name
        (``"drifting-bandwidth"``, ``"churn"``, ...).  Pure analysis: no
        training state is built.  The strategy's plan is solved against
        the scenario's network at t=0 and replayed by
        :class:`repro.sim.SimExecutor`; with ``replan=True`` (the
        default) every schedule-relevant event — bandwidth drift, link
        degradation, elastic join/leave — triggers a re-solve at the
        next period boundary, exactly like a live ``.replan()`` call.

        ``mode`` picks the execution model: ``"sync"`` replays the
        barriered period executor, ``"async"`` the two-tier
        :class:`repro.hier.AsyncSimExecutor` (per-worker virtual clocks,
        staleness-aware merges; ``replan``/``n_channels`` don't apply).
        Default follows the session: async when :attr:`use_async`.

        ``profile`` substitutes an external :class:`LayerProfile` for the
        model-derived one (benchmarks replay paper models this way
        without building the model).

        Returns a :class:`repro.sim.SimReport` (trace + plan history).
        """
        from ..sim import (REPLAN_EVENTS, SimExecutor, SimReport,
                           get_scenario, prepare_run)
        if isinstance(scenario, str):
            scenario = get_scenario(scenario)
        base = self.profile() if profile is None else profile
        if mode is None:
            mode = "async" if self.use_async else "sync"
        if mode not in ("sync", "async"):
            raise ValueError(f"mode must be 'sync' or 'async', got {mode!r}")
        if mode == "async":
            from ..hier import AsyncSimExecutor
            cluster, plan = prepare_run(scenario, self.strategy,
                                        self.cfg.period, base,
                                        fill_mode=self.cfg.fill_mode)
            ex = AsyncSimExecutor(base, plan, cluster,
                                  cfg=self.async_config)
            trace = ex.run(periods if periods is not None
                           else scenario.periods)
            return SimReport(scenario=scenario.name, trace=trace,
                             plans=[(0, plan)])
        cluster, plan = prepare_run(scenario, self.strategy,
                                    self.cfg.period, base,
                                    fill_mode=self.cfg.fill_mode)
        ex = SimExecutor(base, plan, cluster, n_channels=n_channels)
        plans = [(0, plan)]

        def on_events(executor, fired):
            if not replan or not any(isinstance(e, REPLAN_EVENTS)
                                     for e in fired):
                return None
            eff = cluster.effective_profile(base, executor.clock)
            new_plan = self.strategy.build_plan(
                eff, executor.plan.H, fill_mode=self.cfg.fill_mode)
            if new_plan.fingerprint() == executor.plan.fingerprint():
                return None
            plans.append((executor.iteration // executor.plan.H,
                          new_plan))
            return new_plan

        trace = ex.run(periods if periods is not None else scenario.periods,
                       on_events=on_events)
        return SimReport(scenario=scenario.name, trace=trace, plans=plans)

    # ------------------------------------------------------------- serving
    def serve(self, *, worker: int = 0,
              config: EngineConfig | None = None) -> ServeEngine:
        """The inference path: a continuous-batching :class:`ServeEngine`
        over one synchronized replica.

        Engines are memoized per ``(frontend, engine config, worker)``:
        repeated ``serve()`` calls after more ``fit()`` reuse the compiled
        prefill/decode executables and the KV arena, only swapping in the
        fresh params — the old per-call re-jit is gone.
        """
        model = self.model                  # also resolves self._frontend
        if self._state is not None:
            params = worker_unstack(self._state.params, worker)
        else:
            params = model.init(jax.random.PRNGKey(self.cfg.seed))
        cfg = config or EngineConfig()
        key = (self._frontend, cfg, worker)
        engine = self._engines.get(key)
        if engine is None:
            engine = ServeEngine(model, params, cfg,
                                 frontend=self._frontend)
            self._engines[key] = engine
        else:
            if engine.has_work:
                raise RuntimeError(
                    "serve() would reset an engine with queued/in-flight "
                    "requests; drain() the previous handle first (or "
                    "serve() with a different EngineConfig)")
            engine.reset(params=params)
        return engine


class InferenceSession:
    """Deprecated shim over :class:`~repro.serve.ServeEngine`.

    The old ad-hoc greedy loop is gone; this keeps the ``generate(tokens,
    max_new_tokens, *extra)`` call signature alive by delegating to an
    engine (array convenience mode: greedy, no EOS exit — identical
    semantics, same tokens).  New code should use ``Session.serve()``
    directly, which returns the engine.
    """

    def __init__(self, model, params, *, frontend: str | None = None,
                 config: EngineConfig | None = None):
        warnings.warn(
            "InferenceSession is deprecated: Session.serve() now returns "
            "a repro.serve.ServeEngine (continuous batching, EOS exit, "
            "sampling, stats) — use it directly",
            DeprecationWarning, stacklevel=2)
        self.model = model
        self.params = params
        self.frontend = frontend
        self._config = config
        self.engine: ServeEngine | None = None

    def generate(self, tokens: jax.Array, max_new_tokens: int = 16,
                 *extra) -> jax.Array:
        """Prefill ``tokens`` ``[B, S]`` then decode greedily."""
        b, s = tokens.shape
        prefix = extra[0].shape[1] if (self.frontend == "vision"
                                       and extra) else 0
        need = prefix + s + max(max_new_tokens, 0)
        # the old loop sized its cache per call; grow max_seq to match so
        # any request the old loop handled still works
        if self.engine is None or need > self.engine.config.max_seq:
            base = self._config or EngineConfig()
            cfg = dataclasses.replace(base,
                                      max_seq=max(base.max_seq, need))
            self.engine = ServeEngine(self.model, self.params, cfg,
                                      frontend=self.frontend)
        self.engine.reset(params=self.params)
        return self.engine.generate(tokens, max_new_tokens, *extra)
