"""repro.api — the declarative front door to the DreamDDP reproduction.

Two ideas:

* the synchronization algorithm is a **pluggable strategy**
  (:class:`SyncStrategy` + :func:`register_strategy`), not a string
  special-cased across the codebase;
* a training job is **data** (:class:`JobConfig`), and :class:`Session`
  turns it into a running system — ``.fit(n)``, ``.profile()``, ``.plan``,
  ``.replan(bandwidth=..., workers=...)``, ``.serve()``, and
  ``.simulate(scenario)`` (replay through the :mod:`repro.sim`
  geo-cluster simulator, no cluster required).

Quick start::

    from repro.api import JobConfig, Session
    Session(JobConfig(arch="granite-3-2b", algo="dreamddp",
                      workers=8, period=5)).fit(100)

Custom strategy::

    from repro.api import SyncStrategy, register_strategy

    @register_strategy("my-algo")
    class MyAlgo(SyncStrategy):
        def build_plan(self, profile, H, *, fill_mode="exact"):
            ...  # return a repro.core.plans.SyncPlan
"""

from ..core.sync_policies import (Int8EFSync, MeanSync, OuterOptSync,
                                  SyncPolicy, resolve_policy)
from ..serve import (Completion, EngineConfig, EngineStats, Request,
                     SamplingParams, ServeEngine)
from .registry import (available_strategies, get_strategy,
                       register_strategy, unregister_strategy)
from .session import InferenceSession, JobConfig, Session
from .strategies import SyncStrategy

__all__ = [
    "JobConfig", "Session", "InferenceSession",
    "SyncStrategy", "register_strategy", "get_strategy",
    "unregister_strategy", "available_strategies",
    "SyncPolicy", "MeanSync", "Int8EFSync", "OuterOptSync",
    "resolve_policy",
    # serving (re-exported from repro.serve)
    "ServeEngine", "EngineConfig", "Request", "SamplingParams",
    "Completion", "EngineStats",
]
