"""Built-in :class:`SyncStrategy` implementations.

A strategy owns the three decisions the old stringly-typed dispatch spread
across ``core/plans.py`` and ``runtime/step.py``:

1. **plan construction** — :meth:`SyncStrategy.build_plan` turns a
   :class:`~repro.core.profiler.LayerProfile` into a
   :class:`~repro.core.plans.SyncPlan`;
2. **communication mode** — ``comm`` (gradients vs. parameters), recorded
   on the plan so the runtime never inspects algorithm names;
3. **sync hook** — :meth:`SyncStrategy.sync_policy` picks the
   :class:`~repro.core.sync_policies.SyncPolicy` applied at each phase
   (plain mean / int8+EF / outer optimizer).

The paper's algorithms (ssgd, wfbp, ascwfbp, flsgd, plsgd-enp, dreamddp,
dreamddp-bf) are registered here, plus two beyond-string compositions that
prove the registry is a real extension point:

* ``dreamddp-int8`` — the DreamDDP schedule with int8+error-feedback
  compressed syncs (FusionLLM-style adaptive compression, arXiv
  2410.12707);
* ``hier-2tier`` — a HALoS-inspired hierarchical two-tier schedule (arXiv
  2506.04531): the output-most "hot" tier synchronizes every phase (those
  layers accumulate gradient drift fastest and are cheap to ship early in
  BP order), while the remaining "cold" tier is balanced across the period
  like PLSGD-ENP.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

from ..core.bubble_fill import fill_bubbles
from ..core.plans import (GRADIENTS, PARAMETERS, SyncPlan,
                          plan_from_partition)
from ..core.profiler import LayerProfile
from ..core.schedule import (brute_force_schedule, dreamddp_schedule,
                             enp_schedule)
from ..core.sync_policies import Int8EFSync, SyncPolicy, resolve_policy
from .registry import register_strategy

__all__ = ["SyncStrategy", "GradientSync", "FLSGD", "PLSGDEqualNumber",
           "DreamDDP", "DreamDDPInt8", "HierarchicalTwoTier", "HierAsync"]


class SyncStrategy:
    """One synchronization algorithm (subclass or duck-type this).

    Subclasses must implement :meth:`build_plan`; ``comm`` defaults to
    parameter synchronization and :meth:`sync_policy` to the StepConfig
    resolution (plain mean unless the config asks for int8/outer).
    """

    name: str = ""
    comm: str = PARAMETERS

    def build_plan(self, profile: LayerProfile, H: int, *,
                   fill_mode: str = "exact") -> SyncPlan:
        raise NotImplementedError

    def sync_policy(self, cfg: Any) -> SyncPolicy:
        """The sync hook for this strategy given a StepConfig."""
        return resolve_policy(cfg)

    def describe(self) -> str:
        return (self.__doc__ or "").strip().splitlines()[0] if self.__doc__ \
            else self.name


@dataclass(frozen=True)
class GradientSync(SyncStrategy):
    """Classic DDP: gradients worker-averaged every iteration (H == 1).

    ``ssgd`` / ``wfbp`` / ``ascwfbp`` share this SPMD execution and differ
    only in the simulated time model (overlap / channel count).
    """

    name: str = "ssgd"
    comm = GRADIENTS

    def build_plan(self, profile, H, *, fill_mode="exact"):
        n = len(profile)
        return SyncPlan(algo=self.name, comm=GRADIENTS, H=1, n_units=n,
                        phase_units=(tuple(range(n)),), fill_units=((),),
                        unit_names=tuple(c.name for c in profile.layers),
                        meta={"bandwidth": profile.hw.bandwidth,
                              "n_workers": profile.hw.n_workers})


@register_strategy("flsgd")
@dataclass(frozen=True)
class FLSGD(SyncStrategy):
    """Full local SGD: all parameters averaged in the period's last phase."""

    name: str = "flsgd"

    def build_plan(self, profile, H, *, fill_mode="exact"):
        n = len(profile)
        phases = tuple(() for _ in range(H - 1)) + (tuple(range(n)),)
        return SyncPlan(algo=self.name, comm=PARAMETERS, H=H, n_units=n,
                        phase_units=phases,
                        fill_units=tuple(() for _ in range(H)),
                        unit_names=tuple(c.name for c in profile.layers),
                        meta={"bandwidth": profile.hw.bandwidth,
                              "n_workers": profile.hw.n_workers})


@register_strategy("plsgd-enp")
@dataclass(frozen=True)
class PLSGDEqualNumber(SyncStrategy):
    """Partial local SGD with equal-number partitioning (ENP baseline)."""

    name: str = "plsgd-enp"

    def build_plan(self, profile, H, *, fill_mode="exact"):
        return plan_from_partition(self.name, profile, H,
                                   enp_schedule(profile, H), None)


@dataclass(frozen=True)
class DreamDDP(SyncStrategy):
    """DreamDDP: Algorithm-2 partition search + §3.4 bubble fills."""

    name: str = "dreamddp"
    scheduler: Callable = dreamddp_schedule

    def build_plan(self, profile, H, *, fill_mode="exact"):
        res = self.scheduler(profile, H)
        fills = fill_bubbles(profile, res.partition, mode=fill_mode)
        return plan_from_partition(self.name, profile, H, res, fills)


@register_strategy("dreamddp-int8")
@dataclass(frozen=True)
class DreamDDPInt8(DreamDDP):
    """DreamDDP schedule composed with int8+EF compressed syncs."""

    name: str = "dreamddp-int8"

    def sync_policy(self, cfg):
        return Int8EFSync()


@register_strategy("hier-async")
@dataclass(frozen=True)
class HierAsync(DreamDDP):
    """DreamDDP schedule on the async two-tier runtime (no barriers).

    The plan's per-phase unit groups become the push granularity of
    :class:`repro.hier.AsyncHierRunner`: workers run whole periods
    locally and stream layer-wise deltas to the server tier, which
    merges them with staleness-aware momentum.  ``async_runtime`` makes
    :class:`~repro.api.session.Session` pick the async runner and
    :meth:`~repro.api.session.Session.simulate` default to
    ``mode="async"``.
    """

    name: str = "hier-async"
    async_runtime: bool = True


@register_strategy("hier-2tier")
@dataclass(frozen=True)
class HierarchicalTwoTier(SyncStrategy):
    """HALoS-style two-tier schedule: hot tier every phase, cold tier 1/H.

    The output-most ``hot_fraction`` of units (largest per-step drift,
    earliest available in BP order) are synchronized in **every** phase;
    the remaining units are split into H balanced contiguous chunks, one
    per phase.  Every unit still syncs at least once per period, so
    Lemma 4's bounded-staleness argument applies with ``H_l <= H``.
    """

    name: str = "hier-2tier"
    hot_fraction: float = 0.25

    def build_plan(self, profile, H, *, fill_mode="exact"):
        n = len(profile)
        n_hot = max(1, round(n * self.hot_fraction)) if H > 1 else 0
        hot = tuple(range(n - n_hot, n))
        cold = list(range(n - n_hot))
        phase_units, fill_units = [], []
        for h in range(H):
            lo = (len(cold) * h) // H
            hi = (len(cold) * (h + 1)) // H
            phase_units.append(tuple(sorted(set(cold[lo:hi]) | set(hot))))
            # hot repeats beyond their first appearance are supplementary
            fill_units.append(hot if h > 0 else ())
        return SyncPlan(
            algo=self.name, comm=PARAMETERS, H=H, n_units=n,
            phase_units=tuple(phase_units), fill_units=tuple(fill_units),
            unit_names=tuple(c.name for c in profile.layers),
            meta={"hot_units": list(hot),
                  "extra_syncs": (H - 1) * len(hot),
                  "partition_counts": [len(u) for u in phase_units],
                  "bandwidth": profile.hw.bandwidth,
                  "n_workers": profile.hw.n_workers})


# Parameterized instances (same class, different name/config):
register_strategy("ssgd", GradientSync("ssgd"))
register_strategy("wfbp", GradientSync("wfbp"))
register_strategy("ascwfbp", GradientSync("ascwfbp"))
register_strategy("dreamddp", DreamDDP())
# brute-force reference schedule (paper Fig. 15)
register_strategy("dreamddp-bf",
                  DreamDDP(name="dreamddp-bf",
                           scheduler=brute_force_schedule))
