from .pipeline import PeriodPrefetcher, stack_period_batches
from .runner import Runner, RunnerConfig
from .step import (StepConfig, TrainState, init_train_state,
                   make_decode_step, make_period_step, make_phase_steps,
                   make_prefill_step, make_slot_decode_step,
                   make_slot_prefill_step, make_slot_refeed_step,
                   make_train_step)

__all__ = ["PeriodPrefetcher", "Runner", "RunnerConfig", "StepConfig",
           "TrainState", "init_train_state", "make_decode_step",
           "make_period_step", "make_phase_steps", "make_prefill_step",
           "make_slot_decode_step", "make_slot_prefill_step",
           "make_slot_refeed_step", "make_train_step",
           "stack_period_batches"]
