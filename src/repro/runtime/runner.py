"""Fault-tolerant training runner.

Wraps the phase-specialized steps with the operational machinery a
1000+-node deployment needs, scaled to this container:

* **checkpoint/restart** — periodic async checkpoints; any exception inside
  a step restores the last checkpoint and replays (bounded retries);
* **straggler mitigation** — a sync phase whose wall-clock exceeds
  ``deadline_factor x`` the running median is *skipped* (executed as a pure
  local step) and its layer units are re-queued into a makeup sync at the
  next period boundary.  Sound because partial-sync tolerates per-layer
  staleness <= 2H (Lemma 4 with ``H_l <= 2H``);
* **elasticity** — ``restore(n_workers=...)`` reshapes the worker axis via
  :func:`repro.checkpoint.reshard_workers` and re-solves the SyncPlan for
  the new worker count (the schedule is data, not code).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager, reshard_workers
from ..core.plans import SyncPlan, local_plan
from ..core.partial_sync import sync_units
from .step import StepConfig, TrainState, make_train_step

__all__ = ["RunnerConfig", "Runner", "reshard_train_state"]

PyTree = Any


def reshard_train_state(state: TrainState, n_workers: int) -> TrainState:
    """Map a worker-stacked TrainState onto a new worker count.

    Replicas are averaged and re-broadcast (see
    :func:`repro.checkpoint.reshard_workers`) — a synchronization point,
    so Lemma 4's bounded-staleness argument survives membership changes.
    Shared by :meth:`Runner.restore_elastic` and ``Session.replan``.
    """
    return TrainState(
        params=reshard_workers(state.params, n_workers),
        opt_state=reshard_workers(state.opt_state, n_workers),
        step=state.step,
        ef=None if state.ef is None else
        reshard_workers(state.ef, n_workers),
        outer=None if state.outer is None else jax.tree.map(
            lambda x: reshard_workers(x, n_workers), state.outer),
    )


@dataclass(frozen=True)
class RunnerConfig:
    ckpt_every: int = 200
    max_retries: int = 3
    deadline_factor: float = 3.0       # straggler: skip sync if > 3x median
    min_history: int = 8               # steps before deadlines activate
    log_every: int = 10


@dataclass
class Runner:
    model: Any
    optimizer: Any
    plan: SyncPlan
    data: Any                           # .batch(step) -> pytree
    ckpt: CheckpointManager | None = None
    step_cfg: StepConfig = field(default_factory=StepConfig)
    run_cfg: RunnerConfig = field(default_factory=RunnerConfig)

    def __post_init__(self):
        self._build_steps()
        self._times: list[float] = []
        self.history: list[dict] = []
        self.pending_units: set[int] = set()
        self.skipped_syncs = 0
        self.retries = 0

    def _build_steps(self) -> None:
        """(Re)compile the phase-specialized steps for the current plan."""
        self._steps = [jax.jit(make_train_step(
            self.model, self.optimizer, self.plan, h, cfg=self.step_cfg))
            for h in range(self.plan.H)]
        # a pure local step (no sync) for straggler-skipped phases
        self._local = jax.jit(make_train_step(
            self.model, self.optimizer, local_plan(self.plan.n_units), 0,
            cfg=self.step_cfg))
        self._makeup_cache: dict[tuple, Callable] = {}

    def replan(self, new_plan: SyncPlan) -> None:
        """Hot-swap the schedule mid-run (elasticity / bandwidth drift).

        Pending straggler make-ups are kept — unit ids refer to the same
        network-order layout — but the phase executables are rebuilt so
        every subsequent step runs the new partition.
        """
        if new_plan.n_units != self.plan.n_units:
            raise ValueError(
                f"replan changed the unit count ({self.plan.n_units} -> "
                f"{new_plan.n_units}); the model layout must be stable")
        self.plan = new_plan
        self._build_steps()

    # ------------------------------------------------------------------ util
    def _median_time(self) -> float:
        xs = sorted(self._times[-64:])
        return xs[len(xs) // 2] if xs else float("inf")

    def _makeup_step(self, units: tuple[int, ...]):
        if units not in self._makeup_cache:
            layout = self.model.unit_layout()

            def step(state, batch):
                new_state, m = self._local(state, batch)
                return new_state._replace(
                    params=sync_units(new_state.params, list(units),
                                      layout)), m

            self._makeup_cache[units] = step
        return self._makeup_cache[units]

    # ------------------------------------------------------------------- run
    def run(self, state: TrainState, n_steps: int, *,
            start_step: int = 0,
            inject_failure_at: int | None = None,
            inject_straggler_at: tuple[int, float] | None = None
            ) -> TrainState:
        """Train; ``inject_*`` hooks are for fault-tolerance tests."""
        r = start_step
        while r < start_step + n_steps:
            phase = self.plan.phase_of_iteration(r)
            batch = self.data.batch(r)
            t0 = time.perf_counter()
            try:
                if inject_failure_at == r:
                    inject_failure_at = None
                    raise RuntimeError("injected node failure")

                if self.pending_units and phase == 0:
                    fn = self._makeup_step(tuple(sorted(self.pending_units)))
                    self.pending_units.clear()
                else:
                    fn = self._steps[phase]
                state, metrics = fn(state, batch)
                jax.block_until_ready(metrics["loss"])
            except Exception:                         # noqa: BLE001
                # Only swallow the failure if a checkpoint actually exists
                # to restart from — otherwise a restore FileNotFoundError
                # would mask the real error.  latest_step() itself may
                # raise (it surfaces a failed async save); never let that
                # replace the training exception.
                can_restore = False
                if self.ckpt is not None and \
                        self.retries < self.run_cfg.max_retries:
                    try:
                        can_restore = self.ckpt.latest_step() is not None
                    except Exception:                 # noqa: BLE001
                        can_restore = False
                if not can_restore:
                    raise
                self.retries += 1
                r0, state, _ = self._restore_into(state)
                r = r0
                continue

            dt = time.perf_counter() - t0
            if inject_straggler_at is not None and inject_straggler_at[0] == r:
                dt += inject_straggler_at[1]
                inject_straggler_at = None
            # straggler policy: if this was a sync phase and it blew the
            # deadline, requeue its units and remember to skip-equivalent
            # (the sync already happened here; the policy matters when the
            # *link* stalls — we model it by requeueing the NEXT occurrence)
            if (len(self._times) >= self.run_cfg.min_history
                    and self.plan.is_parameter_sync
                    and self.plan.units_for_phase(phase)
                    and dt > self.run_cfg.deadline_factor
                    * self._median_time()):
                self.pending_units.update(self.plan.units_for_phase(phase))
                self.skipped_syncs += 1
            self._times.append(dt)

            self.history.append({"step": r, "phase": phase,
                                 "time": dt,
                                 **{k: float(v) for k, v in
                                    metrics.items()}})
            if self.ckpt is not None and (r + 1) % \
                    self.run_cfg.ckpt_every == 0:
                self.ckpt.save(r + 1, state,
                               meta={"plan": self.plan.to_json()})
            r += 1
        if self.ckpt is not None:
            self.ckpt.wait()
        return state

    def _restore_into(self, template: TrainState):
        step, state, meta = self.ckpt.restore(template)
        return step, state, meta

    def restore_elastic(self, template: TrainState, n_workers: int,
                        new_plan: SyncPlan) -> tuple[int, TrainState]:
        """Restore onto a different worker count (elastic membership)."""
        step, state, _ = self.ckpt.restore(template)
        state = reshard_train_state(state, n_workers)
        self.replan(new_plan)
        return int(state.step), state
