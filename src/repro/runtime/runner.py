"""Fault-tolerant training runner.

Wraps the phase-specialized steps with the operational machinery a
1000+-node deployment needs, scaled to this container:

* **period fusion** — with ``RunnerConfig.fused_period`` the runner
  executes one donated executable per whole synchronization period
  (:func:`repro.runtime.step.make_period_step`) instead of one jitted
  call per iteration: phase boundaries stop being host round-trips,
  XLA's latency-hiding scheduler can float phase *h*'s parameter
  all-reduce under phase *h+1*'s compute, metrics stay device-resident
  until the ``log_every`` drain, and the next period's data is
  prefetched while the current one runs (see DESIGN.md here).  The
  per-step path remains the oracle — bitwise-identical ``TrainState``;
* **checkpoint/restart** — periodic async checkpoints; any exception inside
  a step restores the last checkpoint and replays (bounded retries);
* **straggler mitigation** — a sync phase (per-step path) or period
  (fused path) whose wall-clock exceeds ``deadline_factor x`` the
  running median has its layer units re-queued into a makeup sync at
  the next period boundary.  Sound because partial-sync tolerates
  per-layer staleness <= 2H (Lemma 4 with ``H_l <= 2H``);
* **elasticity** — ``restore(n_workers=...)`` reshapes the worker axis via
  :func:`repro.checkpoint.reshard_workers` and re-solves the SyncPlan for
  the new worker count (the schedule is data, not code).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager, reshard_workers
from ..core.plans import SyncPlan, local_plan
from ..lint import hot_path
from .pipeline import PeriodPrefetcher
from .step import (StepConfig, TrainState, compose_makeup_step,
                   make_period_step, make_train_step)

__all__ = ["RunnerConfig", "Runner", "reshard_train_state"]

PyTree = Any


def reshard_train_state(state: TrainState, n_workers: int) -> TrainState:
    """Map a worker-stacked TrainState onto a new worker count.

    Replicas are averaged and re-broadcast (see
    :func:`repro.checkpoint.reshard_workers`) — a synchronization point,
    so Lemma 4's bounded-staleness argument survives membership changes.
    Shared by :meth:`Runner.restore_elastic` and ``Session.replan``.
    """
    return TrainState(
        params=reshard_workers(state.params, n_workers),
        opt_state=reshard_workers(state.opt_state, n_workers),
        step=state.step,
        ef=None if state.ef is None else
        reshard_workers(state.ef, n_workers),
        outer=None if state.outer is None else jax.tree.map(
            lambda x: reshard_workers(x, n_workers), state.outer),
    )


@dataclass(frozen=True)
class RunnerConfig:
    ckpt_every: int = 200
    max_retries: int = 3
    deadline_factor: float = 3.0       # straggler: skip sync if > 3x median
    min_history: int = 8               # steps/periods before deadlines fire
    log_every: int = 10                # fused: periods between metric drains
    fused_period: bool = False         # period-granularity execution
    # how a fused period is executed (see DESIGN.md):
    #  "pipeline" — H donated per-phase executables dispatched back-to-back
    #               with ONE host sync per period; bitwise-identical to the
    #               per-step oracle by construction (same executables)
    #  "compiled" — one donated make_period_step executable (lax.scan over
    #               the pre-batched period); maximum fusion — XLA may
    #               re-round across phase boundaries (~1-2 ULP vs oracle)
    period_exec: str = "pipeline"
    # depth-k data staging (pipeline.py): how many future periods to keep
    # staged, and whether a daemon thread builds them off the train thread.
    # Batch VALUES are bitwise-identical across depths/modes by
    # construction — pure function of the step index.
    prefetch_depth: int = 1
    prefetch_background: bool = False


@dataclass
class Runner:
    model: Any
    optimizer: Any
    plan: SyncPlan
    data: Any                           # .batch(step) -> pytree
    ckpt: CheckpointManager | None = None
    step_cfg: StepConfig = field(default_factory=StepConfig)
    run_cfg: RunnerConfig = field(default_factory=RunnerConfig)

    def __post_init__(self):
        self._build_steps()
        self._times: list[float] = []
        self.period_times: list[float] = []
        self.history: list[dict] = []
        self.pending_units: set[int] = set()
        self.skipped_syncs = 0
        self.retries = 0
        self._undrained: list[tuple[int, float, dict]] = []

    def _build_steps(self) -> None:
        """(Re)compile the phase-specialized steps for the current plan."""
        self._steps = [jax.jit(make_train_step(
            self.model, self.optimizer, self.plan, h, cfg=self.step_cfg))
            for h in range(self.plan.H)]
        # a pure local step (no sync) for straggler-skipped phases
        self._local = jax.jit(make_train_step(
            self.model, self.optimizer, local_plan(self.plan.n_units), 0,
            cfg=self.step_cfg))
        self._makeup_cache: dict[tuple, Callable] = {}
        # fused-path executables, built lazily on first fused run:
        # donated clones of the phase steps ("pipeline" mode) and whole-
        # period programs keyed by makeup-unit tuple ("compiled" mode)
        self._donated: list[Callable] | None = None
        self._period_cache: dict[tuple, Callable] = {}
        self._prefetch: PeriodPrefetcher | None = None

    def replan(self, new_plan: SyncPlan) -> None:
        """Hot-swap the schedule mid-run (elasticity / bandwidth drift).

        Pending straggler make-ups are kept — unit ids refer to the same
        network-order layout — but the phase executables are rebuilt so
        every subsequent step runs the new partition.
        """
        if new_plan.n_units != self.plan.n_units:
            raise ValueError(
                f"replan changed the unit count ({self.plan.n_units} -> "
                f"{new_plan.n_units}); the model layout must be stable")
        self.plan = new_plan
        self._build_steps()

    # ------------------------------------------------------------------ util
    def _median_time(self) -> float:
        xs = sorted(self._times[-64:])
        return xs[len(xs) // 2] if xs else float("inf")

    def _median_period_time(self) -> float:
        xs = sorted(self.period_times[-64:])
        return xs[len(xs) // 2] if xs else float("inf")

    def _makeup_step(self, units: tuple[int, ...]):
        if units not in self._makeup_cache:
            self._makeup_cache[units] = compose_makeup_step(
                self._local, units, self.model.unit_layout())
        return self._makeup_cache[units]

    def _period_step(self, makeup: tuple[int, ...]):
        if makeup not in self._period_cache:
            self._period_cache[makeup] = make_period_step(
                self.model, self.optimizer, self.plan, cfg=self.step_cfg,
                makeup_units=makeup)
        return self._period_cache[makeup]

    def _donated_steps(self) -> list[Callable]:
        """Donated clones of the phase bodies for the fused pipeline —
        the SAME traced programs as ``self._steps`` (bitwise-identical
        results), re-jitted with ``donate_argnums=0`` so each phase
        updates the state buffers in place."""
        if self._donated is None:
            self._donated = [jax.jit(make_train_step(
                self.model, self.optimizer, self.plan, h,
                cfg=self.step_cfg), donate_argnums=0)
                for h in range(self.plan.H)]
        return self._donated

    def _can_restore(self) -> bool:
        """Only swallow a failure if a checkpoint actually exists to
        restart from — otherwise a restore FileNotFoundError would mask
        the real error.  latest_step() itself may raise (it surfaces a
        failed async save); never let that replace the training
        exception."""
        if self.ckpt is None or self.retries >= self.run_cfg.max_retries:
            return False
        try:
            return self.ckpt.latest_step() is not None
        except Exception:                             # noqa: BLE001
            return False

    @hot_path
    def _drain_metrics(self) -> None:
        """Convert device-resident period metrics into history rows.

        Fused periods stash ``(first_step, period_dt, metrics[H])``
        device-side; this is the only host transfer on the fused path
        and runs every ``log_every`` periods (plus at run end / before
        a checkpoint restore).  ONE batched ``jax.device_get`` covers
        every undrained period — not one sync per key per period — so
        a drain costs a single host round-trip regardless of cadence."""
        if not self._undrained:
            return
        drained = jax.device_get([m for _, _, m in self._undrained])
        for (r0, dt, _), metrics in zip(self._undrained, drained, strict=True):
            if isinstance(metrics, list):      # pipeline: H per-phase dicts
                host = [{k: float(v) for k, v in m.items()}
                        for m in metrics]
            else:                              # compiled: dict of [H] arrays
                h_count = len(next(iter(metrics.values())))
                host = [{k: float(v[h]) for k, v in metrics.items()}
                        for h in range(h_count)]
            for h, row in enumerate(host):
                self.history.append({
                    "step": r0 + h,
                    "phase": self.plan.phase_of_iteration(r0 + h),
                    "time": dt / len(host), **row})
        self._undrained.clear()

    # ------------------------------------------------------------------- run
    def run(self, state: TrainState, n_steps: int, *,
            start_step: int = 0, fused: bool | None = None,
            inject_failure_at: int | None = None,
            inject_straggler_at: tuple[int, float] | None = None
            ) -> TrainState:
        """Train; ``inject_*`` hooks are for fault-tolerance tests.

        ``fused=None`` follows ``RunnerConfig.fused_period`` — except
        when an injection hook is supplied, which drops to the per-step
        oracle (hooks address individual iterations).  Pass
        ``fused=True`` to keep the fused path with hooks re-expressed
        at period granularity (a failure/straggler lands on the period
        containing the named step).
        """
        if fused is None:
            fused = (self.run_cfg.fused_period
                     and inject_failure_at is None
                     and inject_straggler_at is None)
        if not fused:
            return self._run_per_step(state, n_steps,
                                      start_step=start_step,
                                      inject_failure_at=inject_failure_at,
                                      inject_straggler_at=inject_straggler_at)
        return self._run_fused(state, n_steps, start_step=start_step,
                               inject_failure_at=inject_failure_at,
                               inject_straggler_at=inject_straggler_at)

    # -------------------------------------------------------- per-step path
    @hot_path
    def _run_per_step(self, state: TrainState, n_steps: int, *,
                      start_step: int = 0,
                      inject_failure_at: int | None = None,
                      inject_straggler_at: tuple[int, float] | None = None
                      ) -> TrainState:
        r = start_step
        while r < start_step + n_steps:
            phase = self.plan.phase_of_iteration(r)
            batch = self.data.batch(r)
            t0 = time.perf_counter()
            try:
                if inject_failure_at == r:
                    inject_failure_at = None
                    raise RuntimeError("injected node failure")

                if self.pending_units and phase == 0:
                    fn = self._makeup_step(tuple(sorted(self.pending_units)))
                    self.pending_units.clear()
                else:
                    fn = self._steps[phase]
                state, metrics = fn(state, batch)
                # block on the COMPLETED step — params included — before
                # stamping the deadline clock.  Blocking only on the loss
                # (the old behaviour) measured dispatch + forward but let
                # the phase's parameter all-reduce keep running, so a
                # stalled link never tripped `deadline_factor`.
                jax.block_until_ready((state, metrics))
            except Exception:                         # noqa: BLE001
                if not self._can_restore():
                    raise
                self.retries += 1
                r0, state, _ = self._restore_into(state)
                r = r0
                continue

            dt = time.perf_counter() - t0
            if inject_straggler_at is not None and inject_straggler_at[0] == r:
                dt += inject_straggler_at[1]
                inject_straggler_at = None
            # straggler policy: if this was a sync phase and it blew the
            # deadline, requeue its units and remember to skip-equivalent
            # (the sync already happened here; the policy matters when the
            # *link* stalls — we model it by requeueing the NEXT occurrence)
            if (len(self._times) >= self.run_cfg.min_history
                    and self.plan.is_parameter_sync
                    and self.plan.units_for_phase(phase)
                    and dt > self.run_cfg.deadline_factor
                    * self._median_time()):
                self.pending_units.update(self.plan.units_for_phase(phase))
                self.skipped_syncs += 1
            self._times.append(dt)

            # the block above already synced; one device_get batches the
            # (cheap, already-computed) metric transfers per step
            row = jax.device_get(metrics)
            self.history.append({"step": r, "phase": phase,
                                 "time": dt,
                                 **{k: float(v) for k, v in
                                    row.items()}})
            if self.ckpt is not None and (r + 1) % \
                    self.run_cfg.ckpt_every == 0:
                self.ckpt.save(r + 1, state,
                               meta={"plan": self.plan.to_json()})
            r += 1
        if self.ckpt is not None:
            self.ckpt.wait()
        return state

    # ----------------------------------------------------------- fused path
    @hot_path
    def _run_fused(self, state: TrainState, n_steps: int, *,
                   start_step: int = 0,
                   inject_failure_at: int | None = None,
                   inject_straggler_at: tuple[int, float] | None = None
                   ) -> TrainState:
        """One donated executable per whole synchronization period.

        Iterations that don't fill a whole period — a mis-aligned start
        (elastic restore / replan landing mid-period) or the run's tail
        — fall through to the per-step oracle, so any ``start_step`` /
        ``n_steps`` combination is exact.
        """
        mode = self.run_cfg.period_exec
        if mode not in ("pipeline", "compiled"):
            raise ValueError(f"period_exec must be 'pipeline' or "
                             f"'compiled', got {mode!r}")
        H = self.plan.H
        r, end = start_step, start_step + n_steps
        # the pipeline donates the incoming state's buffers; copy once so
        # the caller's reference stays valid (run() never donated before)
        state = jax.tree.map(jnp.copy, state)
        stacked = mode == "compiled"
        cfg = self.run_cfg
        if self._prefetch is None or self._prefetch.data is not self.data \
                or self._prefetch.h != H or self._prefetch.stacked != stacked \
                or self._prefetch.depth != max(1, cfg.prefetch_depth) \
                or self._prefetch.background != cfg.prefetch_background:
            self._prefetch = PeriodPrefetcher(
                self.data, H, stacked=stacked, depth=cfg.prefetch_depth,
                background=cfg.prefetch_background)
        pipe = self._prefetch

        def in_period(step):
            return step is not None and r <= step < r + H

        while r < end:
            if r % H != 0 or r + H > end:
                # partial period: per-step oracle up to the next period
                # boundary (or the end of the run).  Drain first so
                # history rows stay in step order.
                self._drain_metrics()
                n = min(end - r, H - r % H if r % H else end - r)
                fail = strag = None
                if inject_failure_at is not None and \
                        r <= inject_failure_at < r + n:
                    fail, inject_failure_at = inject_failure_at, None
                if inject_straggler_at is not None and \
                        r <= inject_straggler_at[0] < r + n:
                    strag, inject_straggler_at = inject_straggler_at, None
                state = self._run_per_step(state, n, start_step=r,
                                           inject_failure_at=fail,
                                           inject_straggler_at=strag)
                r += n
                continue

            batch = pipe.get(r)
            t0 = time.perf_counter()
            try:
                if in_period(inject_failure_at):
                    inject_failure_at = None
                    raise RuntimeError("injected node failure")

                makeup = ()
                if self.pending_units:
                    makeup = tuple(sorted(self.pending_units))
                    self.pending_units.clear()
                if mode == "compiled":
                    fn = self._period_step(makeup)
                    state, metrics = fn(state, batch)    # async dispatch
                else:
                    # back-to-back async dispatch of the donated phase
                    # clones: no host round-trip between phases, one
                    # block at the period boundary
                    steps = self._donated_steps()
                    metrics = []
                    for h in range(H):
                        if h == 0 and makeup:
                            fn = self._makeup_step(makeup)
                        else:
                            fn = steps[h]
                        state, m = fn(state, batch[h])
                        metrics.append(m)
                if r + 2 * H <= end:
                    # stage p+1..p+depth under p's compute; never past
                    # the last full period of this run
                    pipe.prefetch(r + H, last=end - H)
                # blocking on (state, metrics) times the COMPLETED period
                # — parameter syncs included — with one host sync per H
                # steps instead of per step
                jax.block_until_ready((state, metrics))
            except Exception:                         # noqa: BLE001
                if not self._can_restore():
                    raise
                self.retries += 1
                self._drain_metrics()
                pipe.invalidate()
                r0, state, _ = self._restore_into(state)
                r = r0
                continue

            dt = time.perf_counter() - t0
            if inject_straggler_at is not None and \
                    in_period(inject_straggler_at[0]):
                dt += inject_straggler_at[1]
                inject_straggler_at = None
            # straggler deadline at period granularity: a blown period
            # can't be attributed to one phase from outside the
            # executable, so every unit the period syncs is re-queued
            # for make-up (a superset of the oracle's requeue — extra
            # syncs only tighten Lemma 4's staleness bound)
            if (len(self.period_times) >= self.run_cfg.min_history
                    and self.plan.is_parameter_sync
                    and dt > self.run_cfg.deadline_factor
                    * self._median_period_time()):
                self.pending_units.update(self.plan.all_sync_units())
                self.skipped_syncs += 1
            self.period_times.append(dt)

            self._undrained.append((r, dt, metrics))
            if len(self._undrained) >= self.run_cfg.log_every:
                self._drain_metrics()
            if self.ckpt is not None and \
                    (r + H) // self.run_cfg.ckpt_every > \
                    r // self.run_cfg.ckpt_every:
                self.ckpt.save(r + H, state,
                               meta={"plan": self.plan.to_json()})
            r += H
        self._drain_metrics()
        if self.ckpt is not None:
            self.ckpt.wait()
        return state

    def _restore_into(self, template: TrainState):
        step, state, meta = self.ckpt.restore(template)
        return step, state, meta

    def restore_elastic(self, template: TrainState, n_workers: int,
                        new_plan: SyncPlan) -> tuple[int, TrainState]:
        """Restore onto a different worker count (elastic membership)."""
        step, state, _ = self.ckpt.restore(template)
        state = reshard_train_state(state, n_workers)
        self.replan(new_plan)
        return int(state.step), state
