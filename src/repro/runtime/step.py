"""Phase-specialized train/serve step builders.

DreamDDP compiles **one executable per phase** of the synchronization
period: the phase's layer interval is baked in as static slices, so the
emitted HLO contains exactly the scheduled collective bytes, and the block
stack is split (``segment_cuts``) at the interval boundary so the phase's
parameter all-reduce is data-independent of the remaining backward segments
— the overlap window XLA's latency-hiding scheduler uses (DESIGN.md §2).

The step builder is algorithm-agnostic: the plan's ``comm`` field (data,
set by the :class:`~repro.api.SyncStrategy` that built it) says whether
gradients are worker-averaged before the optimizer (classic DDP) or the
phase's layer units are parameter-averaged after the local update (Eq. 5),
and the *how* of each parameter sync is a composable
:class:`~repro.core.sync_policies.SyncPolicy` (plain mean / int8+EF /
DiLoCo outer step) resolved once per step build — there is no per-algorithm
branching here.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..core.outer_opt import OuterConfig, OuterState
from ..core.partial_sync import (UnitLayout, contiguous_ranges, divergence,
                                 sync_units, tree_worker_mean)
from ..core.plans import SyncPlan, local_plan
from ..core.sync_policies import SyncPolicy, resolve_policy
from ..optim.optimizers import Optimizer

__all__ = ["TrainState", "StepConfig", "init_train_state",
           "make_train_step", "make_phase_steps", "make_period_step",
           "make_prefill_step", "make_decode_step",
           "make_slot_prefill_step", "make_slot_prefill_step_batched",
           "make_slot_refeed_step", "make_slot_refeed_step_batched",
           "make_slot_decode_step", "make_slot_decode_step_paged"]

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree                    # worker-stacked [W, ...]
    opt_state: PyTree
    step: jax.Array
    ef: PyTree | None = None          # int8 error-feedback residuals
    outer: OuterState | None = None   # DiLoCo outer state


@dataclass(frozen=True)
class StepConfig:
    n_microbatches: int = 1
    policy: SyncPolicy | None = None  # explicit sync policy (wins)
    compress: str | None = None       # legacy flag: None | "int8_ef"
    outer: bool = False               # legacy flag: DiLoCo outer optimizer
    outer_cfg: OuterConfig = field(default_factory=OuterConfig)
    track_divergence: bool = False
    segment_cuts: bool = True         # split scans at the sync interval


def init_train_state(model, optimizer: Optimizer, key, n_workers: int,
                     *, cfg: StepConfig = StepConfig()) -> TrainState:
    """Identical initial replicas (workers start at a sync point)."""
    from ..core.partial_sync import worker_stack
    params = worker_stack(model.init(key), n_workers)
    opt_state = optimizer.init(params)
    ef, outer = resolve_policy(cfg).init_state(params)
    return TrainState(params, opt_state, jnp.zeros((), jnp.int32), ef,
                      outer)


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def _cuts_for(units, layout: UnitLayout) -> tuple[int, ...]:
    """Segment-cut unit ids: boundaries of the synced intervals."""
    cuts = set()
    for lo, hi in contiguous_ranges(list(units)):
        cuts.add(lo)
        cuts.add(hi)
    return tuple(sorted(cuts))


def make_train_step(model, optimizer: Optimizer, plan: SyncPlan, phase: int,
                    *, cfg: StepConfig = StepConfig(),
                    donate: bool = True):
    """Build the jittable step for one phase (phase is STATIC)."""
    layout = model.unit_layout()
    units = plan.units_for_phase(phase)
    cuts = _cuts_for(units, layout) if cfg.segment_cuts else ()
    policy = resolve_policy(cfg)

    def per_worker_grads(params, batch):
        """Per-worker loss+grads.  With ``n_microbatches > 1`` the batch
        arrives PRE-microbatched ``[n_micro, B_micro, ...]`` (the data
        pipeline / cell builder adds the axis, keeping shardings static
        through the accumulation scan)."""
        loss_fn = functools.partial(model.loss, segment_cuts=cuts)
        if cfg.n_microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def body(acc, mbatch):
            l, g = jax.value_and_grad(loss_fn)(params, mbatch)
            return (acc[0] + l,
                    jax.tree.map(jnp.add, acc[1], g)), None

        zero = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                             params))
        (loss, grads), _ = jax.lax.scan(body, zero, batch)
        inv = 1.0 / cfg.n_microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(state: TrainState, batch: PyTree
                   ) -> tuple[TrainState, dict]:
        losses, grads = jax.vmap(per_worker_grads)(state.params, batch)
        metrics = {"loss": jnp.mean(losses)}

        if not plan.is_parameter_sync:
            grads = tree_worker_mean(grads)      # DDP: gradient all-reduce

        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               state.params, state.step)
        new_ef, new_outer = state.ef, state.outer
        if plan.is_parameter_sync and units:
            new_params, new_ef, new_outer = policy.apply(
                new_params, state.ef, state.outer, units, layout)
        if cfg.track_divergence:
            metrics["divergence"] = divergence(new_params)
        new_state = TrainState(new_params, new_opt, state.step + 1,
                               new_ef, new_outer)
        return new_state, metrics

    return train_step


def make_phase_steps(model, optimizer: Optimizer, plan: SyncPlan, *,
                     cfg: StepConfig = StepConfig()):
    """One step function per phase of the period (all static)."""
    return [make_train_step(model, optimizer, plan, h, cfg=cfg)
            for h in range(plan.H)]


def compose_makeup_step(local_step, units, layout: UnitLayout):
    """Straggler make-up body: a pure local step followed by an extra
    sync of exactly ``units`` — the ONE definition of make-up semantics,
    shared by the runner's per-step cache and the fused period builder.
    """
    units = tuple(sorted(units))

    def makeup(state: TrainState, batch: PyTree):
        new_state, m = local_step(state, batch)
        return new_state._replace(
            params=sync_units(new_state.params, list(units), layout)), m

    return makeup


def make_period_step(model, optimizer: Optimizer, plan: SyncPlan, *,
                     cfg: StepConfig = StepConfig(),
                     makeup_units: tuple[int, ...] = (),
                     donate: bool = True):
    """Roll ALL ``H`` phase steps of ``plan`` into ONE jitted executable.

    The per-step path dispatches one jitted call per iteration from
    Python, so phase boundaries are host round-trips and XLA can only
    overlap collectives with compute *inside* a single step's HLO.  The
    period step takes the whole period's data pre-batched on a leading
    phase axis (``{tokens: [H, W, B, S], ...}``) and composes the
    phase-specialized bodies statically: consecutive phases with an
    identical unit set (``plan.phase_segments()``) become one
    ``lax.scan`` segment over their batch slice; distinct phases are
    chained directly.  Each phase keeps its exact scheduled collective
    bytes and ``segment_cuts`` overlap windows (the phase index is
    static per segment), and because the whole period is one program,
    XLA's latency-hiding scheduler can float phase *h*'s parameter
    all-reduce across phase *h+1*'s forward — the cross-iteration
    overlap DreamDDP's schedule is designed for.

    ``makeup_units`` (straggler make-up at a period boundary) replaces
    phase 0's body with the oracle's make-up semantics: a pure local
    step followed by an extra sync of exactly those units.

    Metrics come back device-resident with a leading ``[H]`` phase axis
    — the runner drains them on its ``log_every`` cadence instead of
    blocking every step.  The input state's buffers are donated by
    default (the period executable updates parameters in place).
    """
    layout = model.unit_layout()
    segments = list(plan.phase_segments())
    if makeup_units:
        # phase 0 gets its own body; split it out of its segment
        s0, l0 = segments[0]
        segments = [(0, 1)] + ([(1, l0 - 1)] if l0 > 1 else []) \
            + segments[1:]

    bodies: dict[int, Any] = {}
    for start, _ in segments:
        if start == 0 and makeup_units:
            local = make_train_step(model, optimizer,
                                    local_plan(plan.n_units), 0, cfg=cfg)
            bodies[0] = compose_makeup_step(local, makeup_units, layout)
        else:
            bodies[start] = make_train_step(model, optimizer, plan, start,
                                            cfg=cfg)

    def period_step(state: TrainState, batch: PyTree
                    ) -> tuple[TrainState, dict]:
        per_seg = []
        for start, length in segments:
            body = bodies[start]
            if length == 1:
                b = jax.tree.map(lambda x, s=start: x[s], batch)
                state_, m = body(state, b)
                state = state_
                per_seg.append(jax.tree.map(lambda v: v[None], m))
            else:
                seg = jax.tree.map(
                    lambda x, s=start, n=length: x[s:s + n], batch)
                state, ms = jax.lax.scan(body, state, seg)
                per_seg.append(ms)
        if len(per_seg) == 1:
            metrics = per_seg[0]
        else:
            metrics = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *per_seg)
        return state, metrics

    return jax.jit(period_step, donate_argnums=(0,) if donate else ())


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(model, *, with_frontend: str | None = None):
    if with_frontend == "audio":
        def prefill(params, tokens, cache, frames):
            return model.prefill(params, tokens, cache, frames)
    elif with_frontend == "vision":
        def prefill(params, tokens, cache, embeds):
            return model.prefill(params, tokens, cache, embeds=embeds)
    else:
        def prefill(params, tokens, cache):
            return model.prefill(params, tokens, cache)
    return prefill


def make_decode_step(model):
    def decode(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)
    return decode


# ---------------------------------------------------------------------------
# Slot-pooled serve steps (continuous batching; see repro.serve)
# ---------------------------------------------------------------------------
#
# Cache leaves are [layers, slots, ...] across every model family, so a
# "slot" is one lane of axis 1.  The legacy decode path shares one write
# position across the whole batch (``write_pos[0]``); these variants vmap
# the model's own single-sequence step over the slot axis instead, which
# gives every slot an independent write position and sequence length — the
# property continuous batching needs — without touching the models.

_SLOT_AXIS = 1


def _slot_view(arena, slot):
    """One-lane view ``[layers, 1, ...]`` of the arena at ``slot`` (traced
    index: no recompile per slot)."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=_SLOT_AXIS),
        arena)


def _slot_write(arena, new, slot):
    """Scatter a one-lane cache back into the arena at ``slot``."""
    return jax.tree.map(
        lambda a, n: jax.lax.dynamic_update_slice_in_dim(
            a, n.astype(a.dtype), slot, axis=_SLOT_AXIS), arena, new)


def _slots_view(arena, slots):
    """K-lane view ``[layers, K, ...]`` of the arena at ``slots [K]``
    (traced index vector: no recompile per slot assignment)."""
    return jax.tree.map(lambda a: jnp.take(a, slots, axis=_SLOT_AXIS),
                        arena)


def _slots_write(arena, new, slots):
    """Scatter a K-lane cache back into the arena at ``slots [K]``."""
    return jax.tree.map(
        lambda a, n: a.at[:, slots].set(n.astype(a.dtype)), arena, new)


def make_slot_prefill_step(model, *, with_frontend: str | None = None):
    """Prefill one request into arena slot ``slot``.

    ``tokens`` is ``[1, S]``; compiles once per distinct prompt length
    (``slot`` is a traced scalar).  Returns (last-token logits ``[1, 1,
    V]``, updated arena).
    """
    prefill = make_prefill_step(model, with_frontend=with_frontend)

    def slot_prefill(params, arena, tokens, slot, *extra):
        logits, new = prefill(params, tokens, _slot_view(arena, slot),
                              *extra)
        return logits, _slot_write(arena, new, slot)

    return slot_prefill


def make_slot_refeed_step(model):
    """Re-decode the last prompt token of one slot at position ``pos``.

    Used by chunked prefill: after a right-padded prefill the returned
    logits belong to a pad position, so the true last-token logits are
    recovered by one decode step (which rewrites the identical KV entry at
    ``pos`` and attends the same causal window the unpadded prefill would
    have).
    """
    def refeed(params, arena, slot, token, pos):
        logits, new = model.decode_step(params, _slot_view(arena, slot),
                                        token[None, None], pos[None])
        return logits, _slot_write(arena, new, slot)

    return refeed


def make_slot_prefill_step_batched(model, *,
                                   with_frontend: str | None = None):
    """Prefill K same-length requests into arena slots ``slots`` in ONE
    call.

    ``tokens`` is ``[K, S]`` (one row per admitted request, all padded to
    the same bucket length), ``slots [K]`` a traced index vector, and any
    frontend ``extra`` inputs arrive stacked ``[K, ...]``.  The model's
    own batched ``prefill`` runs over the K gathered lanes (every lane
    writes from position 0, which is exactly the native prefill
    contract), so the whole admission group costs one executable launch
    instead of K.  Compiles once per ``(K, S)`` — both are bounded
    (``K <= max_batch``, ``S`` by the prompt-length buckets), so the
    compile-cache contract of the serial path is preserved.

    Returns (last-token logits ``[K, V]``, updated arena).
    """
    prefill = make_prefill_step(model, with_frontend=with_frontend)

    def slot_prefill_batched(params, arena, tokens, slots, *extra):
        logits, new = prefill(params, tokens, _slots_view(arena, slots),
                              *extra)
        return logits[:, 0], _slots_write(arena, new, slots)

    return slot_prefill_batched


def make_slot_refeed_step_batched(model):
    """Re-decode the last prompt token of K slots in ONE call.

    The batched counterpart of :func:`make_slot_refeed_step`: ``slots
    [K]`` / ``tokens [K]`` / ``pos [K]`` — each lane rewrites its own KV
    entry at its own position (vmapped over the gathered lanes, same
    per-lane semantics as the serial refeed).  Returns (logits ``[K,
    V]``, updated arena).
    """
    def one(cache_i, token, pos, params):
        cache_i = jax.tree.map(lambda a: a[:, None], cache_i)
        logits, new = model.decode_step(params, cache_i, token[None, None],
                                        pos[None])
        return logits[0, 0], jax.tree.map(lambda a: a[:, 0], new)

    def slot_refeed_batched(params, arena, slots, tokens, pos):
        sub = _slots_view(arena, slots)
        axes = jax.tree.map(lambda _: _SLOT_AXIS, sub)
        logits, new = jax.vmap(
            one, in_axes=(axes, 0, 0, None),
            out_axes=(0, axes))(sub, tokens, pos, params)
        return logits, _slots_write(arena, new, slots)

    return slot_refeed_batched


def make_slot_decode_step(model):
    """Batched one-token decode with PER-SLOT write positions.

    ``tokens [S]`` / ``pos [S]`` -> (logits ``[S, V]``, arena).  The
    model's ``decode_step`` is vmapped over the slot axis, so each lane
    advances at its own position (and recurrent families update each
    lane's state independently).
    """
    def one(cache_i, token, pos, params):
        # vmap strips the slot axis; reinsert a singleton batch axis for the
        # model's [layers, batch, ...] cache contract and strip it again on
        # the way out (out_axes restores the slot axis).
        cache_i = jax.tree.map(lambda a: a[:, None], cache_i)
        logits, new = model.decode_step(params, cache_i, token[None, None],
                                        pos[None])
        return logits[0, 0], jax.tree.map(lambda a: a[:, 0], new)

    def slot_decode(params, arena, tokens, pos):
        axes = jax.tree.map(lambda _: _SLOT_AXIS, arena)
        logits, new_arena = jax.vmap(
            one, in_axes=(axes, 0, 0, None),
            out_axes=(0, axes))(arena, tokens, pos, params)
        return logits, new_arena

    return slot_decode


def make_slot_decode_step_paged(model):
    """Batched one-token decode against a **paged** KV pool.

    Same contract as :func:`make_slot_decode_step` (``tokens [S]`` /
    ``pos [S]`` -> logits ``[S, V]``), but the arena is the model's page
    pool and two extra per-tick inputs route the KV traffic: the
    per-slot ``block_tables [S, max_blocks]`` and the ``active [S]``
    mask (inactive lanes park their writes on the trash page so a
    retired slot's stale table can never corrupt re-allocated pages).
    KV-cache families (transformer / moe / mla) implement
    ``decode_step_paged``; recurrent-state families (mamba2 / rglru)
    have no position-addressed KV to page and keep their fixed-size
    state lanes on the contiguous path.
    """
    if not getattr(model, "supports_paged_kv", False):
        raise ValueError(
            f"{type(model).__name__} does not support a paged KV cache "
            "(recurrent state lanes / cross-attention KV are fixed-size "
            "per slot) — use the contiguous backend")

    def slot_decode(params, pages, tokens, pos, block_tables, active):
        logits, new_pages = model.decode_step_paged(
            params, pages, tokens[:, None], pos, block_tables, active)
        return logits[:, 0], new_pages

    return slot_decode
