"""Phase-specialized train/serve step builders.

DreamDDP compiles **one executable per phase** of the synchronization
period: the phase's layer interval is baked in as static slices, so the
emitted HLO contains exactly the scheduled collective bytes, and the block
stack is split (``segment_cuts``) at the interval boundary so the phase's
parameter all-reduce is data-independent of the remaining backward segments
— the overlap window XLA's latency-hiding scheduler uses (DESIGN.md §2).

Semantics per algorithm (``plan.algo``):

* ``ssgd`` / ``wfbp`` / ``ascwfbp`` — gradients are worker-averaged every
  step *before* the optimizer (classic DDP; wfbp variants differ only in
  the simulated time model, the SPMD execution is identical);
* ``flsgd`` / ``plsgd-enp`` / ``dreamddp`` — local update first, then the
  phase's layer units are parameter-averaged (Eq. 5), optionally through
  int8+error-feedback compression or a DiLoCo-style outer optimizer
  (both beyond-paper, off by default).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..core.outer_opt import OuterConfig, OuterState, outer_init, \
    outer_sync_units
from ..core.partial_sync import (UnitLayout, contiguous_ranges, divergence,
                                 sync_units, tree_worker_mean)
from ..core.plans import SyncPlan
from ..optim.optimizers import Optimizer

__all__ = ["TrainState", "StepConfig", "init_train_state",
           "make_train_step", "make_phase_steps", "make_prefill_step",
           "make_decode_step"]

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree                    # worker-stacked [W, ...]
    opt_state: PyTree
    step: jax.Array
    ef: PyTree | None = None          # int8 error-feedback residuals
    outer: OuterState | None = None   # DiLoCo outer state


@dataclass(frozen=True)
class StepConfig:
    n_microbatches: int = 1
    compress: str | None = None       # None | "int8_ef"
    outer: bool = False               # DiLoCo outer optimizer on syncs
    outer_cfg: OuterConfig = OuterConfig()
    track_divergence: bool = False
    segment_cuts: bool = True         # split scans at the sync interval


def init_train_state(model, optimizer: Optimizer, key, n_workers: int,
                     *, cfg: StepConfig = StepConfig()) -> TrainState:
    """Identical initial replicas (workers start at a sync point)."""
    from ..core.partial_sync import worker_stack
    params = worker_stack(model.init(key), n_workers)
    opt_state = optimizer.init(params)
    ef = (jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
          if cfg.compress == "int8_ef" else None)
    outer = outer_init(params) if cfg.outer else None
    return TrainState(params, opt_state, jnp.zeros((), jnp.int32), ef,
                      outer)


# ---------------------------------------------------------------------------
# Compressed partial sync (int8 + EF over the worker axis)
# ---------------------------------------------------------------------------

def _sync_units_ef(params: PyTree, ef: PyTree, unit_ids, layout: UnitLayout
                   ) -> tuple[PyTree, PyTree]:
    from ..parallel.compression import compressed_worker_mean
    grouped = layout.by_group(unit_ids)
    new_p, new_e = dict(params), dict(ef)
    for group, idxs in grouped.items():
        p, e = params[group], ef[group]
        if idxs == [None]:
            pair = jax.tree.map(compressed_worker_mean, p, e)
            is2 = lambda t: isinstance(t, tuple) and len(t) == 2
            new_p[group] = jax.tree.map(lambda t: t[0], pair, is_leaf=is2)
            new_e[group] = jax.tree.map(lambda t: t[1], pair, is_leaf=is2)
            continue
        ranges = contiguous_ranges([i for i in idxs if i is not None])

        def one(p_, e_):
            for lo, hi in ranges:
                s, r = compressed_worker_mean(p_[:, lo:hi], e_[:, lo:hi])
                p_ = p_.at[:, lo:hi].set(s)
                e_ = e_.at[:, lo:hi].set(r)
            return p_, e_

        pair = jax.tree.map(one, p, e)
        is2 = lambda t: isinstance(t, tuple) and len(t) == 2
        new_p[group] = jax.tree.map(lambda t: t[0], pair, is_leaf=is2)
        new_e[group] = jax.tree.map(lambda t: t[1], pair, is_leaf=is2)
    return new_p, new_e


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def _cuts_for(units, layout: UnitLayout) -> tuple[int, ...]:
    """Segment-cut unit ids: boundaries of the synced intervals."""
    cuts = set()
    for lo, hi in contiguous_ranges(list(units)):
        cuts.add(lo)
        cuts.add(hi)
    return tuple(sorted(cuts))


def make_train_step(model, optimizer: Optimizer, plan: SyncPlan, phase: int,
                    *, cfg: StepConfig = StepConfig(),
                    donate: bool = True):
    """Build the jittable step for one phase (phase is STATIC)."""
    layout = model.unit_layout()
    units = plan.units_for_phase(phase)
    cuts = _cuts_for(units, layout) if cfg.segment_cuts else ()

    def per_worker_grads(params, batch):
        """Per-worker loss+grads.  With ``n_microbatches > 1`` the batch
        arrives PRE-microbatched ``[n_micro, B_micro, ...]`` (the data
        pipeline / cell builder adds the axis, keeping shardings static
        through the accumulation scan)."""
        loss_fn = functools.partial(model.loss, segment_cuts=cuts)
        if cfg.n_microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def body(acc, mbatch):
            l, g = jax.value_and_grad(loss_fn)(params, mbatch)
            return (acc[0] + l,
                    jax.tree.map(jnp.add, acc[1], g)), None

        zero = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                             params))
        (loss, grads), _ = jax.lax.scan(body, zero, batch)
        inv = 1.0 / cfg.n_microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(state: TrainState, batch: PyTree
                   ) -> tuple[TrainState, dict]:
        losses, grads = jax.vmap(per_worker_grads)(state.params, batch)
        metrics = {"loss": jnp.mean(losses)}

        if not plan.is_parameter_sync:
            grads = tree_worker_mean(grads)      # S-SGD: gradient all-reduce

        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               state.params, state.step)
        new_ef, new_outer = state.ef, state.outer
        if plan.is_parameter_sync and units:
            if cfg.outer:
                new_params, new_outer = outer_sync_units(
                    new_params, state.outer, units, layout, cfg.outer_cfg)
            elif cfg.compress == "int8_ef":
                new_params, new_ef = _sync_units_ef(
                    new_params, state.ef, units, layout)
            else:
                new_params = sync_units(new_params, units, layout)
        if cfg.track_divergence:
            metrics["divergence"] = divergence(new_params)
        new_state = TrainState(new_params, new_opt, state.step + 1,
                               new_ef, new_outer)
        return new_state, metrics

    return train_step


def make_phase_steps(model, optimizer: Optimizer, plan: SyncPlan, *,
                     cfg: StepConfig = StepConfig()):
    """One step function per phase of the period (all static)."""
    return [make_train_step(model, optimizer, plan, h, cfg=cfg)
            for h in range(plan.H)]


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(model, *, with_frontend: str | None = None):
    if with_frontend == "audio":
        def prefill(params, tokens, cache, frames):
            return model.prefill(params, tokens, cache, frames)
    elif with_frontend == "vision":
        def prefill(params, tokens, cache, embeds):
            return model.prefill(params, tokens, cache, embeds=embeds)
    else:
        def prefill(params, tokens, cache):
            return model.prefill(params, tokens, cache)
    return prefill


def make_decode_step(model):
    def decode(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)
    return decode
