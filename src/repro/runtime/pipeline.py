"""Double-buffered host->device data pipeline at period granularity.

The fused runner consumes one pre-batched period ``[H, ...]`` per
dispatch.  :class:`PeriodPrefetcher` builds (and ``jax.device_put``s)
period *p+1*'s batch while period *p*'s executable is still running:
``get()`` hands back the already-staged batch, the runner dispatches the
period step, then calls :meth:`prefetch` for the next period *before*
blocking on the current one — the stack/transfer work is dispatched
asynchronously and lands under the period's compute.

Works with any ``data.batch(step) -> pytree`` source: device-resident
batches (``MarkovCorpus`` computes on device) pass through
``device_put`` for free, host/numpy pipelines get their H2D copy
started a period ahead.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..lint import hot_path

__all__ = ["PeriodPrefetcher", "stack_period_batches"]

PyTree = Any


def stack_period_batches(data: Any, start: int, h: int) -> PyTree:
    """Batches for iterations ``[start, start + h)`` stacked on a new
    leading phase axis (the ``make_period_step`` input layout)."""
    batches = [data.batch(r) for r in range(start, start + h)]
    if h == 1:
        return jax.tree.map(lambda x: jnp.asarray(x)[None], batches[0])
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)


class PeriodPrefetcher:
    """One-period-ahead staging of a period's training batches.

    ``stacked=True`` yields the ``[H, ...]`` layout ``make_period_step``
    consumes; ``stacked=False`` yields the list of H per-step batches
    the pipeline-mode runner feeds its per-phase executables.
    """

    def __init__(self, data: Any, h: int, *, stacked: bool = True):
        self.data = data
        self.h = h
        self.stacked = stacked
        self._staged: tuple[int, PyTree] | None = None

    @hot_path
    def _build(self, start: int) -> PyTree:
        if self.stacked:
            return jax.device_put(stack_period_batches(self.data, start,
                                                       self.h))
        return [jax.device_put(self.data.batch(r))
                for r in range(start, start + self.h)]

    @hot_path
    def get(self, start: int) -> PyTree:
        """The period batch for iterations ``[start, start + H)`` —
        already staged if :meth:`prefetch` predicted this start (the
        common case), built on the spot otherwise (first period, or a
        rollback after a restore)."""
        if self._staged is not None and self._staged[0] == start:
            batch = self._staged[1]
            self._staged = None
            return batch
        self._staged = None
        return self._build(start)

    @hot_path
    def prefetch(self, start: int) -> None:
        """Asynchronously stage the period starting at ``start`` (call
        right after dispatching the current period, before blocking)."""
        if self._staged is not None and self._staged[0] == start:
            return
        self._staged = (start, self._build(start))

    def invalidate(self) -> None:
        """Drop staged work (plan/data changed under us)."""
        self._staged = None
