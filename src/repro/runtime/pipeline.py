"""Depth-k host->device data pipeline at period granularity.

The fused runner consumes one pre-batched period ``[H, ...]`` per
dispatch.  :class:`PeriodPrefetcher` builds (and ``jax.device_put``s)
up to ``depth`` future periods while the current one runs: ``get()``
hands back the already-staged batch, the runner dispatches the period
step, then calls :meth:`prefetch` for the following periods *before*
blocking on the current one.

Two staging modes:

* ``background=False`` (default) — staging happens inline on the caller
  thread; JAX's async dispatch still overlaps the transfer with device
  compute.  ``depth=1`` reproduces the original double-buffer exactly.
* ``background=True`` — a daemon thread drains a staging queue, so
  host-side batch construction (tokenization, numpy work) also moves
  off the training thread.  ``get()`` blocks on the slot's event if the
  batch is still being built.

Each period batch is a pure function of its start step (``data.batch``
is deterministic), so batches are **bitwise identical** across depths
and modes — the depth/background knobs change only *when* the work
happens (``tests/test_pipeline_prefetch.py`` pins this).

Works with any ``data.batch(step) -> pytree`` source: device-resident
batches (``MarkovCorpus`` computes on device) pass through
``device_put`` for free, host/numpy pipelines get their H2D copy
started periods ahead.
"""

from __future__ import annotations

import queue
import threading
from typing import Any

import jax
import jax.numpy as jnp

from ..lint import hot_path

__all__ = ["PeriodPrefetcher", "stack_period_batches"]

PyTree = Any


def stack_period_batches(data: Any, start: int, h: int) -> PyTree:
    """Batches for iterations ``[start, start + h)`` stacked on a new
    leading phase axis (the ``make_period_step`` input layout)."""
    batches = [data.batch(r) for r in range(start, start + h)]
    if h == 1:
        return jax.tree.map(lambda x: jnp.asarray(x)[None], batches[0])
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)


class _Slot:
    """One staged (or in-flight) period batch."""

    __slots__ = ("ready", "value", "error")

    def __init__(self):
        self.ready = threading.Event()
        self.value: PyTree | None = None
        self.error: BaseException | None = None

    def fill(self, value: PyTree) -> None:
        self.value = value
        self.ready.set()

    def fail(self, error: BaseException) -> None:
        self.error = error
        self.ready.set()

    def take(self) -> PyTree:
        self.ready.wait()
        if self.error is not None:
            raise self.error
        value, self.value = self.value, None
        return value


class PeriodPrefetcher:
    """Depth-``k`` staging of period training batches.

    ``stacked=True`` yields the ``[H, ...]`` layout ``make_period_step``
    consumes; ``stacked=False`` yields the list of H per-step batches
    the pipeline-mode runner feeds its per-phase executables.

    Only the owning (training) thread mutates the staging map; the
    background worker touches only slot objects it was handed through
    the queue, and a generation counter lets :meth:`invalidate` orphan
    in-flight work without joining the thread.
    """

    def __init__(self, data: Any, h: int, *, stacked: bool = True,
                 depth: int = 1, background: bool = False):
        self.data = data
        self.h = h
        self.stacked = stacked
        self.depth = max(1, depth)
        self.background = background
        self._staged: dict[int, _Slot] = {}
        self._gen = 0
        self._queue: queue.Queue | None = None
        self._thread: threading.Thread | None = None

    @hot_path
    def _build(self, start: int) -> PyTree:
        if self.stacked:
            return jax.device_put(stack_period_batches(self.data, start,
                                                       self.h))
        return [jax.device_put(self.data.batch(r))
                for r in range(start, start + self.h)]

    # -------------------------------------------------------- background
    def _ensure_worker(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        self._queue = queue.Queue()
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="period-prefetch")
        self._thread.start()

    def _worker(self) -> None:
        while True:
            gen, start, slot = self._queue.get()
            if gen != self._gen:
                # orphaned by invalidate(); nobody will take() this slot
                slot.fail(RuntimeError("prefetch invalidated"))
                continue
            try:
                slot.fill(self._build(start))
            except BaseException as e:              # surfaced in take()
                slot.fail(e)

    def _stage(self, start: int) -> None:
        slot = _Slot()
        self._staged[start] = slot
        if self.background:
            self._ensure_worker()
            self._queue.put((self._gen, start, slot))
        else:
            try:
                slot.fill(self._build(start))
            except BaseException as e:
                slot.fail(e)

    # ---------------------------------------------------------- interface
    @hot_path
    def get(self, start: int) -> PyTree:
        """The period batch for iterations ``[start, start + H)`` —
        already staged if :meth:`prefetch` predicted this start (the
        common case), built on the spot otherwise (first period, or a
        rollback after a restore).  Also drops any staged periods
        *before* ``start`` (stale after a restore rollback)."""
        for s in [s for s in self._staged if s < start]:
            del self._staged[s]
        slot = self._staged.pop(start, None)
        if slot is not None:
            return slot.take()
        return self._build(start)

    @hot_path
    def prefetch(self, start: int, *, last: int | None = None) -> None:
        """Stage the periods ``start, start + H, ...`` up to ``depth``
        entries (call right after dispatching the current period, before
        blocking).  ``last`` clamps staging to period starts ``<= last``
        so a run tail never builds batches past the end of the run."""
        for i in range(self.depth):
            s = start + i * self.h
            if last is not None and s > last:
                break
            if s not in self._staged:
                self._stage(s)

    def invalidate(self) -> None:
        """Drop staged work (plan/data changed under us)."""
        self._gen += 1
        self._staged.clear()
