"""SimExecutor — replay a SyncPlan against a virtual geo-cluster.

The executor drives the *real* schedule artifact
(:class:`~repro.core.plans.SyncPlan` — any registered strategy's output,
not just interval partitions) through a :class:`~repro.sim.events
.VirtualCluster`:

* compute times come from the :class:`~repro.core.profiler.LayerProfile`
  (scaled by the cluster's current straggler slowdown);
* comm times come from the plan's **bytes** — each synchronized unit's
  ``param_bytes`` charged as a hierarchical ring all-reduce against the
  time-varying :class:`~repro.sim.network.NetworkModel` at the instant
  the transfer starts;
* the per-layer dependency is the paper's tau-recursion (Eq. 7): a
  unit's comm starts once its backward finishes *and* a link channel is
  free, in backward-completion order.

On a static network this reproduces
:func:`repro.core.time_model.simulate_phase` exactly — the conformance
suite (:mod:`repro.sim.conformance`) pins that equivalence down per
scenario — while scenario events (drift, stragglers, churn, failures)
take the timeline places the closed form cannot go.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

from ..core.plans import SyncPlan
from ..core.profiler import LayerProfile
from .events import ScenarioEvent, VirtualCluster
from .trace import Interval, Trace

__all__ = ["SimExecutor", "SimReport", "prepare_run"]


def prepare_run(scenario, strategy, H: int, profile: LayerProfile, *,
                fill_mode: str = "exact"):
    """Solve a strategy's plan for a scenario's network at t=0.

    Returns ``(cluster, plan)`` ready for :class:`SimExecutor`.  When the
    strategy forces a different period length (gradient-sync strategies
    return ``H == 1``), the cluster is rebuilt with the plan's actual
    ``H`` so scenario-event period conversion stays aligned.  Shared by
    ``Session.simulate`` and the conformance checker so both always
    agree on which cluster a plan runs against.
    """
    cluster = scenario.build(H)
    plan = strategy.build_plan(cluster.effective_profile(profile, 0.0),
                               H, fill_mode=fill_mode)
    if plan.H != H:
        cluster = scenario.build(plan.H)
    return cluster, plan

#: callback: (executor, events fired at a period boundary) -> replacement
#: plan or None.  Used by ``Session.simulate`` to re-plan after drift.
OnEvents = Callable[["SimExecutor", Sequence[ScenarioEvent]],
                    SyncPlan | None]


@dataclass
class SimReport:
    """What ``Session.simulate`` returns: trace + plan history."""

    scenario: str
    trace: Trace
    plans: list[tuple[int, SyncPlan]] = field(default_factory=list)

    @property
    def final_plan(self) -> SyncPlan:
        return self.plans[-1][1]

    @property
    def replanned(self) -> bool:
        return len(self.plans) > 1

    def summary(self) -> dict:
        t = self.trace
        return {
            "scenario": self.scenario,
            "periods": t.n_periods,
            "makespan_s": t.makespan,
            "period_times_s": t.period_times(),
            "mean_iteration_s": (t.makespan / t.n_iterations
                                 if t.n_iterations else 0.0),
            "exposed_comm_s": t.total_exposed_comm(),
            "replans": len(self.plans) - 1,
            "events": len(t.events),
        }


class SimExecutor:
    """Discrete-event replay of one plan's period timeline."""

    def __init__(self, profile: LayerProfile, plan: SyncPlan,
                 cluster: VirtualCluster, *, n_channels: int = 1):
        if plan.n_units != len(profile):
            raise ValueError(
                f"plan has {plan.n_units} units but profile has "
                f"{len(profile)} layers")
        self.profile = profile
        self.cluster = cluster
        self.n_channels = max(1, n_channels)
        self.clock = 0.0
        self.iteration = 0
        self._deferred: list[ScenarioEvent] = []
        self.trace = Trace(H=plan.H)
        self.set_plan(plan)
        self.trace.meta.update({
            "n_units": plan.n_units,
            "n_workers": cluster.n_active,
            "n_datacenters": cluster.network.topology.n_datacenters,
        })

    def set_plan(self, plan: SyncPlan) -> None:
        """Swap the schedule (only safe at a period boundary).

        Phase counting restarts at the current iteration, so a plan with
        a different ``H`` stays phase-aligned (``Trace.H`` keeps the
        original period length for period bookkeeping, though — prefer
        swaps that preserve ``H``, as ``Session.simulate`` does).
        """
        if plan.n_units != len(self.profile):
            raise ValueError("new plan's unit count does not match profile")
        self.plan = plan
        self._phase_origin = self.iteration
        n = plan.n_units
        # per phase: BP positions to synchronize (0 = output-most layer)
        self._positions = [sorted(n - 1 - u for u in units)
                           for units in plan.phase_units]

    @property
    def positions_per_phase(self) -> list[list[int]]:
        """Current plan's synchronized BP positions, one list per phase."""
        return [list(p) for p in self._positions]

    # ------------------------------------------------------------------ run
    def run(self, periods: int = 1, *,
            on_events: OnEvents | None = None) -> Trace:
        """Simulate ``periods`` further periods of the current plan.

        Scenario events fire at iteration boundaries; at each *period*
        boundary the events fired there — plus any that fired mid-period
        since the last boundary — are offered to ``on_events``, whose
        returned plan (if any) replaces the schedule for the following
        periods.
        """
        for _ in range(periods):
            new = self.cluster.advance(self.iteration, self.clock)
            if new:
                self.trace.events.extend(self.cluster.log[-len(new):])
            fired, self._deferred = self._deferred + new, []
            if fired and on_events is not None:
                new_plan = on_events(self, fired)
                if new_plan is not None:
                    self.set_plan(new_plan)
            self._run_iteration()                      # phase 0
            for _ in range(1, self.plan.H):
                new = self.cluster.advance(self.iteration, self.clock)
                if new:
                    self.trace.events.extend(self.cluster.log[-len(new):])
                    self._deferred.extend(new)         # replan next boundary
                self._run_iteration()
        return self.trace

    def _run_iteration(self) -> None:
        r, tr = self.iteration, self.trace
        h = self.plan.phase_of_iteration(r - self._phase_origin)
        prof = self.profile
        bp = prof.bp_order()
        n = len(bp)
        t0 = self.clock

        stall = self.cluster.take_stall()
        if stall > 0.0:
            tr.intervals.append(Interval("stall", r, h, -1, t0, t0 + stall))
            t0 += stall

        slow = self.cluster.compute_slowdown()
        fp_end = t0 + prof.t_fp_total * slow
        tr.intervals.append(Interval("fp", r, h, -1, t0, fp_end))

        bp_done = []
        acc = fp_end
        for i, c in enumerate(bp):
            start, acc = acc, acc + c.t_bp * slow
            bp_done.append(acc)
            tr.intervals.append(Interval("bp", r, h, n - 1 - i, start, acc))

        free = [fp_end] * self.n_channels
        comm_end = fp_end
        for i in self._positions[h]:
            ch = min(range(len(free)), key=free.__getitem__)
            start = max(bp_done[i], free[ch])
            unit = n - 1 - i
            dur = self.cluster.collective_time(
                prof.layers[unit].param_bytes, start)
            done = start + dur
            free[ch] = done
            comm_end = max(comm_end, done)
            tr.intervals.append(Interval("comm", r, h, unit, start, done))

        end = max(bp_done[-1] if bp_done else fp_end, comm_end)
        tr.iteration_spans.append((self.clock, end))
        self.clock = end
        self.iteration += 1
