"""Scenario events + the stateful VirtualCluster that replays them.

Events are declarative (frozen dataclasses) and fire at **iteration
boundaries** of the simulated run: each event names either a ``period``
(fires before the first iteration of that period) or an absolute
``iteration``.  Times-of-day are never used — a scenario cannot know wall
clock ahead of the profile it runs against — so durations are expressed
in periods and converted to iterations once ``H`` is known.

The :class:`VirtualCluster` owns all mutable simulation state: the
network, the active worker set, per-worker compute slowdowns, pending
events and the seeded RNG.  Identical (scenario, H, seed) therefore
yields an identical replay — the determinism the conformance suite
asserts byte-for-byte.
"""

from __future__ import annotations

import dataclasses
import random
from dataclasses import dataclass

from ..core.profiler import LayerProfile
from .network import NetworkModel

__all__ = ["ScenarioEvent", "StragglerOnset", "LinkDegradation",
           "BandwidthDrift", "WorkerJoin", "WorkerLeave",
           "TransientFailure", "VirtualCluster", "REPLAN_EVENTS"]


@dataclass(frozen=True)
class ScenarioEvent:
    """Base: when the event fires.  Exactly one of period/iteration."""

    period: int | None = None
    iteration: int | None = None

    def fire_iteration(self, H: int) -> int:
        if (self.period is None) == (self.iteration is None):
            raise ValueError(
                f"{type(self).__name__} needs exactly one of "
                f"period=/iteration= (got {self})")
        return self.iteration if self.iteration is not None \
            else self.period * H

    def describe(self) -> dict:
        d = {k: v for k, v in dataclasses.asdict(self).items()
             if v is not None}
        d["kind"] = type(self).__name__
        return d


@dataclass(frozen=True)
class StragglerOnset(ScenarioEvent):
    """Worker ``worker`` computes ``slowdown``x slower for
    ``duration_periods`` periods (None = for the rest of the run)."""

    worker: int = 0
    slowdown: float = 2.0
    duration_periods: int | None = None


@dataclass(frozen=True)
class LinkDegradation(ScenarioEvent):
    """Multiply a link's bandwidth by ``factor`` for a window."""

    link: str = "inter"
    factor: float = 0.5
    duration_periods: int | None = None


@dataclass(frozen=True)
class BandwidthDrift(ScenarioEvent):
    """Permanently re-base a link's bandwidth (piecewise-constant drift)."""

    link: str = "intra"
    bandwidth: float = 1e9


@dataclass(frozen=True)
class WorkerJoin(ScenarioEvent):
    """``n`` new workers join (lowest unused ids)."""

    n: int = 1


@dataclass(frozen=True)
class WorkerLeave(ScenarioEvent):
    """``n`` workers leave (highest active ids)."""

    n: int = 1


@dataclass(frozen=True)
class TransientFailure(ScenarioEvent):
    """Worker ``worker`` fails and recovers after ``downtime`` seconds;
    synchronous data parallelism stalls the whole iteration."""

    worker: int = 0
    downtime: float = 0.1


#: Event kinds that change the optimal schedule — ``Session.simulate``
#: re-solves the plan when one of these fires (at a period boundary).
REPLAN_EVENTS = (BandwidthDrift, LinkDegradation, WorkerJoin, WorkerLeave)


# internal: closes a duration window opened by a timed event
@dataclass(frozen=True)
class _WindowEnd(ScenarioEvent):
    target: object = None              # event being closed / window handle
    kind: str = ""                     # "straggler" | "degradation"


class VirtualCluster:
    """All mutable state of one simulated geo-cluster run."""

    def __init__(self, network: NetworkModel, events=(), *, H: int,
                 seed: int = 0):
        self.network = network
        self.H = H
        self.rng = random.Random(seed)
        self.active: set[int] = set(range(network.topology.n_workers))
        self._next_worker_id = network.topology.n_workers
        self._slow: dict[int, float] = {}
        self._stall = 0.0
        self.log: list[dict] = []
        self._pending: list[tuple[int, int, ScenarioEvent]] = sorted(
            (ev.fire_iteration(H), i, ev) for i, ev in enumerate(events))
        self._seq = len(self._pending)

    # ------------------------------------------------------------ schedule
    def _push(self, fire_it: int, ev: ScenarioEvent) -> None:
        import bisect
        bisect.insort(self._pending, (fire_it, self._seq, ev))
        self._seq += 1

    # -------------------------------------------------------------- replay
    def advance(self, iteration: int, clock: float) -> list[ScenarioEvent]:
        """Apply every event due at or before ``iteration``; returns the
        user-visible events fired (window-end bookkeeping excluded)."""
        fired: list[ScenarioEvent] = []
        while self._pending and self._pending[0][0] <= iteration:
            fire_it, _, ev = self._pending.pop(0)
            self._apply(ev, fire_it, clock)
            if not isinstance(ev, _WindowEnd):
                fired.append(ev)
        return fired

    def _apply(self, ev: ScenarioEvent, fire_it: int, clock: float) -> None:
        if isinstance(ev, _WindowEnd):
            if ev.kind == "straggler":
                self._slow.pop(ev.target, None)
            else:
                self.network.end_degradation(ev.target, clock)
            return                                     # not logged
        if isinstance(ev, StragglerOnset):
            self._slow[ev.worker] = ev.slowdown
            if ev.duration_periods is not None:
                self._push(fire_it + ev.duration_periods * self.H,
                           _WindowEnd(iteration=0, target=ev.worker,
                                      kind="straggler"))
        elif isinstance(ev, LinkDegradation):
            handle = self.network.degrade(ev.link, ev.factor, clock)
            if ev.duration_periods is not None:
                self._push(fire_it + ev.duration_periods * self.H,
                           _WindowEnd(iteration=0, target=handle,
                                      kind="degradation"))
        elif isinstance(ev, BandwidthDrift):
            self.network.set_bandwidth(ev.link, ev.bandwidth, clock)
        elif isinstance(ev, WorkerJoin):
            for _ in range(ev.n):
                self.active.add(self._next_worker_id)
                self._next_worker_id += 1
        elif isinstance(ev, WorkerLeave):
            if ev.n >= len(self.active):
                raise ValueError("WorkerLeave would empty the cluster")
            for w in sorted(self.active, reverse=True)[:ev.n]:
                self.active.discard(w)
                self._slow.pop(w, None)
        elif isinstance(ev, TransientFailure):
            if ev.worker in self.active:
                self._stall += ev.downtime
        else:
            raise TypeError(f"unknown scenario event {ev!r}")
        self.log.append({"iteration": fire_it, "clock": clock,
                         **ev.describe()})

    def take_stall(self) -> float:
        """Pending whole-cluster stall (transient failures); cleared."""
        s, self._stall = self._stall, 0.0
        return s

    # -------------------------------------------------------------- state
    @property
    def n_active(self) -> int:
        return len(self.active)

    def workers_by_dc(self) -> list[int]:
        return self.network.topology.workers_by_dc(self.active)

    def compute_slowdown(self) -> float:
        """Synchronous DP: the slowest *active* worker gates each layer."""
        return max((self._slow.get(w, 1.0) for w in self.active),
                   default=1.0)

    def worker_slowdown(self, worker: int) -> float:
        """One worker's current compute slowdown (async runtimes charge
        stragglers individually instead of gating on the max)."""
        return self._slow.get(worker, 1.0)

    def collective_time(self, nbytes: float, start: float, *,
                        jittered: bool = True) -> float:
        return self.network.collective_time(
            nbytes, start, workers_by_dc=self.workers_by_dc(),
            rng=self.rng if jittered else None)

    def effective_profile(self, profile: LayerProfile,
                          t: float) -> LayerProfile:
        """The closed-form view of this instant: per-layer comm times from
        the current membership/network at ``t`` (no jitter), compute
        times scaled by the current straggler slowdown.

        This is what the scheduler re-plans against and what the
        conformance layer feeds to ``time_model.simulate_phase``.
        """
        slow = self.compute_slowdown()
        by_dc = self.workers_by_dc()
        layers = [dataclasses.replace(
            c, t_fp=c.t_fp * slow, t_bp=c.t_bp * slow,
            t_comm=self.network.collective_time(
                c.param_bytes, t, workers_by_dc=by_dc))
            for c in profile.layers]
        hw = profile.hw.replace(
            bandwidth=self.network.bandwidth_at("intra", t),
            n_workers=self.n_active)
        return LayerProfile(layers, hw)
