"""Schedule-conformance checking: SimExecutor vs the closed-form model.

The simulator and :mod:`repro.core.time_model` are two independent
implementations of the same timing semantics (the Eq. 7 tau-recursion).
This module pins them against each other: for every *static window* of a
scenario — a period during which no event fires mid-period and no drift
breakpoint lands inside — the simulated period time must equal

    stall + sum_h simulate_phase(effective_profile, positions_h)

within ``rtol`` (default 1e-6 relative; in practice they agree to float
round-off, ~1e-12).  ``effective_profile`` is the cluster's closed-form
view at the window start: comm times from the hierarchical ring model at
the current membership/bandwidth, compute times scaled by the current
straggler slowdown.  Transient-failure stalls are additive and known, so
they are moved to the expected side.

Scenarios with link jitter cannot be checked (their timing is seeded
noise by construction) — :func:`check_scenario` rejects them.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field

from ..core.profiler import HardwareSpec, LayerProfile, analytic_profile
from ..core.time_model import simulate_phase
from .executor import SimExecutor, prepare_run
from .trace import Trace

__all__ = ["WindowCheck", "ConformanceReport", "synthetic_profile",
           "reference_period_time", "check_scenario", "check_library",
           "DEFAULT_RTOL"]

DEFAULT_RTOL = 1e-6


def synthetic_profile(n_layers: int = 12, *, seed: int = 0,
                      bandwidth: float = 1e9, n_workers: int = 8,
                      latency: float = 1e-4) -> LayerProfile:
    """Deterministic random-ish profile for scenario/conformance runs."""
    rng = random.Random(seed)
    hw = HardwareSpec(bandwidth=bandwidth, n_workers=n_workers,
                      latency=latency)
    layers = [(f"l{i}", rng.uniform(1e6, 5e7), rng.uniform(1e9, 8e10))
              for i in range(n_layers)]
    return analytic_profile(layers, hw)


@dataclass(frozen=True)
class WindowCheck:
    """One static-window comparison."""

    period: int
    expected: float
    simulated: float
    rtol: float

    @property
    def rel_err(self) -> float:
        scale = max(abs(self.expected), 1e-30)
        return abs(self.simulated - self.expected) / scale

    @property
    def ok(self) -> bool:
        return self.rel_err <= self.rtol


@dataclass
class ConformanceReport:
    scenario: str
    algo: str
    H: int
    checks: list[WindowCheck] = field(default_factory=list)
    skipped_periods: list[int] = field(default_factory=list)
    trace: Trace | None = None

    @property
    def ok(self) -> bool:
        return bool(self.checks) and all(c.ok for c in self.checks)

    @property
    def max_rel_err(self) -> float:
        return max((c.rel_err for c in self.checks), default=float("nan"))

    def summary(self) -> str:
        status = "OK" if self.ok else "FAIL"
        return (f"{self.scenario:<20} {self.algo:<12} H={self.H} "
                f"windows={len(self.checks)} skipped="
                f"{len(self.skipped_periods)} "
                f"max_rel_err={self.max_rel_err:.2e} {status}")


def reference_period_time(profile: LayerProfile, positions_per_phase,
                          *, n_channels: int = 1) -> float:
    """Closed-form period time of an arbitrary per-phase position plan."""
    return sum(simulate_phase(profile, pos,
                              n_channels=n_channels).iteration_time
               for pos in positions_per_phase)


def _event_boundaries(scenario, H: int) -> list[int]:
    """All iterations at which scenario state changes (incl. window ends)."""
    out = []
    for ev in scenario.events:
        fire = ev.fire_iteration(H)
        out.append(fire)
        dur = getattr(ev, "duration_periods", None)
        if dur is not None:
            out.append(fire + dur * H)
    return sorted(out)


def _static_periods(scenario, H: int, trace: Trace) -> tuple[list[int],
                                                             list[int]]:
    """Periods whose cluster/network state is constant throughout."""
    boundaries = _event_boundaries(scenario, H)
    drift_times: list[float] = []
    for tr in (scenario.drift or {}).values():
        drift_times.extend(tr.times())
    static, skipped = [], []
    for p in range(trace.n_periods):
        lo, hi = p * H, (p + 1) * H
        t0 = trace.period_start(p)
        t1 = trace.iteration_spans[hi - 1][1]
        mid_event = any(lo < b < hi for b in boundaries)
        mid_drift = any(t0 < t < t1 for t in drift_times)
        (skipped if (mid_event or mid_drift) else static).append(p)
    return static, skipped


def check_scenario(scenario, *, algo: str = "dreamddp", H: int = 4,
                   profile: LayerProfile | None = None,
                   n_channels: int = 1, rtol: float = DEFAULT_RTOL,
                   fill_mode: str = "exact") -> ConformanceReport:
    """Run a scenario and compare every static window to the time model."""
    from ..api.registry import get_strategy

    if any(spec.jitter > 0 for spec in
           (scenario.intra, scenario.inter) if spec is not None):
        raise ValueError(
            f"scenario {scenario.name!r} has link jitter; its timing is "
            f"seeded noise and cannot be conformance-checked")
    if profile is None:
        profile = synthetic_profile()

    cluster, plan = prepare_run(scenario, get_strategy(algo), H, profile,
                                fill_mode=fill_mode)
    ex = SimExecutor(profile, plan, cluster, n_channels=n_channels)
    trace = ex.run(scenario.periods)

    report = ConformanceReport(scenario=scenario.name, algo=algo, H=plan.H,
                               trace=trace)
    static, report.skipped_periods = _static_periods(scenario, plan.H,
                                                     trace)
    # A replica cluster replayed iteration-by-iteration (with the trace's
    # actual clocks) gives the closed-form view; per-iteration advancing
    # attributes transient-failure stalls to the period they fired in.
    # Built with the plan's actual period length so event conversion and
    # window bookkeeping line up even when the strategy forced H.
    ref = scenario.build(plan.H)
    stall_by_period = [0.0] * trace.n_periods
    eff_by_period: dict[int, LayerProfile] = {}
    for r in range(trace.n_periods * plan.H):
        t_r = trace.iteration_spans[r][0]
        ref.advance(r, t_r)
        p = r // plan.H
        stall_by_period[p] += ref.take_stall()
        if r % plan.H == 0 and p in static:
            eff_by_period[p] = ref.effective_profile(profile, t_r)
    for p in static:
        expected = stall_by_period[p] + reference_period_time(
            eff_by_period[p], ex.positions_per_phase,
            n_channels=n_channels)
        report.checks.append(WindowCheck(
            period=p, expected=expected, simulated=trace.period_time(p),
            rtol=rtol))
    return report


def check_library(*, algos=("dreamddp", "plsgd-enp", "flsgd"), H: int = 4,
                  profile: LayerProfile | None = None,
                  rtol: float = DEFAULT_RTOL) -> list[ConformanceReport]:
    """Conformance-check every library scenario under several strategies."""
    from .scenarios import available_scenarios, get_scenario

    reports = []
    for name in available_scenarios():
        for algo in algos:
            reports.append(check_scenario(get_scenario(name), algo=algo,
                                          H=H, profile=profile, rtol=rtol))
    return reports
