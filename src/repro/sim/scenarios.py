"""The scenario library — named, seeded geo-cluster regimes.

A :class:`Scenario` is pure data (see ``src/repro/sim/README.md`` for the
full schema): a topology, per-link specs and drift traces, and a tuple of
:mod:`~repro.sim.events` that fire at period boundaries.  ``build(H)``
instantiates the mutable :class:`~repro.sim.events.VirtualCluster` for a
run with period length ``H``; identical ``(scenario, H)`` builds replay
identically.

The built-in library covers the regimes the paper and its related work
(FusionLLM's heterogeneous links, HALoS' hierarchical geo-clusters)
evaluate:

==================  =====================================================
``homogeneous``     flat single-DC cluster, static 1 GB/s link
``hier-2tier``      2 datacenters, fast intra / slow+laggy inter links
``drifting-bandwidth``  WAN bandwidth steps down 1 GB/s -> 150 MB/s
``straggler``       one worker computes 2.5x slower for one period
``churn``           2 workers leave, then 2 (new ids) join
``transient-failure``   a worker drops and recovers (whole-DP stall)
``degraded-inter``  inter-DC link degraded to 30% for one period
==================  =====================================================

Run the library's conformance sweep from the CLI (the ``make sim``
target)::

    PYTHONPATH=src python -m repro.sim [--algo dreamddp] [-H 4]
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .events import (BandwidthDrift, LinkDegradation, ScenarioEvent,
                     StragglerOnset, TransientFailure, VirtualCluster,
                     WorkerJoin, WorkerLeave)
from .network import DriftTrace, LinkSpec, NetworkModel, Topology

__all__ = ["Scenario", "register_scenario", "get_scenario",
           "available_scenarios", "SCENARIOS"]


@dataclass(frozen=True)
class Scenario:
    """Declarative description of one simulated geo-cluster regime."""

    name: str
    description: str
    n_workers: int = 8
    n_datacenters: int = 1
    intra: LinkSpec = LinkSpec(bandwidth=1e9, latency=1e-4)
    inter: LinkSpec | None = None
    drift: dict[str, DriftTrace] = field(default_factory=dict)
    events: tuple[ScenarioEvent, ...] = ()
    periods: int = 3
    seed: int = 0

    def topology(self) -> Topology:
        return Topology(self.n_workers, self.n_datacenters)

    def build(self, H: int) -> VirtualCluster:
        """Instantiate the mutable cluster for a run with period ``H``."""
        net = NetworkModel(self.topology(), self.intra, self.inter,
                           drift=dict(self.drift))
        return VirtualCluster(net, self.events, H=H, seed=self.seed)


# ---------------------------------------------------------------- registry

SCENARIOS: dict[str, Scenario] = {}


def register_scenario(sc: Scenario) -> Scenario:
    if sc.name in SCENARIOS:
        raise ValueError(f"scenario {sc.name!r} already registered")
    SCENARIOS[sc.name] = sc
    return sc


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; available: "
                       f"{available_scenarios()}") from None


def available_scenarios() -> tuple[str, ...]:
    return tuple(sorted(SCENARIOS))


# ------------------------------------------------------------ the library

register_scenario(Scenario(
    name="homogeneous",
    description="Flat single-DC cluster on a static 1 GB/s link; the "
                "executor must reproduce time_model exactly.",
    n_workers=8, periods=2,
))

register_scenario(Scenario(
    name="hier-2tier",
    description="Two datacenters (HALoS regime): 20 GB/s intra links, "
                "200 MB/s / 5 ms inter-DC WAN; hierarchical all-reduce.",
    n_workers=8, n_datacenters=2,
    intra=LinkSpec(bandwidth=2e10, latency=5e-5),
    inter=LinkSpec(bandwidth=2e8, latency=5e-3),
    periods=2,
))

register_scenario(Scenario(
    name="drifting-bandwidth",
    description="WAN bandwidth steps 1 GB/s -> 150 MB/s at period 1 "
                "(piecewise-constant drift); replanning should move "
                "comm off the critical path again.",
    n_workers=8,
    events=(BandwidthDrift(period=1, link="intra", bandwidth=1.5e8),),
    periods=3,
))

register_scenario(Scenario(
    name="straggler",
    description="Worker 3 computes 2.5x slower during period 1 only "
                "(thermal throttling / noisy neighbour); fast 20 GB/s "
                "link so the cluster is compute-bound and the straggler "
                "gates the critical path.",
    n_workers=8,
    intra=LinkSpec(bandwidth=2e10, latency=5e-5),
    events=(StragglerOnset(period=1, worker=3, slowdown=2.5,
                           duration_periods=1),),
    periods=3,
))

register_scenario(Scenario(
    name="churn",
    description="Elastic membership: 2 workers leave at period 1, 2 new "
                "workers join at period 2 (ring size changes twice).",
    n_workers=8,
    events=(WorkerLeave(period=1, n=2), WorkerJoin(period=2, n=2)),
    periods=3,
))

register_scenario(Scenario(
    name="transient-failure",
    description="Worker 0 fails at period 1 and recovers after 50 ms; "
                "synchronous DP stalls the whole iteration.",
    n_workers=8,
    events=(TransientFailure(period=1, worker=0, downtime=0.05),),
    periods=3,
))

register_scenario(Scenario(
    name="degraded-inter",
    description="Two-tier cluster whose inter-DC link degrades to 30% "
                "bandwidth for one period, then recovers.",
    n_workers=8, n_datacenters=2,
    intra=LinkSpec(bandwidth=2e10, latency=5e-5),
    inter=LinkSpec(bandwidth=5e8, latency=2e-3),
    events=(LinkDegradation(period=1, link="inter", factor=0.3,
                            duration_periods=1),),
    periods=3,
))


# ----------------------------------------------------------------- CLI

def main(argv=None) -> int:
    """Conformance-sweep the whole library (the ``make sim`` target)."""
    import argparse

    from .conformance import check_library

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--algo", action="append", default=None,
                    help="strategy to check (repeatable); default: "
                         "dreamddp, plsgd-enp, flsgd")
    ap.add_argument("-H", "--period", type=int, default=4)
    args = ap.parse_args(argv)
    algos = tuple(args.algo) if args.algo else ("dreamddp", "plsgd-enp",
                                                "flsgd")
    reports = check_library(algos=algos, H=args.period)
    for r in reports:
        print(r.summary())
    bad = [r for r in reports if not r.ok]
    print(f"{len(reports) - len(bad)}/{len(reports)} conformance "
          f"checks passed")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
