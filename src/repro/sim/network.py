"""Virtual geo-cluster network: links, drift traces, 2-tier topology.

The simulator charges every synchronization collective against a
:class:`NetworkModel` — a two-tier (intra-DC / inter-DC) topology whose
links have piecewise-constant, time-varying bandwidth:

* a declarative :class:`DriftTrace` (the scenario's bandwidth-over-time
  curve, in seconds of simulated time);
* absolute re-bases pushed at event time (:class:`~repro.sim.events
  .BandwidthDrift` fires ``set_bandwidth``);
* multiplicative degradation windows (``degrade`` / ``end_degradation``
  for :class:`~repro.sim.events.LinkDegradation`).

Transfers are integrated exactly over the resulting piecewise-constant
bandwidth function, so a transfer straddling a drift breakpoint takes the
correct integral time — no per-step discretization error.  With a static
link, :meth:`NetworkModel.collective_time` on a flat topology reproduces
:func:`repro.core.profiler.ring_allreduce_time` bit-for-bit, which is what
makes the conformance suite's exact comparisons possible.
"""

from __future__ import annotations

import bisect
import math
from dataclasses import dataclass, field

__all__ = ["LinkSpec", "DriftTrace", "Topology", "NetworkModel",
           "ring_factor"]

_INF = math.inf


@dataclass(frozen=True)
class LinkSpec:
    """Static description of one link class.

    ``jitter`` is the fractional half-width of a uniform multiplicative
    noise applied per transfer by the cluster's seeded RNG (0 = exact,
    deterministic timing — required by the conformance suite).
    """

    bandwidth: float                   # bytes/s
    latency: float = 0.0               # s per collective stage
    jitter: float = 0.0                # +/- fraction per transfer


@dataclass(frozen=True)
class DriftTrace:
    """Piecewise-constant bandwidth curve over simulated seconds.

    ``breakpoints`` is a sorted tuple of ``(time, bandwidth)``; before the
    first breakpoint the link's base bandwidth applies.
    """

    breakpoints: tuple[tuple[float, float], ...] = ()

    def __post_init__(self):
        ts = [t for t, _ in self.breakpoints]
        if ts != sorted(ts):
            raise ValueError("DriftTrace breakpoints must be time-sorted")

    def value_at(self, t: float, default: float) -> float:
        out = default
        for bt, bw in self.breakpoints:
            if bt <= t:
                out = bw
            else:
                break
        return out

    def times(self) -> list[float]:
        return [t for t, _ in self.breakpoints]


@dataclass(frozen=True)
class Topology:
    """Round-robin assignment of workers to datacenters.

    Worker ``w`` lives in datacenter ``w % n_datacenters`` — round-robin
    (rather than block) assignment keeps datacenters balanced under
    elastic join/leave, which always adds/removes extremal worker ids.
    """

    n_workers: int
    n_datacenters: int = 1

    def __post_init__(self):
        if self.n_workers < 1 or self.n_datacenters < 1:
            raise ValueError("need >= 1 worker and >= 1 datacenter")

    def dc_of(self, worker: int) -> int:
        return worker % self.n_datacenters

    def workers_by_dc(self, active) -> list[int]:
        counts = [0] * self.n_datacenters
        for w in active:
            counts[self.dc_of(w)] += 1
        return counts


def ring_factor(k: int) -> float:
    """Bandwidth-optimal ring all-reduce traffic factor ``2 (K-1)/K``.

    Mirrors :func:`repro.core.profiler.ring_allreduce_time`'s ``K >= 2``
    clamp so a flat static network reproduces profiled comm times exactly.
    """
    k = max(k, 2)
    return 2.0 * (k - 1) / k


@dataclass
class _LinkState:
    """One link class's mutable time-varying bandwidth."""

    spec: LinkSpec
    trace: DriftTrace = field(default_factory=DriftTrace)
    # absolute re-bases: sorted (t_from, bandwidth); overrides trace+spec
    overrides: list[tuple[float, float]] = field(default_factory=list)
    # multiplicative windows: [t0, t1) x factor; t1 = inf until closed
    degradations: list[list[float]] = field(default_factory=list)

    def base_bandwidth_at(self, t: float) -> float:
        if self.overrides:
            i = bisect.bisect_right([o[0] for o in self.overrides], t)
            if i > 0:
                return self.overrides[i - 1][1]
        return self.trace.value_at(t, self.spec.bandwidth)

    def bandwidth_at(self, t: float) -> float:
        bw = self.base_bandwidth_at(t)
        for t0, t1, factor in self.degradations:
            if t0 <= t < t1:
                bw *= factor
        return bw

    def breakpoints_after(self, t: float) -> list[float]:
        pts = set(self.trace.times())
        pts.update(o[0] for o in self.overrides)
        for t0, t1, _ in self.degradations:
            pts.add(t0)
            if t1 != _INF:
                pts.add(t1)
        return sorted(p for p in pts if p > t)


class NetworkModel:
    """Two-tier time-varying network (link classes ``intra`` / ``inter``)."""

    LINKS = ("intra", "inter")

    def __init__(self, topology: Topology, intra: LinkSpec,
                 inter: LinkSpec | None = None, *,
                 drift: dict[str, DriftTrace] | None = None):
        if topology.n_datacenters > 1 and inter is None:
            raise ValueError("multi-datacenter topology needs an inter link")
        self.topology = topology
        drift = drift or {}
        unknown = set(drift) - set(self.LINKS)
        if unknown:
            raise ValueError(f"unknown drift link(s) {sorted(unknown)}")
        self._links = {"intra": _LinkState(intra,
                                           drift.get("intra", DriftTrace()))}
        if inter is not None:
            self._links["inter"] = _LinkState(
                inter, drift.get("inter", DriftTrace()))

    # ------------------------------------------------------------- mutation
    def _link(self, name: str) -> _LinkState:
        try:
            return self._links[name]
        except KeyError:
            raise ValueError(f"no {name!r} link in this topology") from None

    def set_bandwidth(self, link: str, bandwidth: float,
                      t_from: float) -> None:
        """Re-base a link's bandwidth from ``t_from`` onward (drift event)."""
        st = self._link(link)
        if st.overrides and t_from < st.overrides[-1][0]:
            raise ValueError("bandwidth re-bases must be time-ordered")
        st.overrides.append((t_from, bandwidth))

    def degrade(self, link: str, factor: float, t_from: float) -> object:
        """Open a multiplicative degradation window; returns a handle."""
        window = [t_from, _INF, factor]
        self._link(link).degradations.append(window)
        return window

    def end_degradation(self, handle: object, t_end: float) -> None:
        handle[1] = t_end

    # -------------------------------------------------------------- queries
    def link_spec(self, link: str) -> LinkSpec:
        """The static spec of one link class (latency/jitter lookup)."""
        return self._link(link).spec

    def bandwidth_at(self, link: str, t: float) -> float:
        return self._link(link).bandwidth_at(t)

    def transfer_time(self, link: str, nbytes: float, start: float) -> float:
        """Integrate ``nbytes`` over the piecewise-constant bandwidth.

        Zero-bandwidth segments stall the transfer until the next
        breakpoint (an outage window is a degradation with factor 0).
        Latency is *not* included — collectives add it per stage.
        """
        if nbytes <= 0:
            return 0.0
        st = self._link(link)
        remaining = float(nbytes)
        t = start
        pts = st.breakpoints_after(start)
        for nxt in pts + [_INF]:
            bw = st.bandwidth_at(t)
            if bw > 0:
                span = nxt - t
                if remaining <= bw * span:
                    return t + remaining / bw - start
                remaining -= bw * span
            elif nxt == _INF:
                raise RuntimeError(
                    f"{link} link bandwidth is 0 forever from t={t}; "
                    f"transfer can never finish")
            t = nxt
        raise AssertionError("unreachable")

    def collective_time(self, nbytes: float, start: float, *,
                        workers_by_dc: list[int] | None = None,
                        rng=None) -> float:
        """One parameter/gradient all-reduce of ``nbytes`` starting at
        ``start`` with the given active membership.

        Flat topology: one ring over the ``intra`` link.  Two-tier:
        per-DC intra rings (in parallel; the slowest DC gates), then one
        inter-DC ring over the datacenters that hold workers — the
        standard hierarchical all-reduce decomposition.

        ``rng`` (the cluster's seeded RNG) applies each link's jitter as
        a uniform multiplicative factor; ``None`` disables jitter (used
        by the conformance reference, which must be closed-form).
        """
        if workers_by_dc is None:
            workers_by_dc = self.topology.workers_by_dc(
                range(self.topology.n_workers))
        populated = [k for k in workers_by_dc if k > 0]
        total = sum(populated)
        if total == 0:
            raise ValueError("collective with no active workers")

        def stage(link: str, eff_bytes: float, t: float) -> float:
            spec = self._link(link).spec
            dur = self.transfer_time(link, eff_bytes, t) + spec.latency
            if rng is not None and spec.jitter > 0:
                dur *= 1.0 + spec.jitter * (2.0 * rng.random() - 1.0)
            return dur

        if "inter" not in self._links or self.topology.n_datacenters == 1:
            return stage("intra", ring_factor(total) * nbytes, start)

        # two-tier: parallel intra rings, then the inter-DC ring
        intra = max((stage("intra", ring_factor(k) * nbytes, start)
                     if k > 1 else 0.0) for k in populated)
        inter = 0.0
        if len(populated) > 1:
            inter = stage("inter",
                          ring_factor(len(populated)) * nbytes,
                          start + intra)
        return intra + inter
