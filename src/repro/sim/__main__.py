"""``python -m repro.sim`` — conformance-sweep the scenario library."""

from .scenarios import main

if __name__ == "__main__":
    raise SystemExit(main())
