"""Trace — the simulator's output artifact.

A :class:`Trace` is a flat, append-only list of per-layer compute/comm
:class:`Interval`\\ s plus the applied scenario events and per-iteration
bounds.  It serializes to *canonical* JSON (sorted keys, shortest
round-trip floats), so two runs with identical seeds compare
byte-identical — the determinism contract the test suite pins down with
:meth:`Trace.fingerprint`.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field

__all__ = ["Interval", "Trace"]


@dataclass(frozen=True)
class Interval:
    """One span of simulated activity.

    ``kind`` is ``fp`` (whole-model forward), ``bp`` (one layer's
    backward), ``comm`` (one unit's all-reduce) or ``stall`` (transient-
    failure wait).  The async runtime adds ``pull`` / ``compute`` /
    ``push`` / ``merge`` spans.  ``unit`` is the network-order layer id,
    or -1 for whole-model spans.  ``worker`` identifies whose timeline
    the span belongs to in async traces (-1 for the synchronous
    executor, where every worker shares one timeline).
    """

    kind: str
    iteration: int
    phase: int
    unit: int
    start: float
    end: float
    worker: int = -1

    @property
    def duration(self) -> float:
        return self.end - self.start

    def to_dict(self) -> dict:
        return {"kind": self.kind, "iteration": self.iteration,
                "phase": self.phase, "unit": self.unit,
                "start": self.start, "end": self.end,
                "worker": self.worker}


@dataclass
class Trace:
    """Full timeline of one simulated run (times in seconds from 0)."""

    H: int
    intervals: list[Interval] = field(default_factory=list)
    events: list[dict] = field(default_factory=list)
    iteration_spans: list[tuple[float, float]] = field(default_factory=list)
    meta: dict = field(default_factory=dict)

    # ------------------------------------------------------------- queries
    @property
    def n_iterations(self) -> int:
        return len(self.iteration_spans)

    @property
    def n_periods(self) -> int:
        return self.n_iterations // self.H

    @property
    def makespan(self) -> float:
        return self.iteration_spans[-1][1] if self.iteration_spans else 0.0

    def iteration_time(self, r: int) -> float:
        s, e = self.iteration_spans[r]
        return e - s

    def period_start(self, p: int) -> float:
        return self.iteration_spans[p * self.H][0]

    def period_time(self, p: int) -> float:
        return (self.iteration_spans[(p + 1) * self.H - 1][1]
                - self.iteration_spans[p * self.H][0])

    def period_times(self) -> list[float]:
        return [self.period_time(p) for p in range(self.n_periods)]

    def of_kind(self, kind: str, iteration: int | None = None
                ) -> list[Interval]:
        return [iv for iv in self.intervals if iv.kind == kind
                and (iteration is None or iv.iteration == iteration)]

    def exposed_comm(self, r: int) -> float:
        """Comm time of iteration ``r`` not hidden under its backward."""
        bps = self.of_kind("bp", r)
        bp_end = max((iv.end for iv in bps), default=0.0)
        comm_end = max((iv.end for iv in self.of_kind("comm", r)),
                       default=bp_end)
        return max(0.0, comm_end - bp_end)

    def total_exposed_comm(self) -> float:
        return sum(self.exposed_comm(r) for r in range(self.n_iterations))

    # ------------------------------------------------------ serialization
    def to_dict(self) -> dict:
        return {
            "H": self.H,
            "intervals": [iv.to_dict() for iv in self.intervals],
            "events": self.events,
            "iteration_spans": [list(s) for s in self.iteration_spans],
            "meta": self.meta,
        }

    def to_json(self) -> str:
        """Canonical JSON: identical replays are byte-identical."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    @staticmethod
    def from_json(s: str) -> "Trace":
        o = json.loads(s)
        return Trace(
            H=o["H"],
            intervals=[Interval(**iv) for iv in o["intervals"]],
            events=o["events"],
            iteration_spans=[tuple(x) for x in o["iteration_spans"]],
            meta=o["meta"],
        )

    def fingerprint(self) -> str:
        return hashlib.sha256(self.to_json().encode()).hexdigest()[:16]
