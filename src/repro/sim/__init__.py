"""repro.sim — deterministic geo-cluster simulator (SimNet).

Drives the real :class:`~repro.core.plans.SyncPlan` machinery against a
virtual network instead of a live mesh, so heterogeneous inter-DC links,
bandwidth drift, stragglers and worker churn become CI-runnable tests
and benchmarks:

* :mod:`~repro.sim.network` — links, piecewise-constant drift, 2-tier
  (intra-DC / inter-DC) topology;
* :mod:`~repro.sim.events` — scenario events + the seeded
  :class:`VirtualCluster` replaying them;
* :mod:`~repro.sim.executor` — :class:`SimExecutor` replays a plan's
  phase timeline, producing a :class:`~repro.sim.trace.Trace`;
* :mod:`~repro.sim.scenarios` — the named scenario library;
* :mod:`~repro.sim.conformance` — checks the simulator against
  :mod:`repro.core.time_model` on every static window.

Quick start::

    from repro.api import JobConfig, Session
    report = Session(JobConfig(algo="dreamddp", period=4)).simulate(
        "drifting-bandwidth")
    print(report.summary())

See ``src/repro/sim/README.md`` for the scenario schema.
"""

from .conformance import (ConformanceReport, WindowCheck, check_library,
                          check_scenario, reference_period_time,
                          synthetic_profile)
from .events import (REPLAN_EVENTS, BandwidthDrift, LinkDegradation,
                     ScenarioEvent, StragglerOnset, TransientFailure,
                     VirtualCluster, WorkerJoin, WorkerLeave)
from .executor import SimExecutor, SimReport, prepare_run
from .network import DriftTrace, LinkSpec, NetworkModel, Topology
from .scenarios import (SCENARIOS, Scenario, available_scenarios,
                        get_scenario, register_scenario)
from .trace import Interval, Trace

__all__ = [
    "LinkSpec", "DriftTrace", "Topology", "NetworkModel",
    "ScenarioEvent", "StragglerOnset", "LinkDegradation", "BandwidthDrift",
    "WorkerJoin", "WorkerLeave", "TransientFailure", "VirtualCluster",
    "REPLAN_EVENTS",
    "SimExecutor", "SimReport", "prepare_run", "Interval", "Trace",
    "Scenario", "SCENARIOS", "register_scenario", "get_scenario",
    "available_scenarios",
    "ConformanceReport", "WindowCheck", "check_scenario", "check_library",
    "reference_period_time", "synthetic_profile",
]
