"""DreamDDP schedule search (paper §3.3, Algorithm 2).

Given a :class:`~repro.core.profiler.LayerProfile` and a synchronization
period ``H``, find the contiguous-interval partition of the ``L`` layer units
into ``H`` phases that minimizes the paper's Eq. 8 per-period time.

Three search strategies are provided:

* :func:`brute_force_schedule` — exhaustive enumeration of all
  ``C(L+H-1, H-1)``-ish interval partitions (paper's reference optimum,
  Fig. 15); only feasible for small ``L``.
* :func:`dreamddp_schedule` — Algorithm 2: a DFS whose branching is pruned by
  the three properties *Optimal Hiding* (Property 1), *Delayed CO Assignment*
  (Property 2) and *At-Least-One Assignment* (Property 3), reducing the
  solution-set size to ``O(2^min(L-H, H))``.
* :func:`enp_schedule` — the Equal-Number Partition baseline (Example 1,
  PLSGD-ENP in the paper's tables).

All schedulers reason in **backward order** (position 0 = output-most layer),
matching the paper: phase 1 synchronizes the layers whose BP finishes first.

Search statistics (solutions enumerated, recursion nodes) are returned so the
Fig. 16 complexity benchmark reads real counters instead of re-deriving
formulas.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from .profiler import LayerProfile
from .time_model import Partition, objective, simulate_period

__all__ = [
    "ScheduleResult",
    "SearchStats",
    "brute_force_schedule",
    "dreamddp_schedule",
    "enp_schedule",
    "brute_force_count",
]


@dataclass
class SearchStats:
    """Counters for the Fig. 16 search-complexity comparison."""

    nodes_visited: int = 0          # recursion invocations
    solutions: int = 0              # size of the solution set Omega
    aloha_hits: int = 0             # Property 3 (at-least-one) applications
    optimal_hiding_hits: int = 0    # Property 1 applications
    delayed_co_hits: int = 0        # Property 2 applications
    branch_hits: int = 0            # un-pruned DFS branches


@dataclass
class ScheduleResult:
    """Outcome of a schedule search."""

    partition: Partition
    objective: float                 # Eq. 8 value of the chosen partition
    stats: SearchStats = field(default_factory=SearchStats)

    @property
    def counts(self) -> tuple[int, ...]:
        return self.partition.counts


# ---------------------------------------------------------------------------
# Brute force (paper's reference optimum, Fig. 15)
# ---------------------------------------------------------------------------

def brute_force_count(n_layers: int, n_phases: int) -> int:
    """Number of weak compositions of L into H parts = C(L+H-1, H-1)."""
    from math import comb

    return comb(n_layers + n_phases - 1, n_phases - 1)


def _compositions(total: int, parts: int):
    """All weak compositions of ``total`` into ``parts`` non-negative ints."""
    if parts == 1:
        yield (total,)
        return
    for first in range(total + 1):
        for rest in _compositions(total - first, parts - 1):
            yield (first,) + rest


def brute_force_schedule(profile: LayerProfile, H: int) -> ScheduleResult:
    """Exhaustively minimize Eq. 8 over all interval partitions."""
    L = len(profile)
    stats = SearchStats()
    best, best_val = None, float("inf")
    for counts in _compositions(L, H):
        stats.solutions += 1
        part = Partition(counts)
        val = objective(profile, part)
        if val < best_val - 1e-15:
            best, best_val = part, val
    stats.nodes_visited = stats.solutions
    assert best is not None
    return ScheduleResult(best, best_val, stats)


# ---------------------------------------------------------------------------
# Equal-Number Partition (paper Example 1; PLSGD-ENP baseline)
# ---------------------------------------------------------------------------

def enp_schedule(profile: LayerProfile, H: int) -> ScheduleResult:
    part = Partition.equal_number(len(profile), H)
    return ScheduleResult(part, objective(profile, part))


# ---------------------------------------------------------------------------
# Algorithm 2: pruned DFS
# ---------------------------------------------------------------------------

class _DFS:
    """State for one Algorithm-2 search (times pre-extracted, BP order)."""

    def __init__(self, profile: LayerProfile, H: int,
                 max_solutions: int | None):
        bp = profile.bp_order()
        self.L = len(bp)
        self.H = H
        self.t_bp = [c.t_bp for c in bp]           # index = BP position
        self.t_comm = [c.t_comm for c in bp]
        self.t_bp_total = sum(self.t_bp)
        # suffix[i] = sum of t_bp for BP positions >= i  (= t_BP^{L_{h:H}}
        # when position i is the first layer of phase h's interval start)
        self.bp_suffix = [0.0] * (self.L + 1)
        for i in range(self.L - 1, -1, -1):
            self.bp_suffix[i] = self.bp_suffix[i + 1] + self.t_bp[i]
        self.stats = SearchStats()
        self.solutions: list[tuple[int, ...]] = []
        self.max_solutions = max_solutions

    # -- helper terms -------------------------------------------------------
    def _bp_rest_minus_h0(self, start: int) -> float:
        """``t_BP^{L_{h:H}} - t_BP^{h0}`` for a phase whose interval starts at
        BP position ``start``.  All layers from ``start`` to the input run
        their BP in this iteration; the first layer's own BP cannot overlap
        its own communication."""
        return self.bp_suffix[start] - self.t_bp[start]

    def run(self) -> None:
        # partition under construction: counts per phase (BP order)
        self._solve(next_pos=0, h=0, counts=[], cur=0, cur_comm=0.0,
                    cur_start=0)

    def _record(self, counts: list[int], cur: int) -> None:
        out = counts + [cur]
        # pad trailing empty phases
        out += [0] * (self.H - len(out))
        self.solutions.append(tuple(out))
        self.stats.solutions += 1

    def _full(self) -> bool:
        return (self.max_solutions is not None
                and len(self.solutions) >= self.max_solutions)

    def _solve(self, next_pos: int, h: int, counts: list[int], cur: int,
               cur_comm: float, cur_start: int) -> None:
        """Assign BP positions ``next_pos..L-1`` to phases ``h..H-1``.

        ``cur``/``cur_comm``/``cur_start`` describe the (open) phase ``h``:
        number of layers so far, their summed comm time, and the BP position
        of the phase's first (output-most) layer.
        """
        if self._full():
            return
        self.stats.nodes_visited += 1
        if next_pos == self.L:                       # all layers assigned
            self._record(counts, cur)
            return
        if h == self.H - 1:                          # last phase takes rest
            self._record(counts, cur + (self.L - next_pos))
            return

        l = next_pos
        if cur == 0:
            # Property 3 (At-Least-One): an empty phase always takes the
            # next layer — assigning it cannot be worse than delaying.
            self.stats.aloha_hits += 1
            self._solve(l + 1, h, counts, 1, self.t_comm[l], l)
            return

        hide_budget = self._bp_rest_minus_h0(cur_start)
        if hide_budget >= cur_comm + self.t_comm[l]:
            # Property 1 (Optimal Hiding): the extra comm is still fully
            # hidden -> taking the layer now is never worse.
            self.stats.optimal_hiding_hits += 1
            self._solve(l + 1, h, counts, cur + 1,
                        cur_comm + self.t_comm[l], cur_start)
            return
        if hide_budget < cur_comm:
            # Property 2 (Delayed CO Assignment): this phase already
            # overflows; adding more comm only grows the overflow.  Close
            # the phase and delay layer ``l``.
            self.stats.delayed_co_hits += 1
            self._solve(l, h + 1, counts + [cur], 0, 0.0, l)
            return

        # Un-pruned case: branch (true DFS).
        self.stats.branch_hits += 1
        # branch A: assign l to phase h (overflows it)
        self._solve(l + 1, h, list(counts), cur + 1,
                    cur_comm + self.t_comm[l], cur_start)
        # branch B: close phase h, delay l to phase h+1
        self._solve(l, h + 1, counts + [cur], 0, 0.0, l)


def dreamddp_schedule(profile: LayerProfile, H: int, *,
                      refine_exact: bool = True,
                      max_solutions: int | None = 200_000) -> ScheduleResult:
    """Algorithm 2: pruned DFS over interval partitions.

    ``refine_exact`` re-ranks the best few candidates with the exact
    event-driven timeline (:func:`~repro.core.time_model.simulate_period`),
    which breaks Eq. 8 ties in favour of schedules whose tau-recursion
    (per-layer comm serialization) is cheaper.
    """
    if H <= 0:
        raise ValueError(f"H must be positive, got {H}")
    L = len(profile)
    if L == 0:
        raise ValueError("empty profile")
    if H == 1:
        # Degenerate: everything in one phase (== FLSGD with overlap).
        part = Partition((L,))
        return ScheduleResult(part, objective(profile, part))
    H_eff = min(H, L)  # at most one layer per phase is meaningful

    dfs = _DFS(profile, H_eff, max_solutions)
    dfs.run()
    assert dfs.solutions, "Algorithm 2 produced no candidate partitions"

    scored = []
    for counts in dfs.solutions:
        counts = counts + (0,) * (H - H_eff)
        part = Partition(counts)
        scored.append((objective(profile, part), part))
    scored.sort(key=lambda t: t[0])

    best_val, best = scored[0]
    if refine_exact and len(scored) > 1:
        # exact-timeline re-rank among near-ties (within 1% of Eq. 8 min)
        cutoff = best_val * (1.0 + 1e-2) + 1e-12
        cands = [p for v, p in scored if v <= cutoff][:64]
        def exact(p: Partition) -> float:
            return sum(tl.iteration_time
                       for tl in simulate_period(profile, p))
        best = min(cands, key=exact)
        best_val = objective(profile, best)

    return ScheduleResult(best, best_val, dfs.stats)
