"""Supplementary communication — "Filling the Bubble Time" (paper §3.4).

After Algorithm 2 fixes the base partition, late phases of a period often
leave the link idle while BP still runs.  DreamDDP fills that idle time with
*extra* synchronizations of the **late layers** (output-most; they converge
last, so extra averaging helps most), subject to Eq. 12: the filled phase's
time must not exceed the unfilled phase's time.

Two admission checks are provided:

* ``mode="eq12"`` — the paper's closed form (Eq. 12), comparing summed comm
  against the BP hiding budget;
* ``mode="exact"`` — event-timeline check via
  :func:`~repro.core.time_model.simulate_phase`: admit the fill only if the
  phase's simulated iteration time does not grow.  Strictly more permissive
  than Eq. 12 is *not* guaranteed — it honours per-layer readiness — so it is
  the default used by the runtime, while benchmarks report both.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .profiler import LayerProfile
from .time_model import Partition, simulate_phase

__all__ = ["FillResult", "fill_bubbles"]

_EPS = 1e-12


@dataclass
class FillResult:
    """Extra BP positions synchronized per phase (the §3.4 supplement)."""

    fills: list[list[int]] = field(default_factory=list)   # per phase
    extra_syncs: int = 0                                    # total extra layer-syncs per period

    def sync_counts(self, partition: Partition) -> list[int]:
        """Per-BP-position sync count over one period (>= 1 everywhere)."""
        n = partition.n_layers
        counts = [1] * n
        for fill in self.fills:
            for pos in fill:
                counts[pos] += 1
        return counts


def _phase_hiding_budget(profile: LayerProfile, partition: Partition,
                         h: int) -> float:
    """``t_BP^{L_{h:H}} - t_BP^{h0}`` for phase ``h`` (Eq. 12 LHS budget)."""
    bp = profile.bp_order()
    s, e = partition.bp_intervals()[h]
    if s == e:
        return sum(c.t_bp for c in bp[s:])
    rest = sum(c.t_bp for c in bp[s:])
    return rest - bp[s].t_bp


def fill_bubbles(profile: LayerProfile, partition: Partition, *,
                 mode: str = "exact", n_channels: int = 1) -> FillResult:
    """Greedily add late-layer syncs to every phase, per Eq. 12 / timeline.

    For phase ``h`` the candidate extra set is the paper's ``{L, ..., l}`` —
    a *prefix* of BP positions (output-most layers first), disjoint from the
    phase's own interval.  We grow the prefix while the admission check
    holds, i.e. pick the paper's minimal ``l`` (maximal set).
    """
    if mode not in ("eq12", "exact"):
        raise ValueError(f"unknown fill mode {mode!r}")
    bp = profile.bp_order()
    result = FillResult(fills=[[] for _ in partition.counts])
    intervals = partition.bp_intervals()

    for h, (s, e) in enumerate(intervals):
        own = set(range(s, e))
        if mode == "eq12":
            budget = _phase_hiding_budget(profile, partition, h)
            base_comm = sum(bp[i].t_comm for i in own)
            base_time = max(budget, base_comm)
            extra: list[int] = []
            extra_comm = 0.0
            for pos in range(len(bp)):              # prefix of BP positions
                if pos in own:
                    continue
                cand = extra_comm + bp[pos].t_comm
                if max(budget, base_comm + cand) <= base_time + _EPS:
                    extra.append(pos)
                    extra_comm = cand
                else:
                    break                            # contiguous prefix only
        else:
            base_tl = simulate_phase(profile, sorted(own),
                                     n_channels=n_channels)
            base_time = base_tl.iteration_time
            extra = []
            for pos in range(len(bp)):
                if pos in own:
                    continue
                cand = sorted(own | set(extra) | {pos})
                tl = simulate_phase(profile, cand, n_channels=n_channels)
                if tl.iteration_time <= base_time + _EPS:
                    extra.append(pos)
                else:
                    break
        result.fills[h] = extra
        result.extra_syncs += len(extra)
    return result
