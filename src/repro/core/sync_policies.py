"""Composable synchronization policies — the *sync hook* of a strategy.

A :class:`SyncPolicy` decides **how** the layer units scheduled for a phase
are reconciled across workers, independently of **which** units the
:class:`~repro.core.plans.SyncPlan` schedules:

* :class:`MeanSync` — plain float32 parameter averaging (paper Eq. 5);
* :class:`Int8EFSync` — int8 quantization with error feedback over the
  worker axis (beyond-paper, FusionLLM-style adaptive compression);
* :class:`OuterOptSync` — DiLoCo-style outer Nesterov step on the averaged
  delta (beyond-paper, see :mod:`repro.core.outer_opt`).

Policies carry their auxiliary state through the two optional
:class:`~repro.runtime.step.TrainState` slots (``ef`` for compression
residuals, ``outer`` for the outer optimizer) so checkpoints keep their
layout: :meth:`SyncPolicy.init_state` returns the ``(ef, outer)`` pair and
:meth:`SyncPolicy.apply` threads it through each sync.

The step builder (:func:`repro.runtime.step.make_train_step`) only ever
calls the policy — the old ``StepConfig.compress`` / ``StepConfig.outer``
flag branches are resolved once by :func:`resolve_policy` and stay
available for backward compatibility.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from .outer_opt import OuterConfig, OuterState, outer_init, outer_sync_units
from .partial_sync import UnitLayout, contiguous_ranges, sync_units

__all__ = ["SyncPolicy", "MeanSync", "Int8EFSync", "OuterOptSync",
           "resolve_policy", "tree_unit_map"]

PyTree = Any


def tree_unit_map(fn, trees: Sequence[PyTree], unit_ids: Sequence[int],
                  layout: UnitLayout, *, axis: int = 0) -> tuple:
    """Apply ``fn`` to each unit-group slice of N parallel param-like trees.

    ``fn(*slices)`` receives one array slice per tree and returns the
    same number of updated slices.  Plain (unstacked) groups pass whole
    leaves; layer-stacked groups pass contiguous ``[lo:hi)`` slices along
    ``axis`` (0 for unstacked trees, 1 for worker-stacked trees).  Leaves
    outside ``unit_ids`` are returned untouched.

    This is the generic form of the slicing idiom used by
    :func:`_sync_units_ef` / :func:`~repro.core.outer_opt.outer_sync_units`;
    the hierarchical server tier (:mod:`repro.hier.merge`) uses it to run
    staleness-aware merges on exactly the per-layer sync units the
    scheduler emits.
    """
    n = len(trees)
    grouped = layout.by_group(unit_ids)
    outs = [dict(t) for t in trees]
    isn = lambda t: isinstance(t, tuple) and len(t) == n

    def split(res, k):
        return jax.tree.map(lambda t: t[k], res, is_leaf=isn)

    for group in grouped:
        idxs = grouped[group]
        subs = [t[group] for t in trees]
        if idxs == [None]:
            res = jax.tree.map(lambda *xs: tuple(fn(*xs)), *subs)
        else:
            ranges = contiguous_ranges([i for i in idxs if i is not None])

            def sliced(*xs):
                xs = list(xs)
                for lo, hi in ranges:
                    sl = slice(lo, hi)
                    ix = (slice(None),) * axis + (sl,)
                    new = fn(*(x[ix] for x in xs))
                    xs = [x.at[ix].set(v) for x, v in zip(xs, new)]
                return tuple(xs)

            res = jax.tree.map(sliced, *subs)
        for k in range(n):
            outs[k][group] = split(res, k)
    return tuple(outs)


@dataclass(frozen=True)
class SyncPolicy:
    """Base policy: plain worker-mean of the scheduled units (Eq. 5)."""

    name = "mean"

    def init_state(self, params: PyTree) -> tuple[PyTree | None,
                                                  OuterState | None]:
        """Auxiliary ``(ef, outer)`` state for a worker-stacked tree."""
        return None, None

    def apply(self, params: PyTree, ef: PyTree | None,
              outer: OuterState | None, unit_ids: Sequence[int],
              layout: UnitLayout
              ) -> tuple[PyTree, PyTree | None, OuterState | None]:
        """Synchronize ``unit_ids``; returns updated (params, ef, outer)."""
        return sync_units(params, unit_ids, layout), ef, outer


@dataclass(frozen=True)
class MeanSync(SyncPolicy):
    """Alias of the base policy, for explicit registration/config."""


@dataclass(frozen=True)
class Int8EFSync(SyncPolicy):
    """int8 + error-feedback compressed partial sync (worker axis)."""

    name = "int8_ef"

    def init_state(self, params: PyTree):
        ef = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
        return ef, None

    def apply(self, params, ef, outer, unit_ids, layout):
        new_p, new_e = _sync_units_ef(params, ef, unit_ids, layout)
        return new_p, new_e, outer


@dataclass(frozen=True)
class OuterOptSync(SyncPolicy):
    """DiLoCo-style outer optimizer applied to each phase's synced units."""

    name = "outer"
    cfg: OuterConfig = field(default_factory=OuterConfig)

    def init_state(self, params: PyTree):
        return None, outer_init(params)

    def apply(self, params, ef, outer, unit_ids, layout):
        new_p, new_o = outer_sync_units(params, outer, unit_ids, layout,
                                        self.cfg)
        return new_p, ef, new_o


def resolve_policy(cfg: Any) -> SyncPolicy:
    """Resolve the policy from a :class:`~repro.runtime.step.StepConfig`.

    ``cfg.policy`` (an explicit :class:`SyncPolicy`, e.g. chosen by a
    :class:`~repro.api.SyncStrategy`) wins; otherwise the legacy
    ``compress`` / ``outer`` flags map onto the equivalent policy.
    """
    policy = getattr(cfg, "policy", None)
    if policy is not None:
        return policy
    if getattr(cfg, "outer", False):
        return OuterOptSync(cfg=getattr(cfg, "outer_cfg", OuterConfig()))
    if getattr(cfg, "compress", None) == "int8_ef":
        return Int8EFSync()
    return MeanSync()


# ---------------------------------------------------------------------------
# Compressed partial sync (int8 + EF over the worker axis)
# ---------------------------------------------------------------------------

def _sync_units_ef(params: PyTree, ef: PyTree, unit_ids, layout: UnitLayout
                   ) -> tuple[PyTree, PyTree]:
    from ..parallel.compression import compressed_worker_mean
    grouped = layout.by_group(unit_ids)
    new_p, new_e = dict(params), dict(ef)
    for group, idxs in grouped.items():
        p, e = params[group], ef[group]
        if idxs == [None]:
            pair = jax.tree.map(compressed_worker_mean, p, e)
            is2 = lambda t: isinstance(t, tuple) and len(t) == 2
            new_p[group] = jax.tree.map(lambda t: t[0], pair, is_leaf=is2)
            new_e[group] = jax.tree.map(lambda t: t[1], pair, is_leaf=is2)
            continue
        ranges = contiguous_ranges([i for i in idxs if i is not None])

        def one(p_, e_):
            for lo, hi in ranges:
                s, r = compressed_worker_mean(p_[:, lo:hi], e_[:, lo:hi])
                p_ = p_.at[:, lo:hi].set(s)
                e_ = e_.at[:, lo:hi].set(r)
            return p_, e_

        pair = jax.tree.map(one, p, e)
        is2 = lambda t: isinstance(t, tuple) and len(t) == 2
        new_p[group] = jax.tree.map(lambda t: t[0], pair, is_leaf=is2)
        new_e[group] = jax.tree.map(lambda t: t[1], pair, is_leaf=is2)
    return new_p, new_e
