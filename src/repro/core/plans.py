"""SyncPlan — the schedule artifact the runtime executes.

A :class:`SyncPlan` is pure data: for each phase ``h`` in a period of ``H``
iterations, the set of layer-unit ids (network order) whose parameters are
averaged across workers in that phase.  It is produced once by the scheduler
(:mod:`repro.core.schedule` + :mod:`repro.core.bubble_fill`) from a profile,
serialized alongside checkpoints, and re-solved whenever bandwidth or the
worker count changes (elasticity: the schedule is data, not code).

``algo`` distinguishes what is communicated:

* ``"ssgd"`` / ``"wfbp"`` / ``"ascwfbp"`` — gradients, every iteration
  (H == 1, all units in phase 0);
* ``"flsgd"`` — parameters, all units in the last phase of the period;
* ``"plsgd-enp"`` / ``"dreamddp"`` — parameters, per the partition
  (+ bubble fills for dreamddp).
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field

from .bubble_fill import FillResult, fill_bubbles
from .profiler import LayerProfile
from .schedule import (ScheduleResult, brute_force_schedule,
                       dreamddp_schedule, enp_schedule)
from .time_model import Partition

__all__ = ["SyncPlan", "build_plan", "ALGOS"]

ALGOS = ("ssgd", "wfbp", "ascwfbp", "flsgd", "plsgd-enp", "dreamddp",
         "dreamddp-bf")


@dataclass(frozen=True)
class SyncPlan:
    """Executable synchronization schedule for one period."""

    algo: str
    H: int
    n_units: int
    # per phase: sorted tuple of unit ids (network order) to synchronize
    phase_units: tuple[tuple[int, ...], ...]
    # per phase: the subset of phase_units that are §3.4 bubble fills
    fill_units: tuple[tuple[int, ...], ...] = ()
    unit_names: tuple[str, ...] = ()
    objective: float = 0.0
    meta: dict = field(default_factory=dict, compare=False, hash=False)

    def __post_init__(self):
        if len(self.phase_units) != self.H:
            raise ValueError(
                f"{len(self.phase_units)} phases for H={self.H}")
        seen: set[int] = set()
        for units in self.phase_units:
            seen.update(units)
        missing = set(range(self.n_units)) - seen
        if missing and self.algo not in ("ssgd", "wfbp", "ascwfbp"):
            raise ValueError(
                f"plan never synchronizes units {sorted(missing)}; every "
                f"layer must sync at least once per period (Lemma 4)")

    # -- queries -------------------------------------------------------------
    def units_for_phase(self, h: int) -> tuple[int, ...]:
        return self.phase_units[h % self.H]

    def phase_of_iteration(self, r: int) -> int:
        return r % self.H

    def sync_frequency(self) -> list[int]:
        """Per-unit sync count per period (>=1; >1 where fills landed)."""
        counts = [0] * self.n_units
        for units in self.phase_units:
            for u in units:
                counts[u] += 1
        return counts

    @property
    def is_parameter_sync(self) -> bool:
        return self.algo in ("flsgd", "plsgd-enp", "dreamddp", "dreamddp-bf")

    # -- (de)serialization ----------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "algo": self.algo, "H": self.H, "n_units": self.n_units,
            "phase_units": [list(u) for u in self.phase_units],
            "fill_units": [list(u) for u in self.fill_units],
            "unit_names": list(self.unit_names),
            "objective": self.objective,
            "meta": self.meta,
        }, indent=1)

    @staticmethod
    def from_json(s: str) -> "SyncPlan":
        o = json.loads(s)
        return SyncPlan(
            algo=o["algo"], H=o["H"], n_units=o["n_units"],
            phase_units=tuple(tuple(u) for u in o["phase_units"]),
            fill_units=tuple(tuple(u) for u in o.get("fill_units", [])),
            unit_names=tuple(o.get("unit_names", ())),
            objective=o.get("objective", 0.0), meta=o.get("meta", {}),
        )

    def fingerprint(self) -> str:
        return hashlib.sha256(self.to_json().encode()).hexdigest()[:16]


def _bp_positions_to_units(positions, n_units: int) -> tuple[int, ...]:
    """BP position i (0 = output-most) -> network-order unit id."""
    return tuple(sorted(n_units - 1 - p for p in positions))


def _plan_from_partition(algo: str, profile: LayerProfile, H: int,
                         result: ScheduleResult,
                         fills: FillResult | None) -> SyncPlan:
    n = len(profile)
    intervals = result.partition.bp_intervals()
    phase_units, fill_units = [], []
    for h, (s, e) in enumerate(intervals):
        base = set(range(s, e))
        extra = set(fills.fills[h]) if fills is not None else set()
        phase_units.append(_bp_positions_to_units(base | extra, n))
        fill_units.append(_bp_positions_to_units(extra - base, n))
    return SyncPlan(
        algo=algo, H=H, n_units=n,
        phase_units=tuple(phase_units), fill_units=tuple(fill_units),
        unit_names=tuple(c.name for c in profile.layers),
        objective=result.objective,
        meta={
            "partition_counts": list(result.partition.counts),
            "search_nodes": result.stats.nodes_visited,
            "search_solutions": result.stats.solutions,
            "extra_syncs": fills.extra_syncs if fills else 0,
            "bandwidth": profile.hw.bandwidth,
            "n_workers": profile.hw.n_workers,
        },
    )


def build_plan(algo: str, profile: LayerProfile, H: int, *,
               fill_mode: str = "exact") -> SyncPlan:
    """Build the SyncPlan for any supported algorithm."""
    n = len(profile)
    names = tuple(c.name for c in profile.layers)
    if algo in ("ssgd", "wfbp", "ascwfbp"):
        return SyncPlan(algo=algo, H=1, n_units=n,
                        phase_units=(tuple(range(n)),),
                        fill_units=((),), unit_names=names)
    if algo == "flsgd":
        phases = tuple(() for _ in range(H - 1)) + (tuple(range(n)),)
        return SyncPlan(algo=algo, H=H, n_units=n, phase_units=phases,
                        fill_units=tuple(() for _ in range(H)),
                        unit_names=names)
    if algo == "plsgd-enp":
        return _plan_from_partition(algo, profile, H,
                                    enp_schedule(profile, H), None)
    if algo == "dreamddp":
        res = dreamddp_schedule(profile, H)
        fills = fill_bubbles(profile, res.partition, mode=fill_mode)
        return _plan_from_partition(algo, profile, H, res, fills)
    if algo == "dreamddp-bf":   # brute-force reference (Fig. 15)
        res = brute_force_schedule(profile, H)
        fills = fill_bubbles(profile, res.partition, mode=fill_mode)
        return _plan_from_partition(algo, profile, H, res, fills)
    raise ValueError(f"unknown algo {algo!r}; choose from {ALGOS}")
