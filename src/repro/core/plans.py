"""SyncPlan — the schedule artifact the runtime executes.

A :class:`SyncPlan` is pure data: for each phase ``h`` in a period of ``H``
iterations, the set of layer-unit ids (network order) whose parameters are
averaged across workers in that phase.  It is produced once by a registered
:class:`~repro.api.SyncStrategy` (see :mod:`repro.api`) from a profile,
serialized alongside checkpoints, and re-solved whenever bandwidth or the
worker count changes (elasticity: the schedule is data, not code).

``comm`` distinguishes what is communicated — ``"gradients"`` (classic DDP:
worker-averaged gradients before the optimizer, every iteration) or
``"parameters"`` (local update first, then the phase's units are
parameter-averaged, Eq. 5).  It is set by the strategy that built the plan;
for plans deserialized from older artifacts it is derived from the legacy
algorithm name.

:func:`build_plan` remains as a thin shim over the strategy registry so
existing ``build_plan("dreamddp", ...)`` call sites keep working.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field

from .bubble_fill import FillResult
from .profiler import LayerProfile
from .schedule import ScheduleResult

__all__ = ["SyncPlan", "build_plan", "plan_from_partition", "local_plan",
           "local_period_plan", "ALGOS", "GRADIENTS", "PARAMETERS"]

#: The seed algorithm names (kept for backward compatibility; the strategy
#: registry in :mod:`repro.api` is the source of truth and hosts more).
ALGOS = ("ssgd", "wfbp", "ascwfbp", "flsgd", "plsgd-enp", "dreamddp",
         "dreamddp-bf")

GRADIENTS = "gradients"
PARAMETERS = "parameters"

# Legacy algo-name -> comm mode, used only when deserializing plans written
# before ``comm`` existed (or constructed without it).
_LEGACY_GRADIENT_ALGOS = ("ssgd", "wfbp", "ascwfbp")


@dataclass(frozen=True)
class SyncPlan:
    """Executable synchronization schedule for one period."""

    algo: str
    H: int
    n_units: int
    # per phase: sorted tuple of unit ids (network order) to synchronize
    phase_units: tuple[tuple[int, ...], ...]
    # "gradients" | "parameters"; derived from legacy algo names when empty
    comm: str = ""
    # per phase: the subset of phase_units that are §3.4 bubble fills
    fill_units: tuple[tuple[int, ...], ...] = ()
    unit_names: tuple[str, ...] = ()
    objective: float = 0.0
    meta: dict = field(default_factory=dict, compare=False, hash=False)

    def __post_init__(self):
        if not self.comm:
            object.__setattr__(
                self, "comm",
                GRADIENTS if self.algo in _LEGACY_GRADIENT_ALGOS
                else PARAMETERS)
        if self.comm not in (GRADIENTS, PARAMETERS):
            raise ValueError(f"comm must be {GRADIENTS!r} or {PARAMETERS!r},"
                             f" got {self.comm!r}")
        if len(self.phase_units) != self.H:
            raise ValueError(
                f"{len(self.phase_units)} phases for H={self.H}")
        seen: set[int] = set()
        for units in self.phase_units:
            seen.update(units)
        missing = set(range(self.n_units)) - seen
        if missing and self.comm == PARAMETERS and self.algo != "local":
            # "local" plans opt out of the in-step sync path entirely —
            # the async hierarchical runtime reconciles workers through
            # the server tier instead (repro.hier), so Lemma 4's bound
            # is enforced there (staleness clamp), not here.
            raise ValueError(
                f"plan never synchronizes units {sorted(missing)}; every "
                f"layer must sync at least once per period (Lemma 4)")

    # -- queries -------------------------------------------------------------
    def units_for_phase(self, h: int) -> tuple[int, ...]:
        return self.phase_units[h % self.H]

    def phase_of_iteration(self, r: int) -> int:
        return r % self.H

    def period_start(self, r: int) -> int:
        """First iteration of the period containing iteration ``r``."""
        return r - r % self.H

    def all_sync_units(self) -> tuple[int, ...]:
        """Every unit synchronized anywhere in the period (sorted)."""
        out: set[int] = set()
        for units in self.phase_units:
            out.update(units)
        return tuple(sorted(out))

    def phase_segments(self) -> tuple[tuple[int, int], ...]:
        """Period batch layout: maximal runs of consecutive phases whose
        unit sets are identical, as ``(start_phase, length)`` pairs.

        Phases in one segment compile to the *same* step body (the body
        depends only on the phase's static unit set), so a period-fused
        executable rolls each segment into one ``lax.scan`` over the
        pre-batched ``[H, ...]`` data instead of unrolling H copies —
        e.g. FLSGD's ``H-1`` local phases + 1 full sync become two
        segments regardless of H.  The phase index stays static per
        segment, so every phase keeps its exact scheduled collective
        bytes and ``segment_cuts`` overlap windows.
        """
        segs: list[tuple[int, int]] = []
        for h in range(self.H):
            if segs and self.phase_units[h] == \
                    self.phase_units[segs[-1][0]]:
                segs[-1] = (segs[-1][0], segs[-1][1] + 1)
            else:
                segs.append((h, 1))
        return tuple(segs)

    def sync_frequency(self) -> list[int]:
        """Per-unit sync count per period (>=1; >1 where fills landed)."""
        counts = [0] * self.n_units
        for units in self.phase_units:
            for u in units:
                counts[u] += 1
        return counts

    @property
    def is_parameter_sync(self) -> bool:
        return self.comm == PARAMETERS

    # -- (de)serialization ----------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "algo": self.algo, "comm": self.comm, "H": self.H,
            "n_units": self.n_units,
            "phase_units": [list(u) for u in self.phase_units],
            "fill_units": [list(u) for u in self.fill_units],
            "unit_names": list(self.unit_names),
            "objective": self.objective,
            "meta": self.meta,
        }, indent=1)

    @staticmethod
    def from_json(s: str) -> "SyncPlan":
        o = json.loads(s)
        return SyncPlan(
            algo=o["algo"], comm=o.get("comm", ""), H=o["H"],
            n_units=o["n_units"],
            phase_units=tuple(tuple(u) for u in o["phase_units"]),
            fill_units=tuple(tuple(u) for u in o.get("fill_units", [])),
            unit_names=tuple(o.get("unit_names", ())),
            objective=o.get("objective", 0.0), meta=o.get("meta", {}),
        )

    def fingerprint(self) -> str:
        return hashlib.sha256(self.to_json().encode()).hexdigest()[:16]


def _bp_positions_to_units(positions, n_units: int) -> tuple[int, ...]:
    """BP position i (0 = output-most) -> network-order unit id."""
    return tuple(sorted(n_units - 1 - p for p in positions))


def plan_from_partition(algo: str, profile: LayerProfile, H: int,
                        result: ScheduleResult,
                        fills: FillResult | None, *,
                        comm: str = PARAMETERS) -> SyncPlan:
    """Materialize a :class:`SyncPlan` from an Algorithm-2 search result.

    Shared by every partition-based strategy (plsgd-enp, dreamddp and its
    registry-provided derivatives).
    """
    n = len(profile)
    intervals = result.partition.bp_intervals()
    phase_units, fill_units = [], []
    for h, (s, e) in enumerate(intervals):
        base = set(range(s, e))
        extra = set(fills.fills[h]) if fills is not None else set()
        phase_units.append(_bp_positions_to_units(base | extra, n))
        fill_units.append(_bp_positions_to_units(extra - base, n))
    return SyncPlan(
        algo=algo, comm=comm, H=H, n_units=n,
        phase_units=tuple(phase_units), fill_units=tuple(fill_units),
        unit_names=tuple(c.name for c in profile.layers),
        objective=result.objective,
        meta={
            "partition_counts": list(result.partition.counts),
            "search_nodes": result.stats.nodes_visited,
            "search_solutions": result.stats.solutions,
            "extra_syncs": fills.extra_syncs if fills else 0,
            "bandwidth": profile.hw.bandwidth,
            "n_workers": profile.hw.n_workers,
        },
    )


def local_plan(n_units: int) -> SyncPlan:
    """A plan whose phase 0 performs **no** synchronization at all.

    Used by the runner for straggler-skipped phases (a pure local step) —
    phase 1 nominally syncs everything so the every-unit-per-period
    invariant holds, but only phase 0 is ever executed.
    """
    return SyncPlan(algo="local", comm=PARAMETERS, H=2, n_units=n_units,
                    phase_units=((), tuple(range(n_units))),
                    fill_units=((), ()))


def local_period_plan(n_units: int, H: int) -> SyncPlan:
    """An H-phase plan that performs no in-step synchronization at all.

    The async hierarchical runtime (:mod:`repro.hier`) executes whole
    periods of pure local steps per worker — reconciliation happens
    through the local/global server tier between periods, not inside the
    step — so every phase's unit set is empty.  ``phase_segments()``
    collapses the H identical phases into one segment, so
    :func:`~repro.runtime.step.make_period_step` compiles this to a
    single ``lax.scan`` over the period batch.
    """
    return SyncPlan(algo="local", comm=PARAMETERS, H=H, n_units=n_units,
                    phase_units=tuple(() for _ in range(H)),
                    fill_units=tuple(() for _ in range(H)))


def build_plan(algo: str, profile: LayerProfile, H: int, *,
               fill_mode: str = "exact") -> SyncPlan:
    """Build the SyncPlan for any registered strategy (registry shim).

    The algorithm dispatch lives in the :mod:`repro.api` strategy registry;
    this function only keeps the historical entry point alive.
    """
    from ..api.registry import available_strategies, get_strategy
    try:
        strategy = get_strategy(algo)
    except KeyError:
        raise ValueError(f"unknown algo {algo!r}; choose from "
                         f"{available_strategies()}") from None
    return strategy.build_plan(profile, H, fill_mode=fill_mode)
