"""Layer-wise communication/computation profiler (paper §3, Fig. 4 "Profiler").

DreamDDP's scheduler consumes per-layer backward times ``t_BP^l`` and
parameter-synchronization times ``t_COMM^l``.  Two sources are provided:

* :func:`analytic_profile` — derives times from per-layer FLOP/byte counts and
  a :class:`HardwareSpec` roofline (used on this CPU-only container, where the
  TPU is the *target*, and for the paper's bandwidth-sweep experiments).
* :func:`measured_profile` — times real per-layer forward/backward on the
  attached backend (used on hardware; also exercised in tests on CPU).

Both produce a :class:`LayerProfile`, the scheduler's only input — so the
schedule is *data*, recomputable when bandwidth changes (paper §6 limitation:
we expose :meth:`LayerProfile.with_bandwidth` for cheap re-profiling).
"""

from __future__ import annotations

import dataclasses
import json
import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

__all__ = [
    "HardwareSpec",
    "LayerCost",
    "LayerProfile",
    "analytic_profile",
    "measured_profile",
    "ring_allreduce_time",
    "V5E",
    "A6000_CLUSTER",
    "GEO_WAN",
]


@dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants for one worker + the inter-worker link.

    ``bandwidth`` is the *per-link* bandwidth of the synchronization axis
    (bytes/s).  For geo-distributed pods this is the WAN link; for the paper's
    clusters it is 1 GB/s / 20 GB/s Ethernet.
    """

    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bandwidth: float = 819e9        # bytes/s per chip
    ici_bandwidth: float = 5e10         # bytes/s per ICI link (intra-pod)
    bandwidth: float = 1e9              # bytes/s on the sync (slow/geo) axis
    latency: float = 5e-4               # per-collective latency on sync axis (s)
    n_workers: int = 32                 # workers on the sync axis
    chips_per_worker: int = 1           # 1 GPU (paper) or a whole pod (geo)
    mfu: float = 0.45                   # achievable fraction of peak for BP/FP
    bwd_fwd_ratio: float = 2.0          # t_BP ~= 2 x t_FP for matmul layers

    def replace(self, **kw) -> "HardwareSpec":
        return dataclasses.replace(self, **kw)


# Presets: the assigned TPU target, the paper's two clusters, and a geo WAN.
V5E = HardwareSpec()
A6000_CLUSTER = HardwareSpec(
    name="a6000x32", peak_flops=155e12, hbm_bandwidth=768e9,
    bandwidth=20e9, latency=3e-5, n_workers=32, mfu=0.40,
)
GEO_WAN = HardwareSpec(
    name="geo-wan", bandwidth=125e6, latency=5e-2, n_workers=4,
)


def ring_allreduce_time(nbytes: float, hw: HardwareSpec) -> float:
    """Ring all-reduce cost model: ``2 (K-1)/K * nbytes / bw + latency``.

    This is the standard bandwidth-optimal ring bound used throughout the
    paper's cost analysis (parameter averaging = all-reduce of params).
    """
    k = max(hw.n_workers, 2)
    return 2.0 * (k - 1) / k * nbytes / hw.bandwidth + hw.latency


@dataclass(frozen=True)
class LayerCost:
    """Profiled costs of one schedulable layer unit (network order)."""

    name: str
    flops_fwd: float = 0.0
    flops_bwd: float = 0.0
    param_bytes: float = 0.0
    t_fp: float = 0.0
    t_bp: float = 0.0
    t_comm: float = 0.0

    def scaled_comm(self, factor: float) -> "LayerCost":
        return dataclasses.replace(self, t_comm=self.t_comm * factor)


@dataclass
class LayerProfile:
    """Ordered per-layer costs, index 0 = input-most layer (network order).

    The scheduler reasons in *backward* order (output-most first); helpers
    here expose both views so callers never hand-flip indices.
    """

    layers: list[LayerCost]
    hw: HardwareSpec = field(default_factory=HardwareSpec)

    # ---- basic views -----------------------------------------------------
    def __len__(self) -> int:
        return len(self.layers)

    @property
    def t_fp_total(self) -> float:
        return sum(c.t_fp for c in self.layers)

    @property
    def t_bp_total(self) -> float:
        return sum(c.t_bp for c in self.layers)

    @property
    def t_comm_total(self) -> float:
        return sum(c.t_comm for c in self.layers)

    @property
    def total_param_bytes(self) -> float:
        return sum(c.param_bytes for c in self.layers)

    def bp_order(self) -> list[LayerCost]:
        """Layers in backward-pass order (output-most first)."""
        return list(reversed(self.layers))

    # ---- derived profiles ------------------------------------------------
    def with_bandwidth(self, bandwidth: float, latency: float | None = None,
                       n_workers: int | None = None) -> "LayerProfile":
        """Re-derive comm times for a new link (cheap re-profile, paper §6)."""
        hw = self.hw.replace(
            bandwidth=bandwidth,
            latency=self.hw.latency if latency is None else latency,
            n_workers=self.hw.n_workers if n_workers is None else n_workers,
        )
        layers = [
            dataclasses.replace(c, t_comm=ring_allreduce_time(c.param_bytes, hw))
            for c in self.layers
        ]
        return LayerProfile(layers, hw)

    def comm_compute_ratio(self) -> float:
        denom = self.t_fp_total + self.t_bp_total
        return self.t_comm_total / denom if denom else float("inf")

    # ---- (de)serialization -------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "hw": dataclasses.asdict(self.hw),
            "layers": [dataclasses.asdict(c) for c in self.layers],
        }, indent=1)

    @staticmethod
    def from_json(s: str) -> "LayerProfile":
        obj = json.loads(s)
        return LayerProfile(
            [LayerCost(**c) for c in obj["layers"]],
            HardwareSpec(**obj["hw"]),
        )


def analytic_profile(
    layer_params: Sequence[tuple[str, float, float]],
    hw: HardwareSpec,
    *,
    param_dtype_bytes: int = 2,
) -> LayerProfile:
    """Build a profile from ``(name, n_params, flops_fwd_per_step)`` triples.

    ``flops_fwd_per_step`` is the forward FLOPs of the layer for the *global*
    per-worker batch; backward is ``bwd_fwd_ratio`` x forward.  Communication
    is a ring all-reduce of the layer's parameter bytes over the sync axis.
    """
    layers = []
    for name, n_params, flops_fwd in layer_params:
        pbytes = n_params * param_dtype_bytes
        t_fp = flops_fwd / (hw.peak_flops * hw.mfu * hw.chips_per_worker)
        t_bp = t_fp * hw.bwd_fwd_ratio
        layers.append(LayerCost(
            name=name, flops_fwd=flops_fwd,
            flops_bwd=flops_fwd * hw.bwd_fwd_ratio,
            param_bytes=pbytes, t_fp=t_fp, t_bp=t_bp,
            t_comm=ring_allreduce_time(pbytes, hw),
        ))
    return LayerProfile(layers, hw)


def measured_profile(
    layer_fns: Sequence[tuple[str, Callable[[], object], float]],
    hw: HardwareSpec,
    *,
    warmup: int = 2,
    iters: int = 5,
) -> LayerProfile:
    """Time per-layer fwd+bwd thunks on the attached backend.

    ``layer_fns`` is ``(name, thunk, param_bytes)``; each thunk runs one
    fwd+bwd of that layer and blocks until ready.  We split the measured
    wall time into t_fp/t_bp with the spec's ``bwd_fwd_ratio``; t_comm is
    still model-derived (measuring a WAN link is deployment-specific).
    """
    layers = []
    for name, thunk, param_bytes in layer_fns:
        for _ in range(warmup):
            thunk()
        t0 = time.perf_counter()
        for _ in range(iters):
            thunk()
        dt = (time.perf_counter() - t0) / iters
        r = hw.bwd_fwd_ratio
        t_fp = dt / (1.0 + r)
        layers.append(LayerCost(
            name=name, param_bytes=param_bytes, t_fp=t_fp, t_bp=t_fp * r,
            t_comm=ring_allreduce_time(param_bytes, hw),
        ))
    return LayerProfile(layers, hw)
