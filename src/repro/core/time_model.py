"""Wall-clock time model of PLSGD (paper Eq. 7/8) + exact event timeline.

Two evaluators are provided for a candidate layer partition:

* :func:`objective` — the paper's Eq. 8 closed form, where a phase's comm time
  is the simple sum of its layers' ``t_comm`` (this is what Algorithm 2's
  pruning properties are stated against);
* :func:`simulate_period` — an exact event-driven timeline honouring the
  per-layer dependency "comm of layer *l* starts only after *l*'s BP completes
  and after the previous comm on the link finishes" (the tau-recursion under
  Eq. 7).  Used to pick among DFS solutions and to build Table 1/Table 2
  style benchmarks, including the S-SGD / WFBP / ASC-WFBP baselines.

Conventions
-----------
Layers are indexed in **network order** 0..L-1 (0 touches the input).  The
backward pass visits them in reverse.  A partition is a tuple of ``H`` counts
``(n_1..n_H)`` summing to L: phase ``h`` synchronizes the ``n_h`` next layers
in *backward* order, so phase 0 always holds the output-most layers — exactly
the interval structure the paper optimizes over (§3.3).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

from .profiler import LayerProfile

__all__ = [
    "Partition",
    "PhaseTimeline",
    "objective",
    "phase_objective",
    "simulate_phase",
    "simulate_period",
    "ssgd_iteration_time",
    "wfbp_iteration_time",
    "ascwfbp_iteration_time",
    "flsgd_period_time",
]


@dataclass(frozen=True)
class Partition:
    """Contiguous-interval partition of L layers into H phases (BP order)."""

    counts: tuple[int, ...]

    @staticmethod
    def equal_number(n_layers: int, n_phases: int) -> "Partition":
        """The paper's Equal-Number Partition baseline (Example 1)."""
        base, rem = divmod(n_layers, n_phases)
        return Partition(tuple(base + (1 if h < rem else 0)
                               for h in range(n_phases)))

    @property
    def n_phases(self) -> int:
        return len(self.counts)

    @property
    def n_layers(self) -> int:
        return sum(self.counts)

    def bp_intervals(self) -> list[tuple[int, int]]:
        """Per-phase ``[start, end)`` in backward-order positions."""
        out, s = [], 0
        for c in self.counts:
            out.append((s, s + c))
            s += c
        return out

    def layer_ids(self, n_layers: int | None = None) -> list[list[int]]:
        """Per-phase layer ids in *network* order (for SyncPlan building)."""
        n = self.n_layers if n_layers is None else n_layers
        out = []
        for s, e in self.bp_intervals():
            # bp position i corresponds to network layer n-1-i
            out.append(sorted(n - 1 - i for i in range(s, e)))
        return out

    def validate(self) -> None:
        if any(c < 0 for c in self.counts):
            raise ValueError(f"negative phase count in {self.counts}")


# ---------------------------------------------------------------------------
# Paper Eq. 8 (simplified sum-comm objective)
# ---------------------------------------------------------------------------

def _bp_prefix(profile: LayerProfile) -> list[float]:
    """Prefix sums of t_bp in BP order; _bp_prefix[i] = time BP of the first
    i backward layers takes."""
    acc, out = 0.0, [0.0]
    for c in profile.bp_order():
        acc += c.t_bp
        out.append(acc)
    return out


def phase_objective(profile: LayerProfile, partition: Partition,
                    h: int) -> float:
    """Eq. 8 inner term for phase ``h`` (BP part + max(BP-remainder, comm))."""
    bp = profile.bp_order()
    pre = _bp_prefix(profile)
    (s, e) = partition.bp_intervals()[h]
    if s == e:  # empty phase: plain local step
        return pre[-1]
    t_bp_before = pre[s]                    # t_BP^{L_{1:h-1}}
    t_h0 = bp[s].t_bp                       # t_BP^{h0}
    t_bp_rest = pre[-1] - pre[s] - t_h0     # t_BP^{L_{h:H}} - t_BP^{h0}
    t_comm = sum(bp[i].t_comm for i in range(s, e))
    return t_bp_before + t_h0 + max(t_bp_rest, t_comm)


def objective(profile: LayerProfile, partition: Partition,
              include_fp: bool = False) -> float:
    """Paper Eq. 8: one full synchronization period's BP+comm time.

    With ``include_fp`` the H forward passes are added (Eq. 7's ``R x t_FP``
    term per period) — useful for end-to-end iteration-time tables.
    """
    total = sum(phase_objective(profile, partition, h)
                for h in range(partition.n_phases))
    if include_fp:
        total += partition.n_phases * profile.t_fp_total
    return total


# ---------------------------------------------------------------------------
# Exact event-driven timeline (tau-recursion under Eq. 7)
# ---------------------------------------------------------------------------

@dataclass
class PhaseTimeline:
    """One phase's simulated schedule (all times relative to FP end)."""

    bp_done: list[float] = field(default_factory=list)      # per bp position
    comm_start: dict[int, float] = field(default_factory=dict)
    comm_done: dict[int, float] = field(default_factory=dict)
    t_fp: float = 0.0

    @property
    def bp_end(self) -> float:
        return self.bp_done[-1] if self.bp_done else 0.0

    @property
    def comm_end(self) -> float:
        return max(self.comm_done.values(), default=0.0)

    @property
    def iteration_time(self) -> float:
        return self.t_fp + max(self.bp_end, self.comm_end)

    @property
    def exposed_comm(self) -> float:
        """Communication time not hidden by backward compute."""
        return max(0.0, self.comm_end - self.bp_end)

    @property
    def link_idle_before_bp_end(self) -> float:
        """Idle link time inside the BP window (the §3.4 'bubble')."""
        busy = sum(min(self.comm_done[i], self.bp_end)
                   - min(self.comm_start[i], self.bp_end)
                   for i in self.comm_start)
        return max(0.0, self.bp_end - busy)


def simulate_phase(profile: LayerProfile, sync_bp_positions: Sequence[int],
                   *, n_channels: int = 1) -> PhaseTimeline:
    """Simulate one iteration that synchronizes the given BP positions.

    Comm of a layer may start once its BP is done *and* a link channel is
    free; channels model ASC-WFBP-style simultaneous communications
    (``n_channels > 1``).  Layers are communicated in BP-completion order.
    """
    bp = profile.bp_order()
    tl = PhaseTimeline(t_fp=profile.t_fp_total)
    acc = 0.0
    for c in bp:
        acc += c.t_bp
        tl.bp_done.append(acc)
    free_at = [0.0] * max(1, n_channels)
    for i in sorted(sync_bp_positions):
        ch = min(range(len(free_at)), key=free_at.__getitem__)
        start = max(tl.bp_done[i], free_at[ch])
        done = start + bp[i].t_comm
        free_at[ch] = done
        tl.comm_start[i] = start
        tl.comm_done[i] = done
    return tl


def simulate_period(profile: LayerProfile, partition: Partition,
                    fills: Sequence[Sequence[int]] | None = None,
                    *, n_channels: int = 1) -> list[PhaseTimeline]:
    """Simulate all H iterations of one period.

    ``fills[h]`` optionally adds extra BP positions synchronized in phase
    ``h`` (the §3.4 bubble-filling supplement).
    """
    out = []
    for h, (s, e) in enumerate(partition.bp_intervals()):
        positions = set(range(s, e))
        if fills is not None and h < len(fills):
            positions |= set(fills[h])
        out.append(simulate_phase(profile, sorted(positions),
                                  n_channels=n_channels))
    return out


# ---------------------------------------------------------------------------
# Baseline algorithm time models (Table 1 comparisons)
# ---------------------------------------------------------------------------

def ssgd_iteration_time(profile: LayerProfile) -> float:
    """S-SGD, no overlap: FP + BP + full-gradient all-reduce (Eq. 3)."""
    return profile.t_fp_total + profile.t_bp_total + profile.t_comm_total


def wfbp_iteration_time(profile: LayerProfile, *, n_channels: int = 1) -> float:
    """WFBP: per-layer gradient comm launched right after that layer's BP,
    overlapped with remaining BP.  ``n_channels > 1`` models genuinely
    independent links (each at full bandwidth) — use
    :func:`ascwfbp_iteration_time` for the shared-link multi-stream
    baseline."""
    tl = simulate_phase(profile, range(len(profile)), n_channels=n_channels)
    return tl.iteration_time


def ascwfbp_iteration_time(profile: LayerProfile, *, boost: float = 1.25,
                           n_streams: int = 4) -> float:
    """ASC-WFBP [Shi et al. 2021]: simultaneous communications on a SHARED
    link.  Aggregate bandwidth cannot exceed the link; the measured benefit
    (~1.2-1.4x over WFBP) comes from multi-stream utilization and latency
    amortization — modelled as a bounded bandwidth boost + latency / n."""
    hw = profile.hw
    boosted = profile.with_bandwidth(hw.bandwidth * boost,
                                     latency=hw.latency / n_streams)
    return wfbp_iteration_time(boosted)


def flsgd_period_time(profile: LayerProfile, H: int) -> float:
    """Local SGD with full synchronization: H local iters + one full
    non-overlapped model all-reduce (Eq. 4 per period)."""
    return H * (profile.t_fp_total + profile.t_bp_total) + profile.t_comm_total
