"""DreamDDP core: the paper's contribution.

Pipeline: profile layers (:mod:`profiler`) -> model the period time
(:mod:`time_model`, Eq. 7/8) -> search the partition (:mod:`schedule`,
Algorithm 2) -> fill bubbles (:mod:`bubble_fill`, §3.4) -> emit a
:class:`~repro.core.plans.SyncPlan` -> execute partial syncs on worker-
stacked pytrees (:mod:`partial_sync`), optionally with an outer optimizer
(:mod:`outer_opt`, beyond-paper).
"""

from .bubble_fill import FillResult, fill_bubbles
from .outer_opt import OuterConfig, OuterState, outer_init, outer_sync_units
from .partial_sync import (UnitEntry, UnitLayout, contiguous_ranges,
                           divergence, sync_units, tree_worker_mean,
                           unit_divergence, worker_stack, worker_unstack)
from .plans import (ALGOS, SyncPlan, build_plan, local_plan,
                    plan_from_partition)
from .sync_policies import (Int8EFSync, MeanSync, OuterOptSync, SyncPolicy,
                            resolve_policy)
from .profiler import (A6000_CLUSTER, GEO_WAN, V5E, HardwareSpec, LayerCost,
                       LayerProfile, analytic_profile, measured_profile,
                       ring_allreduce_time)
from .schedule import (ScheduleResult, SearchStats, brute_force_count,
                       brute_force_schedule, dreamddp_schedule, enp_schedule)
from .time_model import (Partition, PhaseTimeline, ascwfbp_iteration_time,
                         flsgd_period_time,
                         objective, phase_objective, simulate_period,
                         simulate_phase, ssgd_iteration_time,
                         wfbp_iteration_time)

__all__ = [k for k in dir() if not k.startswith("_")]
