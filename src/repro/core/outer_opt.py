"""Beyond-paper: DiLoCo-style outer optimization on partial syncs.

The paper averages parameters at each sync (``w <- mean_k w_k``).  DiLoCo
[Douillard et al., 2024] instead treats the averaged *delta* since the last
sync as a pseudo-gradient and applies an outer Nesterov-momentum step — known
to improve local-SGD convergence at the same communication cost.  DreamDDP's
layer-wise decoupling composes naturally: we keep per-unit outer state and
apply the outer update only to the units synchronized in the current phase.

Communication cost is identical to plain averaging (the all-reduce of the
unit's parameters); the outer params/momentum live *sharded the same way as
the params*, adding 2x the synced units' bytes in HBM — amortized over the
stack this is 2x params, so we default it OFF and enable via config
(``outer_opt=True``).  Recorded separately in EXPERIMENTS.md as beyond-paper.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .partial_sync import UnitLayout, contiguous_ranges

__all__ = ["OuterState", "outer_init", "outer_sync_units"]

PyTree = Any


class OuterState(NamedTuple):
    """Per-parameter outer-optimizer state (worker-stacked like params,
    but numerically identical across the worker axis)."""

    outer_params: PyTree   # the slow/global weights
    momentum: PyTree       # Nesterov momentum on pseudo-gradients


@dataclass(frozen=True)
class OuterConfig:
    lr: float = 0.7
    beta: float = 0.9
    nesterov: bool = True


def outer_init(worker_params: PyTree) -> OuterState:
    """Outer weights start at the (identical) initial replicas."""
    return OuterState(
        outer_params=jax.tree.map(lambda x: x.astype(jnp.float32),
                                  worker_params),
        momentum=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32),
                              worker_params),
    )


def _outer_step(outer: jax.Array, mom: jax.Array, avg: jax.Array,
                cfg: OuterConfig) -> tuple[jax.Array, jax.Array]:
    """One Nesterov step on the pseudo-gradient ``outer - avg``."""
    pseudo_grad = outer - avg.astype(jnp.float32)
    mom_new = cfg.beta * mom + pseudo_grad
    direction = pseudo_grad + cfg.beta * mom_new if cfg.nesterov else mom_new
    return outer - cfg.lr * direction, mom_new


def outer_sync_units(params: PyTree, state: OuterState,
                     unit_ids: Sequence[int], layout: UnitLayout,
                     cfg: OuterConfig = OuterConfig(),
                     ) -> tuple[PyTree, OuterState]:
    """Partial sync with outer optimization.

    For each synced unit: workers all-reduce (mean) their parameters, the
    outer optimizer consumes the mean as a pseudo-gradient, and every worker
    resets that unit to the new outer weights (a synchronization point, as in
    plain averaging — so Lemma 4's bounded-staleness argument still applies).
    """
    if not unit_ids:
        return params, state
    grouped = layout.by_group(unit_ids)
    new_params = dict(params)
    new_outer = dict(state.outer_params)
    new_mom = dict(state.momentum)

    for group, idxs in grouped.items():
        p, o, m = params[group], state.outer_params[group], state.momentum[group]
        if idxs == [None]:
            def full(p_, o_, m_):
                avg = jnp.mean(p_.astype(jnp.float32), axis=0, keepdims=True)
                o2, m2 = _outer_step(o_, m_, avg, cfg)
                return jnp.broadcast_to(o2.astype(p_.dtype), p_.shape), o2, m2
            trip = jax.tree.map(full, p, o, m)
            new_params[group] = jax.tree.map(lambda t: t[0], trip,
                                             is_leaf=lambda t: isinstance(t, tuple))
            new_outer[group] = jax.tree.map(lambda t: t[1], trip,
                                            is_leaf=lambda t: isinstance(t, tuple))
            new_mom[group] = jax.tree.map(lambda t: t[2], trip,
                                          is_leaf=lambda t: isinstance(t, tuple))
            continue
        ranges = contiguous_ranges([i for i in idxs if i is not None])

        def sliced(p_, o_, m_):
            for lo, hi in ranges:
                avg = jnp.mean(p_[:, lo:hi].astype(jnp.float32), axis=0,
                               keepdims=True)
                o2, m2 = _outer_step(o_[:, lo:hi], m_[:, lo:hi], avg, cfg)
                p_ = p_.at[:, lo:hi].set(
                    jnp.broadcast_to(o2.astype(p_.dtype), p_[:, lo:hi].shape))
                o_ = o_.at[:, lo:hi].set(o2)
                m_ = m_.at[:, lo:hi].set(m2)
            return p_, o_, m_

        trip = jax.tree.map(sliced, p, o, m)
        is_trip = lambda t: isinstance(t, tuple) and len(t) == 3 and all(
            isinstance(x, jax.Array) for x in t)
        new_params[group] = jax.tree.map(lambda t: t[0], trip, is_leaf=is_trip)
        new_outer[group] = jax.tree.map(lambda t: t[1], trip, is_leaf=is_trip)
        new_mom[group] = jax.tree.map(lambda t: t[2], trip, is_leaf=is_trip)

    return new_params, OuterState(new_outer, new_mom)
