"""Layer-unit indexing over parameter pytrees + partial synchronization ops.

The runtime stores parameters with a **leading worker axis**: every leaf of
the model pytree is stacked to ``[W, ...]`` where ``W`` is the number of
local-SGD workers, and that axis is sharded over the mesh's worker axes
(``('pod',)`` or ``('pod','data')`` / ``('data',)``).  Under GSPMD each
device holds only its own worker's shard, so divergent replicas cost no
extra memory versus plain replication (DESIGN.md §2).

Model parameter trees are organised into named **groups**:

* plain groups (``embed``, ``final_norm``, ``lm_head``, ...) — synchronized
  as one unit;
* stacked groups (``blocks``, ``enc_blocks``, ...) — leaves carry a layer
  axis at position 1 (``[W, n_layers, ...]``, scan-over-layers layout); each
  layer index is its own schedulable unit, and a phase's contiguous layer
  interval lowers to one static slice -> one fused all-reduce of exactly the
  scheduled bytes.

A :class:`UnitLayout` lists the units in **network order** — the same order
the profiler and scheduler use — and maps every unit to (group, index).

All sync ops are pure functions of worker-stacked trees; the mean is taken
in ``float32`` and cast back (bf16 parameter averaging loses ~3 bits
otherwise).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "UnitEntry",
    "UnitLayout",
    "contiguous_ranges",
    "sync_units",
    "tree_worker_mean",
    "worker_stack",
    "worker_unstack",
    "divergence",
    "unit_divergence",
]

PyTree = Any


@dataclass(frozen=True)
class UnitEntry:
    """One schedulable layer unit."""

    name: str
    group: str
    index: int | None = None        # None => whole (plain) group

    @property
    def is_stacked(self) -> bool:
        return self.index is not None


@dataclass(frozen=True)
class UnitLayout:
    """Ordered layer units (network order: unit 0 touches the input)."""

    entries: tuple[UnitEntry, ...]

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(e.name for e in self.entries)

    def by_group(self, unit_ids: Sequence[int]) -> dict[str, list[int | None]]:
        """Group the given unit ids: group -> stacked indices (or [None])."""
        out: dict[str, list[int | None]] = {}
        for u in unit_ids:
            e = self.entries[u]
            out.setdefault(e.group, []).append(e.index)
        return out

    def validate_against(self, params: PyTree, *,
                         worker_stacked: bool = True) -> None:
        """Check every referenced group exists and stack sizes match.

        ``worker_stacked=False`` for raw model trees (stack axis 0 instead
        of 1)."""
        axis = 1 if worker_stacked else 0
        for e in self.entries:
            if e.group not in params:
                raise KeyError(f"unit {e.name}: group {e.group!r} missing "
                               f"from params (has {list(params)})")
        # stacked groups: the layer axis must cover the max index
        for group, idxs in self.by_group(range(len(self))).items():
            real = [i for i in idxs if i is not None]
            if not real:
                continue
            leaves = jax.tree_util.tree_leaves(params[group])
            if not leaves:
                raise ValueError(f"group {group!r} has no leaves")
            n = leaves[0].shape[axis]
            if max(real) >= n:
                raise ValueError(
                    f"group {group!r}: layout references layer {max(real)} "
                    f"but stack has {n}")


def contiguous_ranges(indices: Sequence[int]) -> list[tuple[int, int]]:
    """Sorted ``[lo, hi)`` runs covering ``indices`` (static-slice friendly)."""
    if not indices:
        return []
    xs = sorted(set(indices))
    out, lo, prev = [], xs[0], xs[0]
    for x in xs[1:]:
        if x == prev + 1:
            prev = x
            continue
        out.append((lo, prev + 1))
        lo = prev = x
    out.append((lo, prev + 1))
    return out


# ---------------------------------------------------------------------------
# Worker-axis helpers
# ---------------------------------------------------------------------------

def worker_stack(params: PyTree, n_workers: int) -> PyTree:
    """Tile a plain param tree to ``[W, ...]`` (identical initial replicas —
    the paper's requirement that workers start from a synchronization
    point)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_workers,) + x.shape), params)


def worker_unstack(params: PyTree, worker: int = 0) -> PyTree:
    """Extract one worker's replica (e.g. for evaluation/serving)."""
    return jax.tree.map(lambda x: x[worker], params)


def _mean_bcast(x: jax.Array, *, mean_dtype=jnp.float32) -> jax.Array:
    """Average over the worker axis and broadcast back — the parameter
    all-reduce.  Mean in float32, cast back to the storage dtype."""
    m = jnp.mean(x.astype(mean_dtype), axis=0, keepdims=True).astype(x.dtype)
    return jnp.broadcast_to(m, x.shape)


def tree_worker_mean(tree: PyTree, *, mean_dtype=jnp.float32) -> PyTree:
    """Full synchronization: average every leaf over the worker axis."""
    return jax.tree.map(lambda x: _mean_bcast(x, mean_dtype=mean_dtype), tree)


# ---------------------------------------------------------------------------
# Partial synchronization (the paper's core op)
# ---------------------------------------------------------------------------

def sync_units(params: PyTree, unit_ids: Sequence[int], layout: UnitLayout,
               *, mean_dtype=jnp.float32) -> PyTree:
    """Average the given layer units across workers; others untouched.

    ``params`` is a dict of groups; every leaf is worker-stacked ``[W, ...]``
    (stacked groups ``[W, n_layers, ...]``).  Unit ids are **static** — each
    schedule phase compiles to its own executable, so the slices below are
    constant-folded and the emitted collective moves exactly the scheduled
    bytes.
    """
    if not unit_ids:
        return params
    grouped = layout.by_group(unit_ids)
    out = dict(params)
    for group, idxs in grouped.items():
        sub = params[group]
        if idxs == [None]:
            out[group] = tree_worker_mean(sub, mean_dtype=mean_dtype)
            continue
        if None in idxs:
            raise ValueError(f"group {group!r} mixes plain and stacked units")
        ranges = contiguous_ranges([i for i in idxs if i is not None])

        def sync_leaf(x: jax.Array) -> jax.Array:
            for lo, hi in ranges:
                sl = x[:, lo:hi]
                x = x.at[:, lo:hi].set(_mean_bcast(sl, mean_dtype=mean_dtype))
            return x

        out[group] = jax.tree.map(sync_leaf, sub)
    return out


# ---------------------------------------------------------------------------
# Model divergence Gamma_r (paper Fig. 5 / Lemma 4)
# ---------------------------------------------------------------------------

def divergence(params: PyTree) -> jax.Array:
    """``Gamma_r = (1/K) sum_k ||w_k - w_bar||^2`` over the worker axis."""
    def leaf_div(x: jax.Array) -> jax.Array:
        xf = x.astype(jnp.float32)
        d = xf - jnp.mean(xf, axis=0, keepdims=True)
        return jnp.sum(d * d) / x.shape[0]
    return sum(jax.tree_util.tree_leaves(jax.tree.map(leaf_div, params)))


def unit_divergence(params: PyTree, layout: UnitLayout) -> jax.Array:
    """Per-unit divergence vector (network order), for Fig. 5-style plots."""
    vals = []
    for e in layout.entries:
        sub = params[e.group]
        if e.index is not None:
            sub = jax.tree.map(lambda x, i=e.index: x[:, i], sub)
        vals.append(divergence(sub))
    return jnp.stack(vals)
