from .optimizers import OptConfig, Optimizer, lr_schedule, make_optimizer

__all__ = ["OptConfig", "Optimizer", "lr_schedule", "make_optimizer"]
