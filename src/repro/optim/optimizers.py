"""Optimizers (pure functional, worker-stacked-tree friendly).

All updates are elementwise over leaves, so the same code serves plain and
worker-stacked parameter trees (the local step of LSGD runs per worker with
no cross-worker reduction — that is the point of the paper).

Adafactor factors the second moment over the last two axes — with stacked
block leaves ``[W, n_layers, a, b]`` that is exactly the weight matrix, so
optimizer state is ~``(a+b)/(a*b)`` of Adam's.  It is the default for the
``large`` archs (DESIGN.md §7 memory plan).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "Optimizer", "make_optimizer", "lr_schedule"]

PyTree = Any


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"                 # sgd | momentum | adam | adamw | adafactor
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.9
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # adafactor
    factored_min_dim: int = 8
    decay_rate: float = 0.8


def lr_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to ``min_lr_ratio * lr``."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def _global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def _clip(grads: PyTree, max_norm: float) -> PyTree:
    g = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), grads)


class Optimizer(NamedTuple):
    cfg: OptConfig
    init: Any                  # params -> state
    update: Any                # (grads, state, params, step) -> (params, state)


# ---------------------------------------------------------------------------
# SGD / momentum
# ---------------------------------------------------------------------------

def _make_sgd(cfg: OptConfig, nesterov_momentum: bool) -> Optimizer:
    def init(params):
        if not nesterov_momentum:
            return {}
        return {"m": jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        lr = lr_schedule(cfg, step)
        g = _clip(grads, cfg.grad_clip) if cfg.grad_clip else grads
        if not nesterov_momentum:
            new = jax.tree.map(
                lambda p, gg: (p.astype(jnp.float32) - lr * gg
                               ).astype(p.dtype), params, g)
            return new, state
        m_new = jax.tree.map(lambda m, gg: cfg.momentum * m + gg,
                             state["m"], g)
        new = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, m_new)
        return new, {"m": m_new}

    return Optimizer(cfg, init, update)


# ---------------------------------------------------------------------------
# Adam / AdamW
# ---------------------------------------------------------------------------

def _make_adam(cfg: OptConfig, decoupled_wd: bool) -> Optimizer:
    def init(params):
        z = lambda x: jnp.zeros(x.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        lr = lr_schedule(cfg, step)
        g = _clip(grads, cfg.grad_clip) if cfg.grad_clip else \
            jax.tree.map(lambda x: x.astype(jnp.float32), grads)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - cfg.beta1 ** t
        bc2 = 1.0 - cfg.beta2 ** t

        def upd(p, gg, m, v):
            m2 = cfg.beta1 * m + (1 - cfg.beta1) * gg
            v2 = cfg.beta2 * v + (1 - cfg.beta2) * gg * gg
            upd_ = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
            pf = p.astype(jnp.float32)
            if decoupled_wd and cfg.weight_decay:
                pf = pf * (1.0 - lr * cfg.weight_decay)
            return (pf - lr * upd_).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, params, g, state["m"], state["v"])
        is3 = lambda t_: isinstance(t_, tuple) and len(t_) == 3
        new = jax.tree.map(lambda t_: t_[0], out, is_leaf=is3)
        m = jax.tree.map(lambda t_: t_[1], out, is_leaf=is3)
        v = jax.tree.map(lambda t_: t_[2], out, is_leaf=is3)
        return new, {"m": m, "v": v}

    return Optimizer(cfg, init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment over the trailing two axes)
# ---------------------------------------------------------------------------

def _factored(x: jax.Array, min_dim: int) -> bool:
    return x.ndim >= 2 and x.shape[-1] >= min_dim and x.shape[-2] >= min_dim


def _make_adafactor(cfg: OptConfig) -> Optimizer:
    def init(params):
        def one(x):
            if _factored(x, cfg.factored_min_dim):
                return {
                    "vr": jnp.zeros(x.shape[:-1], jnp.float32),       # row
                    "vc": jnp.zeros(x.shape[:-2] + x.shape[-1:],
                                    jnp.float32),                     # col
                }
            return {"v": jnp.zeros(x.shape, jnp.float32)}
        return {"v": jax.tree.map(one, params),
                "m": jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), params)
                if cfg.beta1 else None}

    def update(grads, state, params, step):
        lr = lr_schedule(cfg, step)
        t = step.astype(jnp.float32) + 1.0
        beta2t = 1.0 - t ** (-cfg.decay_rate)
        g = _clip(grads, cfg.grad_clip) if cfg.grad_clip else \
            jax.tree.map(lambda x: x.astype(jnp.float32), grads)

        def upd(p, gg, v, m):
            g2 = gg * gg + 1e-30
            if "vr" in v:
                vr = beta2t * v["vr"] + (1 - beta2t) * jnp.mean(g2, -1)
                vc = beta2t * v["vc"] + (1 - beta2t) * jnp.mean(g2, -2)
                rms_r = vr / jnp.mean(vr, -1, keepdims=True)
                precond = gg / (jnp.sqrt(rms_r)[..., None]
                                * jnp.sqrt(vc)[..., None, :] + cfg.eps)
                v_new = {"vr": vr, "vc": vc}
            else:
                vf = beta2t * v["v"] + (1 - beta2t) * g2
                precond = gg / (jnp.sqrt(vf) + cfg.eps)
                v_new = {"v": vf}
            # update clipping (Adafactor's RMS-1 rule)
            rms = jnp.sqrt(jnp.mean(precond * precond) + 1e-30)
            precond = precond / jnp.maximum(1.0, rms)
            if m is not None:
                m = cfg.beta1 * m + (1 - cfg.beta1) * precond
                precond = m
            pf = p.astype(jnp.float32)
            if cfg.weight_decay:
                pf = pf * (1.0 - lr * cfg.weight_decay)
            return (pf - lr * precond).astype(p.dtype), v_new, m

        ms = (state["m"] if state["m"] is not None
              else jax.tree.map(lambda _: None, params))
        out = jax.tree.map(upd, params, g, state["v"], ms,
                           is_leaf=lambda x: x is None)
        # out leaves are 3-tuples; state["v"] subdicts already consumed
        is3 = lambda t_: isinstance(t_, tuple) and len(t_) == 3
        new = jax.tree.map(lambda t_: t_[0], out, is_leaf=is3)
        v = jax.tree.map(lambda t_: t_[1], out, is_leaf=is3)
        m = (jax.tree.map(lambda t_: t_[2], out, is_leaf=is3)
             if state["m"] is not None else None)
        return new, {"v": v, "m": m}

    return Optimizer(cfg, init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    cfg = OptConfig(name=name, **kw)
    if name == "sgd":
        return _make_sgd(cfg, nesterov_momentum=False)
    if name == "momentum":
        return _make_sgd(cfg, nesterov_momentum=True)
    if name == "adam":
        return _make_adam(cfg, decoupled_wd=False)
    if name == "adamw":
        return _make_adam(cfg, decoupled_wd=True)
    if name == "adafactor":
        return _make_adafactor(cfg)
    raise ValueError(f"unknown optimizer {name!r}")
