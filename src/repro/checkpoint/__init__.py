from .manager import CheckpointManager, reshard_workers

__all__ = ["CheckpointManager", "reshard_workers"]
