"""Sharded, atomic, keep-k checkpointing with elastic resharding.

Layout::

    <dir>/step_000123/
        manifest.json        # tree structure, dtypes, shapes, plan, meta
        arr_00000.npy ...    # one file per leaf (content-addressed name)
    <dir>/LATEST             # atomic pointer (rename-into-place)

Design points for the 1000+-node setting (adapted to a single-host
container; the multi-host variant shards leaves by process index):

* **atomic** — everything is written into ``step_x.tmp`` and ``os.rename``d;
  a crash mid-save never corrupts the last good checkpoint;
* **async** — ``save()`` snapshots to host memory (device_get) and hands the
  file I/O to a background thread, so the train loop resumes immediately;
* **keep-k** — old steps garbage-collected after a successful save;
* **elastic** — :func:`reshard_workers` maps a worker-stacked state saved
  with ``W_old`` replicas onto ``W_new``: replicas are *averaged* into the
  shared model and re-broadcast (a synchronization point, so Lemma 4's
  bounded-staleness argument is preserved across membership changes), and
  the SyncPlan is re-solved by the caller for the new ``K``/bandwidth.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CheckpointManager", "reshard_workers"]

PyTree = Any


def _path_str(p) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten(tree: PyTree) -> list[tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [("/".join(_path_str(p) for p in path), np.asarray(leaf))
            for path, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: PyTree, *, meta: dict | None = None,
             block: bool = False) -> None:
        self.wait()                       # one in-flight save at a time
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        treedef = jax.tree_util.tree_structure(state)

        def work():
            try:
                self._write(step, host, treedef, meta or {})
                self._gc()
            except BaseException as e:    # surfaced by the next wait()
                self._error = e

        if self.async_save and not block:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self.wait()                   # re-raise a sync-save failure

    def wait(self) -> None:
        """Block until any in-flight save lands; re-raise its failure."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host: PyTree, treedef, meta: dict) -> None:
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _flatten(host)
        manifest = {"step": step, "meta": meta, "leaves": []}
        for i, (key, arr) in enumerate(leaves):
            fn = f"arr_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append(
                {"key": key, "file": fn, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        latest_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(name)
        os.rename(latest_tmp, os.path.join(self.dir, "LATEST"))

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        self.wait()                       # pending async saves count
        ptr = os.path.join(self.dir, "LATEST")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            return int(f.read().strip().split("_")[1])

    def peek_meta(self, step: int | None = None) -> dict:
        """Read a checkpoint's manifest ``meta`` without loading arrays.

        The async hierarchical runner stores its membership/cursor state
        here and needs it *before* it can build the restore template
        (which worker states and in-flight deltas exist is itself part
        of the checkpoint).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)["meta"]

    def restore(self, template: PyTree, *, step: int | None = None
                ) -> tuple[int, PyTree, dict]:
        """Load into ``template``'s structure (shapes may differ in the
        worker axis — caller reshards via :func:`reshard_workers`).

        Waits for any in-flight async save first, so a restore issued right
        after a save never races the background writer."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_key = {e["key"]: e for e in manifest["leaves"]}

        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for path, _leaf in flat:
            key = "/".join(_path_str(p) for p in path)
            if key not in by_key:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = np.load(os.path.join(d, by_key[key]["file"]))
            out.append(arr)
        return step, jax.tree_util.tree_unflatten(treedef, out), \
            manifest["meta"]


def reshard_workers(state: PyTree, w_new: int) -> PyTree:
    """Elastically change the worker-replica count.

    Every leaf's axis 0 is the worker axis.  Replicas are averaged (float32)
    and broadcast to ``w_new`` — all workers restart from a synchronization
    point, so convergence guarantees survive membership changes.
    """
    def one(x):
        x = jnp.asarray(x)
        m = jnp.mean(x.astype(jnp.float32), axis=0,
                     keepdims=True).astype(x.dtype)
        return jnp.broadcast_to(m, (w_new,) + x.shape[1:])
    return jax.tree.map(one, state)
