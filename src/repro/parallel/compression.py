"""int8 + error-feedback compression for the slow (pod/WAN) sync axis.

Beyond-paper (recorded separately in EXPERIMENTS.md): the paper sends raw
parameters; on a 10 Mbps-1 Gbps WAN, quantizing the synchronized *delta*
(parameter minus the last synchronized value) to int8 with per-row scales
cuts the collective term ~2x vs bf16 with error feedback absorbing the
quantization noise (Karimireddy et al.-style EF21 on the model-average
stream).

The quantize/dequantize pair also has a Pallas kernel
(:mod:`repro.kernels.int8_quant`); this module is the jnp reference used by
the step builder.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "EFState", "ef_init",
           "compressed_worker_mean"]

PyTree = Any


def quantize_int8(x: jax.Array, *, axis: int = -1,
                  stochastic_key: jax.Array | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-slice int8 quantization along ``axis``."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=axis, keepdims=True) / 127.0 + 1e-12
    y = xf / scale
    if stochastic_key is not None:
        y = y + jax.random.uniform(stochastic_key, y.shape,
                                   minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


class EFState(NamedTuple):
    """Per-leaf error-feedback residuals (float32, worker-stacked)."""

    residual: PyTree


def ef_init(params: PyTree) -> EFState:
    return EFState(jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params))


def compressed_worker_mean(x: jax.Array, residual: jax.Array
                           ) -> tuple[jax.Array, jax.Array]:
    """Worker-mean of ``x`` through an int8 wire format + error feedback.

    Each worker quantizes ``delta_k = x_k - mean_prev_estimate + e_k``;
    in the SPMD formulation we quantize the *deviation from the worker
    mean's bf16 cast* so the wire carries int8.  Returns
    ``(synced, new_residual)``; ``synced`` is identical across the worker
    axis.  Under GSPMD the ``mean`` of the int8-dequantized tensor lowers to
    the all-reduce of ~1 byte/element instead of 2 (the collective-bytes
    saving measured in the dry-run HLO).
    """
    xf = x.astype(jnp.float32) + residual
    q, scale = quantize_int8(xf)
    deq = dequantize_int8(q, scale)
    new_residual = xf - deq
    synced = jnp.mean(deq, axis=0, keepdims=True)
    synced = jnp.broadcast_to(synced, x.shape).astype(x.dtype)
    return synced, new_residual
