from .compression import (EFState, compressed_worker_mean, dequantize_int8,
                          ef_init, quantize_int8)
from .sharding import (batch_shardings, cache_shardings, leaf_spec, named,
                       param_shardings)

__all__ = [
    "EFState", "compressed_worker_mean", "dequantize_int8", "ef_init",
    "quantize_int8", "batch_shardings", "cache_shardings", "leaf_spec",
    "named", "param_shardings",
]
