"""Logical-axis -> mesh sharding rules.

Models annotate every parameter leaf with logical axis names
(``'vocab' | 'heads' | 'ff' | 'expert' | 'layers' | None``).  This module
turns those into :class:`jax.sharding.PartitionSpec`s for a given mesh:

* tensor/expert parallel: ``vocab/heads/ff/expert -> 'model'``;
* the worker axis (divergent local-SGD replicas) is **prepended** to every
  spec — ``('data',)`` / ``('pod','data')`` for small archs, ``('pod',)``
  for large ones, ``()`` when W == 1;
* FSDP (large archs): the first unsharded non-layer dim of every >=2D leaf
  is sharded over ``'data'`` (ZeRO-3-style storage; GSPMD all-gathers per
  layer inside the scan).

Batch specs: training batches are ``[W, B/W, S]`` -> ``P(worker_axes,
leftover_data_axes)``; serving batches shard over ``'data'`` and activations
inherit from the einsums.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["RULES", "leaf_spec", "param_shardings", "batch_shardings",
           "named", "cache_shardings", "maybe_constrain"]


def _ambient_mesh():
    """The mesh currently in scope, across jax versions (or ``None``).

    jax >= 0.5 exposes :func:`jax.sharding.get_abstract_mesh`; on 0.4.x the
    context set by ``with mesh:`` lives in the thread-local resource env.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        try:
            mesh = getter()
            if mesh is not None and getattr(mesh, "axis_names", ()):
                return mesh
        except Exception:                                   # noqa: BLE001
            pass
    try:
        from jax._src import mesh as mesh_lib
        return mesh_lib.thread_resources.env.physical_mesh
    except Exception:                                       # noqa: BLE001
        return None


def maybe_constrain(x, *dims):
    """`with_sharding_constraint` that degrades to identity when no mesh
    (or a mesh without the named axes) is ambient — model code stays
    runnable on bare CPU while dry-run lowering (under ``jax.set_mesh``)
    gets the constraint.  Used to pin activation shardings where GSPMD's
    solver otherwise picks contraction-dim partial sums (§Perf).

    ``None`` dims are left UNCONSTRAINED (a ``None`` in a raw
    with_sharding_constraint means *replicated*, which would force
    gathers on batch dims — measured as +78% FLOPs in the dsv3 cell).
    Named dims are dropped when the dim size does not divide the axis.
    """
    mesh = _ambient_mesh()
    names = getattr(mesh, "axis_names", ())
    want = {d for dd in dims if dd is not None
            for d in ((dd,) if isinstance(dd, str) else dd)}
    if not names or not want.issubset(set(names)):
        return x
    sizes = dict(getattr(mesh, "shape", {}))

    def ax_size(dd):
        if isinstance(dd, str):
            return sizes.get(dd, 1)
        n = 1
        for a in dd:
            n *= sizes.get(a, 1)
        return n

    spec = []
    for i, dd in enumerate(dims):
        if dd is None:
            spec.append(P.UNCONSTRAINED)
        elif x.shape[i] % ax_size(dd) == 0:
            spec.append(dd)
        else:
            spec.append(P.UNCONSTRAINED)
    return jax.lax.with_sharding_constraint(x, P(*spec))

PyTree = Any

RULES: dict[str | None, str | None] = {
    "vocab": "model",
    "heads": "model",
    "ff": "model",
    "expert": "model",
    "layers": None,
    None: None,
}


def leaf_spec(logical: tuple, *, worker_axes: tuple[str, ...] = (),
              fsdp: bool = False, fsdp_axis: str = "data",
              with_lead: bool = True, shape: tuple[int, ...] | None = None,
              mesh: Mesh | None = None,
              rules: dict | None = None) -> P:
    """One leaf's PartitionSpec from its logical axes.

    Each mesh axis may appear at most once: the first logical dim claiming
    it wins (e.g. MoE ``('expert', None, 'ff')`` -> expert-parallel over
    ``model``, ``ff`` left unsharded).  ``with_lead`` prepends the worker
    axis entry (worker-stacked training trees); serving trees have no
    worker dim and pass ``with_lead=False``.  With ``shape``/``mesh`` a dim
    is only sharded when divisible by the mesh axis (explicitly-sharded jit
    arguments must divide evenly; e.g. vocab 50280 over model=16 falls back
    to replicated — noted in DESIGN.md)."""
    used = set(worker_axes)
    off = 1 if with_lead else 0
    rules = RULES if rules is None else rules

    def axes_of(m) -> tuple[str, ...]:
        return (m,) if isinstance(m, str) else tuple(m)

    def divisible(i: int, m) -> bool:
        if shape is None or mesh is None:
            return True
        size = 1
        for a in axes_of(m):
            size *= mesh.shape[a]
        return shape[i + off] % size == 0

    dims: list = []
    for i, ax in enumerate(logical):
        m = rules.get(ax, None)
        if m is not None and (any(a in used for a in axes_of(m))
                              or not divisible(i, m)):
            m = None
        if m is not None:
            used.update(axes_of(m))
        dims.append(m)
    if fsdp and fsdp_axis not in used:
        # shard the first unsharded, non-layer dim over `data`
        for i, (ax, d) in enumerate(zip(logical, dims, strict=True)):
            if d is None and ax != "layers" and len(logical) >= 2 \
                    and divisible(i, fsdp_axis):
                dims[i] = fsdp_axis
                break
    if not with_lead:
        return P(*dims)
    lead = (worker_axes if len(worker_axes) != 1 else worker_axes[0]) \
        if worker_axes else None
    return P(lead, *dims)


RULES_FSDP_MODEL: dict[str | None, str | None] = {
    # intra-worker ZeRO-3: no tensor parallel; weights sharded over the
    # model axis via the fsdp mechanism, batch sharded over `model`.
    # Expert dim keeps EP (weights already partitioned by expert).
    "vocab": None, "heads": None, "ff": None, "expert": "model",
    "layers": None, None: None,
}

RULES_EP2: dict[str | None, object] = {
    # two-axis expert parallel: expert dim over (`data` x `model`) jointly
    # (256 experts / 256 chips = 1 expert/device, weights fully local —
    # no FSDP gathers or partial sums on the expert matmuls; token
    # redistribution rides the dispatch einsums).  §Perf dsv3 iteration.
    "vocab": "model", "heads": "model", "ff": None,
    "expert": ("data", "model"), "layers": None, None: None,
}


def param_shardings(spec_tree: PyTree, mesh: Mesh, *,
                    worker_axes: tuple[str, ...] = (),
                    fsdp: bool = False, with_lead: bool = True,
                    shapes: PyTree | None = None,
                    rules: dict | None = None,
                    fsdp_axis: str = "data") -> PyTree:
    """NamedShardings for a (worker-stacked) parameter tree.

    ``spec_tree`` mirrors the *unstacked* params (logical tuples at leaves);
    with ``with_lead`` the worker axis is assumed prepended to every leaf.
    ``shapes`` (a matching ShapeDtypeStruct tree) enables divisibility
    checks."""
    is_spec = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)

    def one(sp, sds=None):
        return NamedSharding(
            mesh, leaf_spec(tuple(sp), worker_axes=worker_axes, fsdp=fsdp,
                            with_lead=with_lead,
                            shape=None if sds is None else tuple(sds.shape),
                            mesh=mesh, rules=rules, fsdp_axis=fsdp_axis))

    if shapes is None:
        return jax.tree.map(one, spec_tree, is_leaf=is_spec)
    return jax.tree.map(one, spec_tree, shapes, is_leaf=is_spec)


def named(mesh: Mesh, *dims) -> NamedSharding:
    return NamedSharding(mesh, P(*dims))


def batch_shardings(batch_spec: PyTree, mesh: Mesh, *,
                    worker_axes: tuple[str, ...],
                    data_axes_left: tuple[str, ...]) -> PyTree:
    """Training batch ``[W, B/W, ...]``: worker axis + leftover data axes."""
    lead = (worker_axes if len(worker_axes) != 1 else worker_axes[0]) \
        if worker_axes else None
    sub = (data_axes_left if len(data_axes_left) != 1 else
           data_axes_left[0]) if data_axes_left else None

    def one(s):
        rest = (None,) * (len(s.shape) - 2)
        return NamedSharding(mesh, P(lead, sub, *rest))

    return jax.tree.map(one, batch_spec)


def cache_shardings(cache_spec: PyTree, mesh: Mesh, *,
                    batch_axes=("data",)) -> PyTree:
    """Serving caches ``[n_layers, B, S, ...]``: shard batch over data, and
    the head/state trailing dims over 'model' when present (>=4D leaves)."""
    ba = batch_axes if len(batch_axes) != 1 else batch_axes[0]

    def one(s):
        nd = len(s.shape)
        if nd >= 4:
            # [layers, B, S, heads, ...] -> heads over model
            dims = [None, ba, None, "model"] + [None] * (nd - 4)
        elif nd == 3:
            dims = [None, ba, None]
        else:
            dims = [None] * nd
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(one, cache_spec)
