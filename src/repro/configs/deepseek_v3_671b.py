"""deepseek-v3-671b — DeepSeek-V3 (MLA + 256-expert MoE + MTP).

[arXiv:2412.19437]: 61 layers, d_model 7168; MLA with 128 heads
(q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64, v 128); first 3 layers
dense (d_ff 18432), remaining 58 MoE with 1 shared + 256 routed experts
top-8 (sigmoid router, routed scale 2.5), per-expert d_ff 2048 (assigned
spec); vocab 129280; one MTP module.
"""

from ..models.mla import MLAConfig
from ..models.moe import MoEConfig
from ..models.transformer import DecoderLM, LMConfig
from .common import ArchSpec

CONFIG = LMConfig(
    name="deepseek-v3-671b",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,                     # per-expert hidden (assigned spec)
    vocab=129_280,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    tie_embeddings=False,
    mla=MLAConfig(n_heads=128, q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff=2048, n_shared=1,
                  capacity_factor=1.25, router="sigmoid", routed_scale=2.5),
    n_dense_layers=3,
    dense_d_ff=18432,
    mtp=True,
)

SMOKE = LMConfig(
    name="dsv3-smoke",
    n_layers=3,
    d_model=48,
    n_heads=4,
    n_kv_heads=4,
    d_ff=32,
    vocab=256,
    mla=MLAConfig(n_heads=4, q_lora_rank=24, kv_lora_rank=16,
                  qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=1,
                  router="sigmoid", routed_scale=2.5),
    n_dense_layers=1,
    dense_d_ff=96,
    mtp=True,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="deepseek-v3-671b",
    family="moe",
    make_model=lambda: DecoderLM(CONFIG),
    make_smoke=lambda: DecoderLM(SMOKE),
    large=True,                    # 671B: one replica spans a pod (FSDP)
    optimizer="adafactor",
    sub_quadratic=False,           # MLA is still full quadratic attention
    notes="MLA absorbed decode (57x KV shrink); MTP head = extra unit",
)
