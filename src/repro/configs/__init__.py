"""Assigned-architecture registry: ``--arch <id>`` -> :class:`ArchSpec`."""

from __future__ import annotations

from . import (deepseek_v3_671b, granite_3_2b, llava_next_34b, mamba2_780m,
               phi4_mini_3_8b, qwen2_5_32b, qwen3_1_7b, qwen3_moe_30b_a3b,
               recurrentgemma_9b, whisper_medium)
from .common import ArchSpec, batch_specs
from .shapes import SHAPES, ShapeSpec

_MODULES = (granite_3_2b, phi4_mini_3_8b, qwen2_5_32b, qwen3_1_7b,
            llava_next_34b, mamba2_780m, recurrentgemma_9b,
            qwen3_moe_30b_a3b, deepseek_v3_671b, whisper_medium)

ARCHS: dict[str, ArchSpec] = {m.ARCH.arch_id: m.ARCH for m in _MODULES}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; choose from "
                       f"{sorted(ARCHS)}")
    return ARCHS[arch_id]


def all_cells() -> list[tuple[str, str]]:
    """Every runnable (arch_id, shape_name) pair."""
    return [(a.arch_id, s.name) for a in ARCHS.values()
            for s in a.shapes()]


__all__ = ["ARCHS", "SHAPES", "ArchSpec", "ShapeSpec", "get_arch",
           "batch_specs", "all_cells"]
