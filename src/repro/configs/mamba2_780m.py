"""mamba2-780m — Mamba-2 780M (attention-free SSM, SSD).

[arXiv:2405.21060]: 48 layers, d_model 1536 (d_inner 3072, 48 heads x
head_dim 64), ssm_state 128, vocab 50280, conv width 4.
"""

from ..models.mamba2 import Mamba2Config, Mamba2LM
from .common import ArchSpec

CONFIG = Mamba2Config(
    name="mamba2-780m",
    n_layers=48,
    d_model=1536,
    vocab=50_280,
    d_state=128,
    head_dim=64,
    expand=2,
    n_groups=1,
    conv_width=4,
    chunk=128,
    param_dtype="bfloat16",
)

SMOKE = Mamba2Config(
    name="mamba2-smoke",
    n_layers=3,
    d_model=48,
    vocab=384,
    d_state=16,
    head_dim=8,
    chunk=8,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="mamba2-780m",
    family="ssm",
    make_model=lambda: Mamba2LM(CONFIG),
    make_smoke=lambda: Mamba2LM(SMOKE),
    large=False,
    optimizer="adamw",
    sub_quadratic=True,            # O(1)-state decode: long_500k runs
    notes="attention-free; partial sync applies to mamba blocks unchanged",
)
