"""qwen3-moe-30b-a3b — Qwen3-30B-A3B (MoE, 128 experts top-8).

[hf:Qwen/Qwen3-30B-A3B]: 48 layers, d_model 2048, 32 heads with GQA kv=4
(head_dim 128), per-expert d_ff 768, 128 experts top-8 (softmax router,
renormalized), vocab 151936, qk_norm, untied.
"""

from ..models.moe import MoEConfig
from ..models.transformer import DecoderLM, LMConfig
from .common import ArchSpec

CONFIG = LMConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,                      # per-expert hidden (assigned spec)
    vocab=151_936,
    head_dim=128,
    qk_norm=True,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff=768, n_shared=0,
                  capacity_factor=1.25, router="softmax"),
)

SMOKE = LMConfig(
    name="qwen3-moe-smoke",
    n_layers=3,
    d_model=32,
    n_heads=4,
    n_kv_heads=2,
    d_ff=48,
    vocab=256,
    head_dim=8,
    qk_norm=True,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=48, capacity_factor=1.25),
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="qwen3-moe-30b-a3b",
    family="moe",
    make_model=lambda: DecoderLM(CONFIG),
    make_smoke=lambda: DecoderLM(SMOKE),
    large=True,                    # expert bytes dominate; EP over `model`
    optimizer="adafactor",
    sub_quadratic=False,
    notes="expert-parallel over model axis; huge t_COMM^l for MoE layers",
)
