"""recurrentgemma-9b — Griffin hybrid (RG-LRU + local attention, 1:2).

[arXiv:2402.19427]: 38 temporal layers in pattern (rec, rec, attn),
d_model 4096, 16 heads MQA (kv=1, head_dim 256), d_ff 12288 (GeGLU),
lru_width 4096, window 2048, vocab 256000, tied embeddings.

Organised as 12 scanned superblocks of (rec, rec, attn) + a 2-layer rec
tail; a superblock is one DreamDDP unit — the heterogeneous-cost case where
Algorithm 2's schedule beats the equal-number partition.
"""

from ..models.rglru import RGConfig, RGLM
from .common import ArchSpec

CONFIG = RGConfig(
    name="recurrentgemma-9b",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256_000,
    lru_width=4096,
    head_dim=256,
    window=2048,
    conv_width=4,
    pattern=("rec", "rec", "attn"),
)

SMOKE = RGConfig(
    name="rg-smoke",
    n_layers=5,
    d_model=32,
    n_heads=4,
    n_kv_heads=1,
    d_ff=64,
    vocab=256,
    lru_width=32,
    head_dim=8,
    window=8,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    make_model=lambda: RGLM(CONFIG),
    make_smoke=lambda: RGLM(SMOKE),
    large=False,                    # Adafactor: 16 replicas fit (DESIGN §7)
    optimizer="adafactor",
    sub_quadratic=True,             # LRU state + 2048 window: long_500k runs
    notes="1:2 attn:rec; window attention => sub-quadratic decode",
)
