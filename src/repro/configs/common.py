"""ArchSpec — how one assigned architecture plugs into the framework."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax.numpy as jnp
from jax import ShapeDtypeStruct

from .shapes import SHAPES, ShapeSpec

__all__ = ["ArchSpec", "batch_specs"]


@dataclass(frozen=True)
class ArchSpec:
    """One selectable ``--arch``.

    ``large`` archs cannot replicate per-DP-rank (a full divergent replica
    does not fit 16 chips x 16 GB): their local-SGD worker axis is the
    ``pod`` axis only (W=1 single-pod, W=2 multi-pod) and parameters are
    FSDP-sharded over ``data`` inside the worker.  Small archs put workers
    on (``pod`` x) ``data`` — the paper's 8-32-worker regime.
    """

    arch_id: str
    family: str                               # dense|vlm|ssm|hybrid|moe|audio
    make_model: Callable[[], Any]             # full published config
    make_smoke: Callable[[], Any]             # reduced same-family config
    large: bool = False                       # worker axis = pod only + FSDP
    optimizer: str = "adamw"
    sub_quadratic: bool = False               # long_500k runnable
    frontend: str | None = None               # "vision" | "audio" (stub)
    n_frontend_tokens: int = 0                # patches / frames prepended
    notes: str = ""

    # ---- shape coverage -----------------------------------------------------
    def shapes(self) -> list[ShapeSpec]:
        out = []
        for s in SHAPES.values():
            if s.name == "long_500k" and not self.sub_quadratic:
                continue                      # quadratic attention: skipped
            out.append(s)
        return out

    def n_workers(self, *, multi_pod: bool) -> int:
        if self.large:
            return 2 if multi_pod else 1
        return 32 if multi_pod else 16

    def worker_axes(self, *, multi_pod: bool) -> tuple[str, ...]:
        if self.large:
            return ("pod",) if multi_pod else ()
        return ("pod", "data") if multi_pod else ("data",)


def batch_specs(arch: ArchSpec, shape: ShapeSpec, *,
                n_workers: int = 1) -> dict[str, ShapeDtypeStruct]:
    """ShapeDtypeStructs for the *data inputs* of one (arch x shape) cell.

    Training batches carry the leading worker axis ``[W, B/W, ...]``;
    serving requests do not (serving uses one synchronized replica).
    """
    model = arch.make_model()
    d = model.cfg.d_model
    i32, bf16 = jnp.int32, jnp.bfloat16
    s, b = shape.seq_len, shape.global_batch

    if shape.kind == "train":
        w = n_workers
        if b % max(w, 1):
            raise ValueError(f"global_batch {b} not divisible by W={w}")
        bw = b // w
        nf = arch.n_frontend_tokens
        text = s - nf if arch.frontend == "vision" else s
        spec = {
            "tokens": ShapeDtypeStruct((w, bw, text), i32),
            "labels": ShapeDtypeStruct((w, bw, text), i32),
        }
        if arch.frontend == "vision":
            spec["embeds"] = ShapeDtypeStruct((w, bw, nf, d), bf16)
        if arch.frontend == "audio":
            spec["frames"] = ShapeDtypeStruct((w, bw, nf, d), bf16)
        return spec

    if shape.kind == "prefill":
        nf = arch.n_frontend_tokens
        text = s - nf if arch.frontend == "vision" else s
        spec = {"tokens": ShapeDtypeStruct((b, text), i32)}
        if arch.frontend == "vision":
            spec["embeds"] = ShapeDtypeStruct((b, nf, d), bf16)
        if arch.frontend == "audio":
            spec["frames"] = ShapeDtypeStruct((b, nf, d), bf16)
        return spec

    # decode: one new token against a seq_len-deep cache
    return {
        "token": ShapeDtypeStruct((b, 1), i32),
        "pos": ShapeDtypeStruct((b,), i32),
    }
