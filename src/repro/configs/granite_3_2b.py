"""granite-3-2b — IBM Granite 3.0 2B base (dense GQA).

[hf:ibm-granite/granite-3.0-2b-base]: 40 layers, d_model 2048, 32 heads with
GQA kv=8, d_ff 8192 (SwiGLU), vocab 49155, RoPE, RMSNorm, tied embeddings.
"""

from ..models.transformer import DecoderLM, LMConfig
from .common import ArchSpec

CONFIG = LMConfig(
    name="granite-3-2b",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=49155,
    head_dim=64,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE = LMConfig(
    name="granite-smoke",
    n_layers=4,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab=512,
    head_dim=8,
    tie_embeddings=True,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="granite-3-2b",
    family="dense",
    make_model=lambda: DecoderLM(CONFIG),
    make_smoke=lambda: DecoderLM(SMOKE),
    large=False,
    optimizer="adamw",
    sub_quadratic=False,
    notes="GQA dense baseline; full-attention => long_500k skipped",
)
