"""llava-next-34b — LLaVA-NeXT 34B backbone (VLM; anyres frontend = stub).

Backbone per assignment: 60 layers, d_model 7168, 56 heads with GQA kv=8,
d_ff 20480, vocab 64000 (the Yi-34B-style trunk).  The anyres vision tower
is a STUB: ``input_specs()`` supplies precomputed patch embeddings
``[b, n_patches, d_model]`` that are prepended to the token embeddings
(n_patches=576, one base tile).  Loss covers the text tail only.
"""

from ..models.transformer import DecoderLM, LMConfig
from .common import ArchSpec

CONFIG = LMConfig(
    name="llava-next-34b",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64_000,
    head_dim=128,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=5_000_000.0,
    tie_embeddings=False,
)

SMOKE = LMConfig(
    name="llava-smoke",
    n_layers=3,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=160,
    vocab=512,
    head_dim=8,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="llava-next-34b",
    family="vlm",
    make_model=lambda: DecoderLM(CONFIG),
    make_smoke=lambda: DecoderLM(SMOKE),
    large=True,                 # 34B: one divergent replica per pod
    optimizer="adafactor",
    sub_quadratic=False,
    frontend="vision",
    n_frontend_tokens=576,
    notes="anyres tiling stubbed as precomputed patch embeddings",
)
