"""whisper-medium — Whisper medium backbone (enc-dec; conv frontend stub).

[arXiv:2212.04356]: 24 encoder + 24 decoder layers, d_model 1024, 16 heads
(MHA, kv=16), d_ff 4096 (GELU), vocab 51865, 1500 audio frames.  The conv
frontend is a STUB (``input_specs()`` provides precomputed frame
embeddings); ``max_positions`` is raised to the assigned 32k stress shape
(the real model stops at 448 — backbone stress test per the brief).
"""

from ..models.whisper import WhisperConfig, WhisperModel
from .common import ArchSpec

CONFIG = WhisperConfig(
    name="whisper-medium",
    n_enc_layers=24,
    n_dec_layers=24,
    d_model=1024,
    n_heads=16,
    d_ff=4096,
    vocab=51_865,
    n_frames=1500,
    max_positions=32_776,
)

SMOKE = WhisperConfig(
    name="whisper-smoke",
    n_enc_layers=2,
    n_dec_layers=2,
    d_model=32,
    n_heads=4,
    d_ff=64,
    vocab=256,
    n_frames=12,
    max_positions=64,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="whisper-medium",
    family="audio",
    make_model=lambda: WhisperModel(CONFIG),
    make_smoke=lambda: WhisperModel(SMOKE),
    large=False,
    optimizer="adamw",
    sub_quadratic=False,
    frontend="audio",
    n_frontend_tokens=1500,
    notes="enc-dec; cross-attention decode against cached encoder KV",
)
