"""phi4-mini-3.8b — Microsoft Phi-4-mini (dense GQA, RoPE, SwiGLU).

[arXiv:2412.08905]: 32 layers, d_model 3072, 24 heads with GQA kv=8,
d_ff 8192, vocab 200064 (o200k), tied embeddings.
"""

from ..models.transformer import DecoderLM, LMConfig
from .common import ArchSpec

CONFIG = LMConfig(
    name="phi4-mini-3.8b",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200_064,
    head_dim=128,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE = LMConfig(
    name="phi4-smoke",
    n_layers=3,
    d_model=48,
    n_heads=6,
    n_kv_heads=2,
    d_ff=128,
    vocab=640,
    head_dim=8,
    tie_embeddings=True,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="phi4-mini-3.8b",
    family="dense",
    make_model=lambda: DecoderLM(CONFIG),
    make_smoke=lambda: DecoderLM(SMOKE),
    large=False,
    optimizer="adamw",
    sub_quadratic=False,
    notes="24 q-heads: not divisible by model=16 — GSPMD pads; see §Perf",
)
