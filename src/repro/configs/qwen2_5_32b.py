"""qwen2.5-32b — Qwen2.5-32B (dense GQA with QKV bias).

[hf:Qwen/Qwen2.5-32B]: 64 layers, d_model 5120, 40 heads with GQA kv=8,
d_ff 27648, vocab 152064, QKV bias, untied embeddings.
"""

from ..models.transformer import DecoderLM, LMConfig
from .common import ArchSpec

CONFIG = LMConfig(
    name="qwen2.5-32b",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab=152_064,
    head_dim=128,
    qkv_bias=True,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

SMOKE = LMConfig(
    name="qwen2.5-smoke",
    n_layers=3,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=192,
    vocab=512,
    head_dim=8,
    qkv_bias=True,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="qwen2.5-32b",
    family="dense",
    make_model=lambda: DecoderLM(CONFIG),
    make_smoke=lambda: DecoderLM(SMOKE),
    large=False,                 # 16 workers fit with Adafactor (DESIGN §7)
    optimizer="adafactor",
    sub_quadratic=False,
    notes="QKV bias; Adafactor so 16 divergent replicas fit a pod",
)
