"""qwen3-1.7b — Qwen3-1.7B (dense GQA with qk_norm).

[hf:Qwen/Qwen3-1.7B]: 28 layers, d_model 2048, 16 heads with GQA kv=8,
d_ff 6144, vocab 151936, per-head q/k RMSNorm, head_dim 128, tied.
"""

from ..models.transformer import DecoderLM, LMConfig
from .common import ArchSpec

CONFIG = LMConfig(
    name="qwen3-1.7b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab=151_936,
    head_dim=128,
    qk_norm=True,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = LMConfig(
    name="qwen3-smoke",
    n_layers=3,
    d_model=48,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab=384,
    head_dim=16,
    qk_norm=True,
    tie_embeddings=True,
    param_dtype="float32",
)

ARCH = ArchSpec(
    arch_id="qwen3-1.7b",
    family="dense",
    make_model=lambda: DecoderLM(CONFIG),
    make_smoke=lambda: DecoderLM(SMOKE),
    large=False,
    optimizer="adamw",
    sub_quadratic=False,
    notes="qk_norm GQA",
)
