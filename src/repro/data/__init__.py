from .synthetic import MarkovCorpus, TeacherImages

__all__ = ["MarkovCorpus", "TeacherImages"]
