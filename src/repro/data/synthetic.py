"""Deterministic synthetic corpora (offline container: no external data).

* :class:`MarkovCorpus` — an order-1 Markov chain over the vocabulary with a
  low-entropy transition structure; a model that learns the transitions
  drives the loss well below the unigram entropy, so convergence curves are
  informative (used for the paper's GPT-2 / Llama-2 convergence repro).
* :class:`TeacherImages` — a frozen random "teacher" MLP labels random
  images; stands in for CIFAR in the ResNet experiments.

Both shard deterministically by worker id: worker ``k`` draws from stream
``seed * 1000 + k`` — IID across workers, per the paper's centralized
(non-federated) setting.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["MarkovCorpus", "TeacherImages"]


@dataclass
class MarkovCorpus:
    vocab: int
    seq_len: int
    batch_per_worker: int
    n_workers: int
    seed: int = 0
    branching: int = 4           # out-degree of each state (entropy knob)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        nexts = rng.integers(0, self.vocab,
                             size=(self.vocab, self.branching))
        probs = rng.dirichlet(np.ones(self.branching) * 0.5,
                              size=self.vocab)
        self._nexts = jnp.asarray(nexts)
        self._probs = jnp.asarray(probs, jnp.float32)
        self._base_keys = jnp.stack([
            jax.random.PRNGKey(self.seed * 1000 + k)
            for k in range(self.n_workers)])
        # one jitted program per corpus: the chain scan used to run as
        # hundreds of eager dispatches per batch (~300 ms of host time —
        # longer than the train step it feeds); compiled it is ~0.2 ms,
        # so the runner's period prefetcher can actually hide it
        self._build = jax.jit(self._batch_impl)

    def _batch_impl(self, step: jax.Array) -> dict:
        def one_worker(worker_key):
            def one_seq(key):
                k0, key = jax.random.split(key)
                start = jax.random.randint(k0, (), 0, self.vocab)

                def body(carry, k):
                    tok = carry
                    idx = jax.random.categorical(
                        k, jnp.log(self._probs[tok] + 1e-9))
                    nxt = self._nexts[tok, idx]
                    return nxt, tok
                keys = jax.random.split(key, self.seq_len)
                _, toks = jax.lax.scan(body, start, keys)
                return toks.astype(jnp.int32)
            keys = jax.random.split(worker_key, self.batch_per_worker)
            return jax.vmap(one_seq)(keys)

        wkeys = jax.vmap(jax.random.fold_in, in_axes=(0, None))(
            self._base_keys, step)
        toks = jax.vmap(one_worker)(wkeys)
        return {"tokens": toks, "labels": toks}

    def batch(self, step: int) -> dict:
        """Worker-stacked batch ``{tokens, labels}: [W, B, S]`` (int32)."""
        return self._build(jnp.asarray(step, jnp.int32))

    def entropy_floor(self) -> float:
        """Per-token conditional entropy of the chain (nats) — the loss a
        perfect model reaches."""
        p = np.asarray(self._probs)
        return float(-(p * np.log(p + 1e-12)).sum(-1).mean())


@dataclass
class TeacherImages:
    n_classes: int
    image_dim: int               # flattened image size
    batch_per_worker: int
    n_workers: int
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed + 7)
        self._w1 = jnp.asarray(
            rng.normal(0, 1 / np.sqrt(self.image_dim),
                       (self.image_dim, 128)), jnp.float32)
        self._w2 = jnp.asarray(
            rng.normal(0, 1 / np.sqrt(128), (128, self.n_classes)),
            jnp.float32)
        self._base_keys = jnp.stack([
            jax.random.PRNGKey(self.seed * 1000 + k)
            for k in range(self.n_workers)])
        self._build = jax.jit(self._batch_impl)   # same reason as Markov

    def _batch_impl(self, step: jax.Array) -> dict:
        def one_worker(key):
            x = jax.random.normal(
                key, (self.batch_per_worker, self.image_dim))
            logits = jnp.tanh(x @ self._w1) @ self._w2
            return x, jnp.argmax(logits, -1).astype(jnp.int32)
        wkeys = jax.vmap(jax.random.fold_in, in_axes=(0, None))(
            self._base_keys, step)
        xs, ys = jax.vmap(one_worker)(wkeys)
        return {"images": xs, "labels": ys}

    def batch(self, step: int) -> dict:
        return self._build(jnp.asarray(step, jnp.int32))
