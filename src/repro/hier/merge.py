"""Staleness-aware merge rules for the hierarchical server tier.

Two rules, both operating on the *same per-layer sync units* the
DreamDDP scheduler emits (via
:func:`repro.core.sync_policies.tree_unit_map`), so layer-wise partial
sync composes with asynchronous push/pull:

* ``"halos"`` — HALoS-style staleness-aware momentum (arxiv 2506.04531):
  each arriving delta is scaled by ``staleness_beta ** min(tau, bound)``
  (``tau`` = global versions elapsed since the contributing worker
  pulled), folded into a server-side momentum, and applied with a
  Nesterov-style look-ahead — the same shape as the DiLoCo outer step in
  :mod:`repro.core.outer_opt`, but keyed by staleness instead of a
  synchronous round.

* ``"delayed-nesterov"`` — from "Asynchronous Local-SGD Training for
  Language Modeling" (arxiv 2401.09135): apply the (staleness-scaled)
  delta immediately *without* momentum, accumulate it in a buffer, and
  every ``dn_delay`` merges fold the buffered average into the momentum
  and apply that in one delayed step.  Decouples the momentum update
  rate from the (asynchronous, bursty) delta arrival rate.

The staleness clamp ``max_staleness`` is the async counterpart of the
paper's Lemma 4 bound: a delta can never be weighted as if it were less
than ``staleness_beta ** max_staleness`` stale, and the executor's
histogram records how often the clamp engages.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any

__all__ = ["MergeConfig", "MERGE_RULES", "staleness_scale"]

PyTree = Any

MERGE_RULES = ("halos", "delayed-nesterov")


@dataclass(frozen=True)
class MergeConfig:
    """Hyper-parameters of the global merge (see module docstring).

    ``lr`` defaults to ``1 / n_workers`` (resolved at server init): each
    worker's full-period delta lands with weight ``1/W``, so a round of
    W fresh deltas advances the global model by the worker-mean delta —
    the async analogue of Eq. 5's synchronous parameter average.
    ``dn_delay`` defaults to ``n_workers`` for the same reason: one
    delayed-momentum application per nominal round.
    """

    rule: str = "halos"
    lr: float | None = None            # None -> 1 / n_workers
    momentum: float = 0.9
    nesterov: bool = True              # halos: Nesterov-style application
    staleness_beta: float = 0.9        # per-version decay of merge weight
    max_staleness: int = 8             # staleness clamp (Lemma 4 analogue)
    dn_delay: int = 0                  # delayed-nesterov: 0 -> n_workers

    def __post_init__(self):
        if self.rule not in MERGE_RULES:
            raise ValueError(f"merge rule must be one of {MERGE_RULES}, "
                             f"got {self.rule!r}")
        if not 0.0 < self.staleness_beta <= 1.0:
            raise ValueError("staleness_beta must be in (0, 1]")
        if self.max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")

    def resolve(self, n_workers: int) -> "MergeConfig":
        """Fill ``lr`` / ``dn_delay`` defaults for a concrete fleet size."""
        out = self
        if out.lr is None:
            out = replace(out, lr=1.0 / max(1, n_workers))
        if out.dn_delay <= 0:
            out = replace(out, dn_delay=max(1, n_workers))
        return out


def staleness_scale(cfg: MergeConfig, tau: int) -> float:
    """Weight of a delta that is ``tau`` global versions stale."""
    return cfg.staleness_beta ** min(max(0, tau), cfg.max_staleness)
