"""AsyncHierRunner — real training driven by the deterministic op log.

The :class:`~repro.hier.executor.AsyncSimExecutor` decides *when* things
happen (on seeded virtual clocks); this runner executes *what* happens,
in exactly that order:

* ``PullOp``    — worker downloads the global float32 model;
* ``PeriodOp``  — worker runs one fused H-step local period (the
  period-fused executor from :mod:`repro.runtime.step`, compiled once
  for a ``[H, 1, ...]`` single-worker batch via
  :func:`~repro.core.plans.local_period_plan` and reused by every
  worker) and computes its delta against the pulled base;
* ``PushOp``    — the per-phase layer-group delta lands at its
  datacenter's :class:`~repro.hier.servers.LocalServer`;
* ``MergeOp``   — that server's accumulated batch merges into the
  :class:`~repro.hier.servers.GlobalServer` with staleness-aware weight;
* ``JoinOp`` / ``LeaveOp`` — elastic membership: joiners bootstrap from
  the current global model with fresh optimizer state, leavers drop
  their local state (their already-pushed deltas still merge).

Every quantity that orders or scales an update (versions, staleness,
contributor sets) is carried *in* the op, and the runner asserts its own
server state agrees op-by-op — so the executor's timing machine and the
training math can never silently drift apart.  Checkpoints land only at
merge boundaries and store the full reachable state (worker states,
server tensors, in-flight deltas, membership, op cursor); a restore
regenerates the op log from the same seed and fast-forwards to the
cursor, which is why a resumed run replays to an identical trace and
bitwise-identical parameters (``DESIGN.md``).

Times in the history are *virtual* (simulated seconds) — the runner
never reads a wall clock, keeping ``repro.hier`` inside the
SIM-DETERMINISM lint scope.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from ..core.plans import SyncPlan, local_period_plan
from ..core.sync_policies import resolve_policy
from ..lint import hot_path
from ..runtime.step import StepConfig, init_train_state, make_period_step
from ..sim.executor import prepare_run
from .executor import (AsyncConfig, AsyncSimExecutor, JoinOp, LeaveOp,
                       MergeOp, PeriodOp, PullOp, PushOp)
from .servers import GlobalServer, LocalServer

__all__ = ["AsyncHierRunner", "AsyncRunnerConfig"]

PyTree = Any


@dataclass(frozen=True)
class AsyncRunnerConfig:
    async_cfg: AsyncConfig = field(default_factory=AsyncConfig)
    ckpt_every_merges: int = 0        # 0 = no periodic checkpoints
    fill_mode: str = "exact"


class AsyncHierRunner:
    """Execute async hierarchical training over a scenario's timeline."""

    def __init__(self, model, optimizer, strategy, data, *, profile,
                 scenario, step_cfg: StepConfig = StepConfig(),
                 run_cfg: AsyncRunnerConfig = AsyncRunnerConfig(),
                 H: int = 4, ckpt=None, seed: int = 0):
        policy = resolve_policy(step_cfg)
        if policy.name != "mean":
            raise ValueError(
                f"async runtime requires the plain mean sync policy "
                f"(deltas are merged server-side); got {policy.name!r}")
        self.model = model
        self.optimizer = optimizer
        self.strategy = strategy
        self.data = data
        self.profile = profile
        self.scenario = scenario
        self.step_cfg = step_cfg
        self.run_cfg = run_cfg
        self.ckpt = ckpt
        self.seed = seed
        self.layout = model.unit_layout()

        cluster, plan = prepare_run(scenario, strategy, H, profile,
                                    fill_mode=run_cfg.fill_mode)
        self.plan: SyncPlan = plan
        self.H = plan.H
        self._n_workers0 = cluster.n_active
        self._local_plan = local_period_plan(plan.n_units, plan.H)
        self._period_fn = make_period_step(
            model, optimizer, self._local_plan, cfg=step_cfg, donate=True)
        self._init_key = jax.random.PRNGKey(seed)
        self._template = init_train_state(model, optimizer, self._init_key,
                                          1, cfg=step_cfg)
        self._pull_fn = jax.jit(lambda g, p: jax.tree.map(
            lambda gl, pl: gl.astype(pl.dtype)[None], g, p))
        self._delta_fn = jax.jit(lambda p, g: jax.tree.map(
            lambda pl, gl: pl[0].astype(jnp.float32) - gl, p, g))

        self.states: dict[int, Any] = {
            w: jax.tree.map(jnp.copy, self._template)
            for w in sorted(cluster.active)}
        self.server = GlobalServer(
            jax.tree.map(lambda x: x[0], self._template.params),
            self.layout, run_cfg.async_cfg.merge,
            n_workers=self._n_workers0)
        self.locals: dict[int, LocalServer] = {}
        self._bases: dict[int, PyTree] = {}
        self._deltas: dict[tuple[int, int], PyTree] = {}
        self._refs: dict[tuple[int, int], int] = {}
        self.cursor = 0
        self.total_periods = 0
        self.history: list[dict] = []
        self.trace = None
        self._pending_metrics: list[tuple] = []

    # ------------------------------------------------------------- schedule
    def _schedule(self, periods: int):
        """Regenerate the full deterministic timeline for ``periods``."""
        cluster = self.scenario.build(self.H)
        ex = AsyncSimExecutor(self.profile, self.plan, cluster,
                              cfg=self.run_cfg.async_cfg)
        trace = ex.run(periods)
        return ex.ops, trace

    # ------------------------------------------------------------------ run
    def run(self, periods: int):
        """Execute the timeline for ``periods`` nominal periods per worker.

        ``periods`` is absolute, not incremental: the op log is a
        deterministic function of (scenario seed, total periods), and the
        work-conserving quota means a *longer* run is not a superset of a
        shorter one — so a runner executes exactly one timeline.  Calling
        ``run`` again with the same total is how a restored runner
        resumes: the already-executed prefix is skipped via the cursor.
        """
        if self.total_periods and periods != self.total_periods:
            raise ValueError(
                f"this runner's timeline was scheduled for "
                f"{self.total_periods} periods; op-log replay cannot "
                f"extend it to {periods} (build a new runner)")
        self.total_periods = periods
        ops, trace = self._schedule(self.total_periods)
        if self.cursor > len(ops):
            raise RuntimeError(
                f"op cursor {self.cursor} beyond regenerated log "
                f"({len(ops)} ops) — scenario/seed mismatch on resume?")
        for op in ops[self.cursor:]:
            if isinstance(op, MergeOp):
                for key in op.contributors:
                    k = (key[0], key[1])
                    self._refs[k] = self._refs.get(k, 0) + 1
        self._run_ops(ops)
        self.trace = trace
        self._drain_metrics()
        if self.ckpt is not None:
            self.ckpt.wait()
        return trace

    @hot_path
    def _run_ops(self, ops) -> None:
        every = self.run_cfg.ckpt_every_merges
        for i in range(self.cursor, len(ops)):
            op = ops[i]
            self._apply_op(op)
            self.cursor = i + 1
            if (self.ckpt is not None and every > 0
                    and isinstance(op, MergeOp)
                    and op.version % every == 0):
                self.save()

    @hot_path
    def _apply_op(self, op) -> None:
        if isinstance(op, PullOp):
            if self.server.version != op.version:
                raise AssertionError(
                    f"pull at version {op.version} but server is at "
                    f"{self.server.version}")
            st = self.states[op.worker]
            self._bases[op.worker] = self.server.params
            self.states[op.worker] = st._replace(
                params=self._pull_fn(self.server.params, st.params))
        elif isinstance(op, PeriodOp):
            batch = self._period_batch(op.worker, op.iter0)
            st, metrics = self._period_fn(self.states[op.worker], batch)
            self.states[op.worker] = st
            delta = self._delta_fn(st.params, self._bases.pop(op.worker))
            key = (op.worker, op.period)
            if self._refs.get(key, 0) > 0:
                self._deltas[key] = delta
            self._pending_metrics.append(
                (op.worker, op.period, op.iter0, op.t0, op.t1, metrics))
        elif isinstance(op, PushOp):
            srv = self.locals.setdefault(op.dc, LocalServer(op.dc))
            srv.push(self._deltas[(op.worker, op.period)], op.units,
                     op.base_version, worker=op.worker, period=op.period,
                     phase=op.phase)
        elif isinstance(op, MergeOp):
            entries = self.locals[op.dc].take(op.contributors)
            delta, units, base = LocalServer.merged_delta(entries)
            if units != op.units:
                raise AssertionError(
                    f"merge units {units} != executor's {op.units}")
            tau = self.server.merge(delta, base, units)
            if tau != op.staleness or self.server.version != op.version:
                raise AssertionError(
                    f"merge (version {self.server.version}, staleness "
                    f"{tau}) disagrees with executor op {op}")
            for key in op.contributors:
                k = (key[0], key[1])
                self._refs[k] -= 1
                if self._refs[k] == 0:
                    del self._refs[k]
                    self._deltas.pop(k, None)
        elif isinstance(op, JoinOp):
            st = jax.tree.map(jnp.copy, self._template)
            self.states[op.worker] = st._replace(
                params=self._pull_fn(self.server.params, st.params))
        elif isinstance(op, LeaveOp):
            self.states.pop(op.worker, None)
            self._bases.pop(op.worker, None)
        else:
            raise TypeError(f"unknown op {op!r}")

    @hot_path
    def _period_batch(self, worker: int, iter0: int) -> PyTree:
        w = worker % self.data.n_workers
        per_step = [jax.tree.map(lambda x: x[w][None],
                                 self.data.batch(iter0 + h))
                    for h in range(self.H)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per_step)

    @hot_path
    def _drain_metrics(self) -> None:
        """One batched host sync for everything accumulated this run."""
        if not self._pending_metrics:
            return
        host = jax.device_get([m[-1] for m in self._pending_metrics])
        for (w, p, it0, t0, t1, _), metrics in zip(self._pending_metrics,
                                                   host):
            loss = metrics.get("loss")
            self.history.append({
                "worker": w, "period": p, "step": it0,
                "t_start": t0, "t_end": t1, "time": t1 - t0,
                "loss": float(loss.mean()) if loss is not None else None,
            })
        self._pending_metrics = []

    # ------------------------------------------------------------ stacking
    def stacked_params(self, n_workers: int | None = None) -> PyTree:
        """Global model broadcast to a worker-stacked ``[W, ...]`` view
        (what ``Session.state`` / ``serve()`` consume)."""
        w = self._n_workers0 if n_workers is None else n_workers
        dtype_src = self._template.params
        return jax.tree.map(
            lambda g, p: jnp.broadcast_to(g.astype(p.dtype),
                                          (w,) + g.shape),
            self.server.params, dtype_src)

    # ---------------------------------------------------------- checkpoint
    def save(self) -> None:
        """Checkpoint at the current (merge-boundary) op cursor."""
        if self.ckpt is None:
            raise ValueError("runner built without a CheckpointManager")
        self._drain_metrics()
        payload = {
            "workers": {str(w): self.states[w]
                        for w in sorted(self.states)},
            "server": self.server.state(),
            "pending": {f"{w}:{p}": self._deltas[(w, p)]
                        for (w, p) in sorted(self._deltas)},
            "bases": {str(w): self._bases[w]
                      for w in sorted(self._bases)},
        }
        meta = {
            "mode": "hier-async",
            "cursor": self.cursor,
            "total_periods": self.total_periods,
            "workers": sorted(self.states),
            "pending": sorted(f"{w}:{p}" for (w, p) in self._deltas),
            "bases": sorted(self._bases),
            "refs": {f"{w}:{p}": n
                     for (w, p), n in sorted(self._refs.items())},
            "locals": {str(dc): self.locals[dc].describe()
                       for dc in sorted(self.locals)},
            "server": self.server.meta(),
            "plan_fingerprint": self.plan.fingerprint(),
            "seed": self.seed,
        }
        self.ckpt.save(self.server.version, payload, meta=meta)

    def restore(self, step: int | None = None) -> int:
        """Resume from a checkpoint; returns the restored global version.

        The op log is regenerated from the scenario seed on the next
        :meth:`run`, so the continuation replays the exact timeline the
        interrupted run would have produced.
        """
        if self.ckpt is None:
            raise ValueError("runner built without a CheckpointManager")
        meta = self.ckpt.peek_meta(step)
        if meta.get("plan_fingerprint") != self.plan.fingerprint():
            raise ValueError("checkpoint was written under a different "
                             "plan; cannot replay its op log")
        zero_delta = jax.tree.map(
            lambda x: jnp.zeros(x.shape[1:], jnp.float32),
            self._template.params)
        template = {
            "workers": {str(w): jax.tree.map(jnp.copy, self._template)
                        for w in meta["workers"]},
            "server": self.server.state(),
            "pending": {k: zero_delta for k in meta["pending"]},
            "bases": {str(w): zero_delta for w in meta["bases"]},
        }
        _, payload, meta = self.ckpt.restore(template, step=step)
        self.states = {int(w): st
                       for w, st in payload["workers"].items()}
        self.server.load(payload["server"], meta["server"])
        self._deltas = {}
        for k, delta in payload["pending"].items():
            w, p = k.split(":")
            self._deltas[(int(w), int(p))] = jax.tree.map(
                jnp.asarray, delta)
        self._refs = {}
        for k, n in meta["refs"].items():
            w, p = k.split(":")
            self._refs[(int(w), int(p))] = int(n)
        self.locals = {}
        for dc, entries in meta["locals"].items():
            srv = LocalServer(int(dc))
            for e in entries:
                srv.push(self._deltas[(e["worker"], e["period"])],
                         tuple(e["units"]), e["base_version"],
                         worker=e["worker"], period=e["period"],
                         phase=e["phase"])
            self.locals[int(dc)] = srv
        self._bases = {int(w): jax.tree.map(jnp.asarray, b)
                       for w, b in payload["bases"].items()}
        self._pending_metrics = []
        self.cursor = int(meta["cursor"])
        self.total_periods = int(meta["total_periods"])
        return self.server.version
