"""Asynchronous two-tier (hierarchical) execution runtime.

Workers run DreamDDP partial-sync periods locally and push layer-wise
deltas to a local-server tier that merges into a global model with
staleness-aware momentum — no period-boundary barrier.  Timing is
decided by a deterministic event executor on seeded virtual clocks
(:class:`AsyncSimExecutor`); the training math replays its op log
(:class:`AsyncHierRunner`).  See ``DESIGN.md`` in this package.
"""

from .conformance import (AsyncConformanceReport, check_async_library,
                          check_async_scenario, reference_async_spans)
from .executor import (AsyncConfig, AsyncSimExecutor, JoinOp, LeaveOp,
                       MergeOp, PeriodOp, PullOp, PushOp)
from .merge import MERGE_RULES, MergeConfig, staleness_scale
from .runner import AsyncHierRunner, AsyncRunnerConfig
from .servers import GlobalServer, LocalServer, PushEntry

__all__ = [
    "AsyncConfig", "AsyncSimExecutor",
    "PullOp", "PeriodOp", "PushOp", "MergeOp", "JoinOp", "LeaveOp",
    "MERGE_RULES", "MergeConfig", "staleness_scale",
    "GlobalServer", "LocalServer", "PushEntry",
    "AsyncHierRunner", "AsyncRunnerConfig",
    "AsyncConformanceReport", "check_async_scenario",
    "check_async_library", "reference_async_spans",
]
