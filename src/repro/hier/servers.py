"""LocalServer / GlobalServer — the two-tier async state machine.

The :class:`GlobalServer` holds the float32 reference model (unstacked:
no worker axis, layer-stacked groups keep their layer axis at position
0) plus the merge rule's auxiliary state (momentum; delta buffer for
delayed-Nesterov) and a monotonically increasing ``version`` counter —
one increment per merge.  Staleness of a delta is
``version_at_merge - version_at_pull``.

A :class:`LocalServer` fronts one datacenter: workers push per-phase
layer-group deltas to it without blocking, it accumulates them, and
every ``pushes_per_merge`` arrivals it forwards the batch (averaged at
merge time) upstream.  With the default of 1 it is a pass-through tier;
with more it trades staleness for fewer inter-DC transfers.

Both tiers are driven strictly by the deterministic op log of
:class:`repro.hier.executor.AsyncSimExecutor` — they never consult a
wall clock or ambient randomness, which is what makes checkpoint/restart
replay to an identical trace (see ``DESIGN.md``).
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from ..core.partial_sync import UnitLayout
from ..core.sync_policies import tree_unit_map
from ..lint import hot_path
from .merge import MergeConfig, staleness_scale

__all__ = ["GlobalServer", "LocalServer", "PushEntry"]

PyTree = Any


class GlobalServer:
    """Global tier: staleness-aware merges into the reference model."""

    def __init__(self, params: PyTree, layout: UnitLayout,
                 cfg: MergeConfig, *, n_workers: int):
        self.cfg = cfg.resolve(n_workers)
        self.layout = layout
        self.params = jax.tree.map(
            lambda x: jnp.asarray(x, jnp.float32), params)
        self.momentum = jax.tree.map(jnp.zeros_like, self.params)
        self.buffer = jax.tree.map(jnp.zeros_like, self.params)
        self.version = 0
        self.dn_count = 0
        self.staleness_hist: dict[int, int] = {}
        self._merge_cache: dict[tuple[int, ...], Any] = {}
        self._flush = None

    # -------------------------------------------------------------- merges
    @hot_path
    def merge(self, delta: PyTree, base_version: int,
              unit_ids: Sequence[int]) -> int:
        """Fold one (averaged) delta into the model; returns its staleness.

        ``delta`` is unstacked float32 (same structure as ``params``);
        only the slices belonging to ``unit_ids`` are touched.
        """
        tau = max(0, self.version - base_version)
        scale = jnp.float32(staleness_scale(self.cfg, tau))
        fn = self._merge_fn(tuple(unit_ids))
        if self.cfg.rule == "halos":
            self.params, self.momentum = fn(
                self.params, self.momentum, delta, scale)
        else:
            self.params, self.buffer = fn(
                self.params, self.buffer, delta, scale)
            self.dn_count += 1
            if self.dn_count >= self.cfg.dn_delay:
                self.params, self.momentum, self.buffer = self._flush_fn()(
                    self.params, self.momentum, self.buffer)
                self.dn_count = 0
        self.version += 1
        self.staleness_hist[tau] = self.staleness_hist.get(tau, 0) + 1
        return tau

    def _merge_fn(self, units: tuple[int, ...]):
        fn = self._merge_cache.get(units)
        if fn is not None:
            return fn
        cfg, layout = self.cfg, self.layout
        if cfg.rule == "halos":
            def apply(params, momentum, delta, scale):
                def step(w, m, d):
                    ds = d * scale
                    m2 = cfg.momentum * m + ds
                    upd = ds + cfg.momentum * m2 if cfg.nesterov else m2
                    return w + cfg.lr * upd, m2, d
                p2, m2, _ = tree_unit_map(
                    step, (params, momentum, delta), units, layout)
                return p2, m2
        else:
            def apply(params, buffer, delta, scale):
                def step(w, b, d):
                    ds = d * scale
                    return w + cfg.lr * ds, b + ds, d
                p2, b2, _ = tree_unit_map(
                    step, (params, buffer, delta), units, layout)
                return p2, b2
        fn = jax.jit(apply)
        self._merge_cache[units] = fn
        return fn

    def _flush_fn(self):
        if self._flush is None:
            cfg = self.cfg

            def flush(params, momentum, buffer):
                def one(m, b):
                    return cfg.momentum * m + b / cfg.dn_delay
                m2 = jax.tree.map(one, momentum, buffer)
                p2 = jax.tree.map(
                    lambda w, m: w + cfg.lr * cfg.momentum * m, params, m2)
                b2 = jax.tree.map(jnp.zeros_like, buffer)
                return p2, m2, b2

            self._flush = jax.jit(flush)
        return self._flush

    # --------------------------------------------------------------- state
    def snapshot(self) -> tuple[PyTree, int]:
        """Current ``(params, version)`` — what a pulling worker sees.

        The returned tree is never mutated in place (merges replace it
        functionally), so callers may hold it as a delta base.
        """
        return self.params, self.version

    def state(self) -> dict:
        """Array state for checkpointing (scalars live in :meth:`meta`)."""
        return {"params": self.params, "momentum": self.momentum,
                "buffer": self.buffer}

    def meta(self) -> dict:
        return {"version": self.version, "dn_count": self.dn_count,
                "staleness_hist": {str(k): v for k, v in
                                   sorted(self.staleness_hist.items())}}

    def load(self, state: dict, meta: dict) -> None:
        as32 = lambda t: jax.tree.map(
            lambda x: jnp.asarray(x, jnp.float32), t)
        self.params = as32(state["params"])
        self.momentum = as32(state["momentum"])
        self.buffer = as32(state["buffer"])
        self.version = int(meta["version"])
        self.dn_count = int(meta["dn_count"])
        self.staleness_hist = {int(k): int(v) for k, v in
                               meta["staleness_hist"].items()}


class PushEntry:
    """One worker push waiting (or in flight) at a local server."""

    __slots__ = ("worker", "period", "phase", "units", "base_version",
                 "delta")

    def __init__(self, worker, period, phase, units, base_version, delta):
        self.worker = worker
        self.period = period
        self.phase = phase
        self.units = tuple(sorted(units))
        self.base_version = base_version
        self.delta = delta

    @property
    def key(self) -> tuple[int, int, int]:
        return (self.worker, self.period, self.phase)

    def describe(self) -> dict:
        return {"worker": self.worker, "period": self.period,
                "phase": self.phase, "units": list(self.units),
                "base_version": self.base_version}


class LocalServer:
    """Local tier: per-datacenter accumulation of worker pushes."""

    def __init__(self, dc: int):
        self.dc = dc
        self.entries: list[PushEntry] = []

    def push(self, delta: PyTree, units: Sequence[int], base_version: int,
             *, worker: int, period: int, phase: int) -> None:
        self.entries.append(PushEntry(worker, period, phase, units,
                                      base_version, delta))

    def take(self, contributors: Sequence[tuple[int, int, int]]
             ) -> list[PushEntry]:
        """Pop the entries named by the executor's merge op, in op order."""
        want = list(contributors)
        by_key = {e.key: e for e in self.entries}
        missing = [k for k in want if tuple(k) not in by_key]
        if missing:
            raise KeyError(f"local server {self.dc} missing pushes "
                           f"{missing}")
        taken = [by_key[tuple(k)] for k in want]
        drop = {tuple(k) for k in want}
        self.entries = [e for e in self.entries if e.key not in drop]
        return taken

    @staticmethod
    def merged_delta(entries: Sequence[PushEntry]
                     ) -> tuple[PyTree, tuple[int, ...], int]:
        """Average a flush batch: ``(delta, union units, min base)``."""
        deltas = [e.delta for e in entries]
        if len(deltas) == 1:
            avg = deltas[0]
        else:
            inv = 1.0 / len(deltas)
            avg = jax.tree.map(lambda *xs: sum(xs[1:], xs[0]) * inv, *deltas)
        units: set[int] = set()
        for e in entries:
            units.update(e.units)
        base = min(e.base_version for e in entries)
        return avg, tuple(sorted(units)), base

    def describe(self) -> list[dict]:
        return [e.describe() for e in self.entries]
