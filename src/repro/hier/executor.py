"""AsyncSimExecutor — deterministic event replay of the async runtime.

Each worker loops ``pull -> compute one H-step period locally -> push
per-phase layer-group deltas`` on its *own* virtual clock; nothing ever
blocks at a period boundary.  The executor is a discrete-event machine
over one heap whose ordering key is ``(time, kind-rank, actor, seq)`` —
all four components are deterministic functions of the scenario seed, so
two runs produce byte-identical :class:`~repro.sim.trace.Trace`\\ s and
identical op logs (the determinism contract checkpoint/restart relies
on, see ``DESIGN.md``).

Work is assigned greedily ("work-conserving"): the run targets
``periods * n_initial_workers`` worker-periods in total and each worker
claims the next one the moment it finishes its last.  Under a straggler
the fast workers absorb the slow worker's deficit instead of blocking on
it — that, plus replacing per-phase ring collectives with one
point-to-point pull per period that is *double-buffered* (the next
period's pull is initiated at compute start and hides under the compute;
pushes leave the critical path entirely), is where the async makespan
win over the synchronous executor comes from at equal sample budget.
The prefetched base is read one merge window earlier, which the
staleness-aware merge scale absorbs (``merge.py``).

Scenario events reuse :class:`~repro.sim.events.VirtualCluster` replay:
an event fires when the *minimum* local iteration across active workers
crosses its fire iteration (the synchronous executor's shared iteration
counter degenerates to exactly this).  Straggler slowdowns are read per
worker (:meth:`~repro.sim.events.VirtualCluster.worker_slowdown`);
transient-failure downtime is charged only to the failed worker.

The op log (:class:`PullOp` / :class:`PeriodOp` / :class:`PushOp` /
:class:`MergeOp` / :class:`JoinOp` / :class:`LeaveOp`) totally orders
every state transition of the server tier; the real runner
(:mod:`repro.hier.runner`) replays it to execute the actual training
math in the simulated arrival order.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field

from ..core.plans import SyncPlan
from ..core.profiler import LayerProfile
from ..sim.events import TransientFailure, VirtualCluster
from ..sim.trace import Interval, Trace
from .merge import MergeConfig, staleness_scale

__all__ = ["AsyncConfig", "AsyncSimExecutor", "PullOp", "PeriodOp",
           "PushOp", "MergeOp", "JoinOp", "LeaveOp"]

# heap ranks: merges land before push arrivals, pushes before pull
# initiations, pulls before period starts at the same instant — so a
# pull always reads the newest version whose time has come, and a
# period start always sees its worker's prefetched pull
_RANK_FLUSH, _RANK_PUSH, _RANK_PULL, _RANK_START = 0, 1, 2, 3


@dataclass(frozen=True)
class AsyncConfig:
    """Knobs of the async tier (the merge math lives in MergeConfig)."""

    pushes_per_merge: int = 1      # local-server flush threshold
    merge: MergeConfig = field(default_factory=MergeConfig)


# ------------------------------------------------------------- op log types
@dataclass(frozen=True)
class PullOp:
    """Worker downloaded the global model (version read at pull start)."""
    t: float
    worker: int
    period: int
    version: int


@dataclass(frozen=True)
class PeriodOp:
    """Worker ran H local steps; ``iter0`` is its first local iteration."""
    t0: float
    t1: float
    worker: int
    period: int
    iter0: int


@dataclass(frozen=True)
class PushOp:
    """One per-phase layer-group delta arrived at datacenter ``dc``."""
    t: float
    worker: int
    period: int
    phase: int
    units: tuple[int, ...]
    base_version: int
    dc: int


@dataclass(frozen=True)
class MergeOp:
    """Local server ``dc`` flushed into the global model.

    ``version`` is the global version *after* the merge; ``staleness``
    is ``version_before - min(contributor base versions)``.
    """
    t: float
    dc: int
    version: int
    staleness: int
    units: tuple[int, ...]
    contributors: tuple[tuple[int, int, int], ...]   # (worker, period, phase)


@dataclass(frozen=True)
class JoinOp:
    t: float
    worker: int


@dataclass(frozen=True)
class LeaveOp:
    t: float
    worker: int


class AsyncSimExecutor:
    """Deterministic async two-tier replay of one plan (module docstring)."""

    def __init__(self, profile: LayerProfile, plan: SyncPlan,
                 cluster: VirtualCluster, *, cfg: AsyncConfig | None = None):
        if plan.n_units != len(profile):
            raise ValueError(
                f"plan has {plan.n_units} units but profile has "
                f"{len(profile)} layers")
        self.profile = profile
        self.plan = plan
        self.cluster = cluster
        self.cfg = cfg or AsyncConfig()
        self.merge_cfg = self.cfg.merge.resolve(cluster.n_active)
        layers = profile.layers
        self._pull_bytes = sum(layers[u].param_bytes
                               for u in plan.all_sync_units())
        self._push_groups = [
            (h, units, sum(layers[u].param_bytes for u in units))
            for h, units in enumerate(plan.phase_units) if units]
        self._compute_base = plan.H * (profile.t_fp_total
                                       + profile.t_bp_total)
        self.ops: list = []
        self.trace: Trace | None = None

    # ----------------------------------------------------------- plumbing
    def _p2p(self, link: str, nbytes: float, start: float) -> float:
        """One point-to-point transfer (pull / push / flush) duration."""
        net = self.cluster.network
        spec = net.link_spec(link)
        dur = net.transfer_time(link, nbytes, start) + spec.latency
        if spec.jitter > 0:
            dur *= 1.0 + spec.jitter * (2.0 * self.cluster.rng.random()
                                        - 1.0)
        return dur

    def _schedule(self, t: float, rank: int, actor: int, payload) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, rank, actor, self._seq, payload))

    # ---------------------------------------------------------------- run
    def run(self, periods: int = 1) -> Trace:
        """Replay until ``periods * n_initial_workers`` worker-periods
        have been claimed and every in-flight push has merged."""
        cl = self.cluster
        self._heap = []
        self._seq = 0
        self.ops = []
        self._version = 0
        self._local: dict[int, list] = {}     # dc -> pending push records
        self._stall_credit: dict[int, float] = {}
        self._pull_ready: dict[int, tuple[float, int]] = {}
        self._iters: dict[int, int] = {w: 0 for w in sorted(cl.active)}
        self._periods_done: dict[int, int] = {w: 0 for w in sorted(cl.active)}
        self._known: set[int] = set(cl.active)
        self._left: set[int] = set()
        self._started = 0
        self._target = periods * cl.n_active
        self.staleness_hist: dict[int, int] = {}
        self._merges = 0
        self._final_merge_t = 0.0
        tr = Trace(H=self.plan.H)
        self._tr = tr
        self._spans: list[tuple[float, float]] = []
        self._log_mark = len(cl.log)

        for w in sorted(cl.active):
            self._schedule(0.0, _RANK_START, w, ("start", w))
        while self._heap:
            t, rank, actor, _, payload = heapq.heappop(self._heap)
            if payload[0] == "start":
                self._period_start(t, payload[1])
            elif payload[0] == "push":
                self._push_arrival(t, *payload[1:])
            elif payload[0] == "pull":
                self._pull_start(t, payload[1], payload[2])
            else:
                self._do_merge(t, payload[1], payload[2])

        tr.events.extend(cl.log[self._log_mark:])
        # spans sorted by completion so Trace.makespan (last end) holds
        tr.iteration_spans = sorted(self._spans, key=lambda s: (s[1], s[0]))
        tr.meta.update({
            "mode": "async",
            "n_units": self.plan.n_units,
            "n_workers": len(self._known),
            "n_datacenters": cl.network.topology.n_datacenters,
            "target_periods": self._target,
            "worker_periods": {str(w): self._periods_done[w]
                               for w in sorted(self._periods_done)},
            "merges": self._merges,
            "final_merge_time": self._final_merge_t,
            "merge_rule": self.merge_cfg.rule,
            "pushes_per_merge": self.cfg.pushes_per_merge,
            "staleness_hist": {str(k): v for k, v in
                               sorted(self.staleness_hist.items())},
            "staleness_scale_min": (
                staleness_scale(self.merge_cfg,
                                max(self.staleness_hist, default=0))),
        })
        self.trace = tr
        return tr

    # -------------------------------------------------------------- events
    def _period_start(self, t: float, w: int) -> None:
        cl = self.cluster
        if w not in cl.active:
            return                                 # left while queued
        min_iter = min(self._iters.values()) if self._iters else 0
        fired = cl.advance(min_iter, t)
        cl.take_stall()        # async never stalls the whole cluster
        for ev in fired:
            if isinstance(ev, TransientFailure) and ev.worker in cl.active:
                self._stall_credit[ev.worker] = (
                    self._stall_credit.get(ev.worker, 0.0) + ev.downtime)
        self._membership_diff(t)
        if w not in cl.active:
            return                                 # this very event left
        if self._started >= self._target:
            return                                 # quota exhausted
        self._started += 1
        p = self._periods_done[w]
        it0 = self._iters[w]
        ready = self._pull_ready.pop(w, None)
        stall = self._stall_credit.pop(w, 0.0)
        if ready is None:
            # cold pull (first period, or first after a join): nothing to
            # overlap it with, so it sits on the critical path
            version = self._version
            self.ops.append(PullOp(t, w, p, version))
            dur = self._p2p("intra", self._pull_bytes, t)
            self._tr.intervals.append(
                Interval("pull", it0, -1, -1, t, t + dur, worker=w))
            t0 = t + dur + stall
            stall_at = t + dur
        else:
            # warm pull: prefetched during the previous period's compute
            # (double buffering); version was read at pull initiation
            version = ready[1]
            t0 = max(t + stall, ready[0])
            stall_at = t
        if stall > 0.0:
            self._tr.intervals.append(
                Interval("stall", it0, -1, -1, stall_at, stall_at + stall,
                         worker=w))
        comp = self._compute_base * cl.worker_slowdown(w)
        t1 = t0 + comp
        self._tr.intervals.append(
            Interval("compute", it0, -1, -1, t0, t1, worker=w))
        self.ops.append(PeriodOp(t0, t1, w, p, it0))
        self._spans.append((t, t1))
        # prefetch the next period's pull under this period's compute
        # (a separate event so the version is read at initiation time);
        # speculative — harmless if this worker never claims another
        # period (the runner just installs the pulled model)
        if self._started < self._target:
            self._schedule(t0, _RANK_PULL, w, ("pull", w, p + 1))
        dc = cl.network.topology.dc_of(w)
        pt = t1
        for h, units, nbytes in self._push_groups:
            arr = pt + self._p2p("intra", nbytes, pt)
            self._tr.intervals.append(
                Interval("push", it0, h, -1, pt, arr, worker=w))
            self._schedule(arr, _RANK_PUSH, w,
                           ("push", w, p, h, units, version, dc))
            pt = arr
        self._iters[w] = it0 + self.plan.H
        self._periods_done[w] = p + 1
        self._schedule(t1, _RANK_START, w, ("start", w))

    def _pull_start(self, t: float, w: int, p: int) -> None:
        """Prefetched pull initiation: read the global version *now*."""
        if w not in self.cluster.active:
            return
        version = self._version
        self.ops.append(PullOp(t, w, p, version))
        dur = self._p2p("intra", self._pull_bytes, t)
        self._tr.intervals.append(
            Interval("pull", self._iters.get(w, 0), -1, -1, t, t + dur,
                     worker=w))
        self._pull_ready[w] = (t + dur, version)

    def _membership_diff(self, t: float) -> None:
        cl = self.cluster
        active = set(cl.active)
        for w in sorted(active - self._known):
            self._known.add(w)
            self._iters[w] = 0
            self._periods_done[w] = 0
            self.ops.append(JoinOp(t, w))
            self._schedule(t, _RANK_START, w, ("start", w))
        for w in sorted(self._known - active - self._left):
            self._left.add(w)
            self._iters.pop(w, None)     # excluded from min-iteration
            self._pull_ready.pop(w, None)
            self.ops.append(LeaveOp(t, w))

    def _push_arrival(self, t: float, w: int, p: int, h: int,
                      units: tuple[int, ...], base_version: int,
                      dc: int) -> None:
        self.ops.append(PushOp(t, w, p, h, units, base_version, dc))
        buf = self._local.setdefault(dc, [])
        buf.append((w, p, h, units, base_version))
        if len(buf) < self.cfg.pushes_per_merge:
            return
        entries, self._local[dc] = list(buf), []
        net = self.cluster.network
        if net.topology.n_datacenters > 1:
            flush_units: set[int] = set()
            for e in entries:
                flush_units.update(e[3])
            nbytes = sum(self.profile.layers[u].param_bytes
                         for u in sorted(flush_units))
            dur = self._p2p("inter", nbytes, t)
            self._tr.intervals.append(
                Interval("flush", -1, -1, -1, t, t + dur, worker=dc))
            self._schedule(t + dur, _RANK_FLUSH, dc,
                           ("flush", dc, entries))
        else:
            self._do_merge(t, dc, entries)

    def _do_merge(self, t: float, dc: int, entries: list) -> None:
        base = min(e[4] for e in entries)
        tau = max(0, self._version - base)
        units: set[int] = set()
        for e in entries:
            units.update(e[3])
        self._version += 1
        self._merges += 1
        self._final_merge_t = max(self._final_merge_t, t)
        self.staleness_hist[tau] = self.staleness_hist.get(tau, 0) + 1
        self._tr.intervals.append(
            Interval("merge", -1, -1, -1, t, t, worker=dc))
        self.ops.append(MergeOp(
            t, dc, self._version, tau, tuple(sorted(units)),
            tuple((e[0], e[1], e[2]) for e in entries)))
