"""Async-conformance checking: AsyncSimExecutor vs a heap-free reference.

The async time model is simple enough to state in closed form — workers
never block on each other, so each worker-period computes for

    H * (t_fp_total + t_bp_total) * slowdown_w

starting at ``max(claim + stall_w, pull_ready_w)``, where the pull is
*double-buffered*: a worker's first pull (cold, after start or join)
sits on its critical path, and every later pull was initiated at the
previous period's compute start and usually hides under it.  The
makespan is the max worker clock over a *greedy* assignment of the
``periods * n_initial_workers`` worker-period quota (next free worker,
ties by id).  :func:`reference_async_spans` re-derives every
worker-period span with a direct argmin loop — no event heap, no push
or merge machinery — against a replica
:class:`~repro.sim.events.VirtualCluster` for scenario-event state, the
same replica-replay idiom :func:`repro.sim.conformance.check_scenario`
uses for the synchronous executor.  :func:`check_async_scenario` then
pins the executor's trace to that reference span-by-span.

Because the reference shares none of the executor's queue/arrival
bookkeeping, agreement (to float round-off; ``rtol`` = 1e-6 like the
sync layer) validates the heap ordering, quota accounting, membership
diffing and per-worker stall attribution all at once.  Jittered
scenarios are rejected, exactly as in the sync layer: their timing is
seeded noise by construction.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from ..core.plans import SyncPlan
from ..core.profiler import LayerProfile
from ..sim.conformance import DEFAULT_RTOL, WindowCheck, synthetic_profile
from ..sim.events import TransientFailure
from ..sim.executor import prepare_run
from ..sim.trace import Trace
from .executor import AsyncConfig, AsyncSimExecutor

__all__ = ["AsyncConformanceReport", "reference_async_spans",
           "check_async_scenario", "check_async_library"]


@dataclass
class AsyncConformanceReport:
    scenario: str
    algo: str
    H: int
    checks: list[WindowCheck] = field(default_factory=list)
    trace: Trace | None = None

    @property
    def ok(self) -> bool:
        return bool(self.checks) and all(c.ok for c in self.checks)

    @property
    def max_rel_err(self) -> float:
        return max((c.rel_err for c in self.checks), default=float("nan"))

    def summary(self) -> str:
        status = "OK" if self.ok else "FAIL"
        return (f"{self.scenario:<20} {self.algo:<12} H={self.H} "
                f"spans={len(self.checks)} "
                f"max_rel_err={self.max_rel_err:.2e} {status}")


def reference_async_spans(scenario, plan: SyncPlan, profile: LayerProfile,
                          periods: int) -> list[tuple[float, float]]:
    """Heap-free greedy reference for the async worker-period spans."""
    cl = scenario.build(plan.H)
    net = cl.network
    lat = net.link_spec("intra").latency
    layers = profile.layers
    pull_bytes = sum(layers[u].param_bytes for u in plan.all_sync_units())
    compute_base = plan.H * (profile.t_fp_total + profile.t_bp_total)

    pending = {w: 0.0 for w in sorted(cl.active)}
    iters = {w: 0 for w in sorted(cl.active)}
    known, left = set(cl.active), set()
    credits: dict[int, float] = {}
    ready: dict[int, float] = {}       # prefetched-pull completion times
    target = periods * cl.n_active
    started = 0
    spans: list[tuple[float, float]] = []

    def pull(at: float) -> float:
        return net.transfer_time("intra", pull_bytes, at) + lat

    while pending and started < target:
        w = min(sorted(pending), key=lambda a: (pending[a], a))
        t = pending.pop(w)
        if w not in cl.active:
            continue
        min_iter = min(iters.values()) if iters else 0
        fired = cl.advance(min_iter, t)
        cl.take_stall()
        for ev in fired:
            if isinstance(ev, TransientFailure) and ev.worker in cl.active:
                credits[ev.worker] = (credits.get(ev.worker, 0.0)
                                      + ev.downtime)
        active = set(cl.active)
        for w2 in sorted(active - known):
            known.add(w2)
            iters[w2] = 0
            pending[w2] = t
        for w2 in sorted(known - active - left):
            left.add(w2)
            iters.pop(w2, None)
            pending.pop(w2, None)
            ready.pop(w2, None)
        if w not in cl.active:
            continue
        started += 1
        stall = credits.pop(w, 0.0)
        if w in ready:
            t0 = max(t + stall, ready.pop(w))     # warm (prefetched) pull
        else:
            t0 = t + pull(t) + stall              # cold pull
        t1 = t0 + compute_base * cl.worker_slowdown(w)
        ready[w] = t0 + pull(t0)                  # prefetch the next pull
        spans.append((t, t1))
        iters[w] += plan.H
        pending[w] = t1
    return sorted(spans, key=lambda s: (s[1], s[0]))


def check_async_scenario(scenario, *, algo: str = "dreamddp", H: int = 4,
                         profile: LayerProfile | None = None,
                         periods: int | None = None,
                         cfg: AsyncConfig | None = None,
                         rtol: float = DEFAULT_RTOL,
                         fill_mode: str = "exact"
                         ) -> AsyncConformanceReport:
    """Run a scenario async and pin every worker-period span."""
    from ..api.registry import get_strategy

    if any(spec.jitter > 0 for spec in
           (scenario.intra, scenario.inter) if spec is not None):
        raise ValueError(
            f"scenario {scenario.name!r} has link jitter; its timing is "
            f"seeded noise and cannot be conformance-checked")
    if profile is None:
        profile = synthetic_profile()
    periods = scenario.periods if periods is None else periods

    cluster, plan = prepare_run(scenario, get_strategy(algo), H, profile,
                                fill_mode=fill_mode)
    ex = AsyncSimExecutor(profile, plan, cluster, cfg=cfg)
    trace = ex.run(periods)

    report = AsyncConformanceReport(scenario=scenario.name, algo=algo,
                                    H=plan.H, trace=trace)
    expected = reference_async_spans(scenario, plan, profile, periods)
    simulated = trace.iteration_spans
    if len(expected) != len(simulated):
        raise AssertionError(
            f"reference produced {len(expected)} worker-periods but the "
            f"executor produced {len(simulated)}")
    for i, ((es, ee), (ss, se)) in enumerate(zip(expected, simulated)):
        report.checks.append(WindowCheck(period=i, expected=es,
                                         simulated=ss, rtol=rtol))
        report.checks.append(WindowCheck(period=i, expected=ee,
                                         simulated=se, rtol=rtol))
    return report


def check_async_library(*, algos=("dreamddp",), H: int = 4,
                        profile: LayerProfile | None = None,
                        rtol: float = DEFAULT_RTOL
                        ) -> list[AsyncConformanceReport]:
    """Async-conformance-check every jitter-free library scenario."""
    from ..sim.scenarios import available_scenarios, get_scenario

    reports = []
    for name in available_scenarios():
        sc = get_scenario(name)
        if any(spec.jitter > 0 for spec in (sc.intra, sc.inter)
               if spec is not None):
            continue
        for algo in algos:
            reports.append(check_async_scenario(sc, algo=algo, H=H,
                                                profile=profile, rtol=rtol))
    return reports
