"""DreamDDP on JAX/TPU: layer-wise scheduled partial synchronization."""

__version__ = "1.1.0"
