from .hlo import CollectiveOp, CollectiveSummary, parse_collectives
from .roofline import (RooflineTerms, V5EConstants, model_flops,
                       roofline_from_artifact)

__all__ = ["CollectiveOp", "CollectiveSummary", "parse_collectives",
           "RooflineTerms", "V5EConstants", "model_flops",
           "roofline_from_artifact"]
