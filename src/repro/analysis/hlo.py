"""Optimized-HLO collective parser.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but NOT collective
traffic, so the roofline's third term is parsed from the compiled module
text: every ``all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute`` (sync or ``-start`` async form) is collected with its
result shape, dtype and replica-group size, and converted to per-device
wire bytes with the standard ring-collective factors:

    all-reduce       2 (K-1)/K * bytes          (result == operand)
    all-gather         (K-1)/K * result_bytes   (each device receives K-1 shards)
    reduce-scatter     (K-1)/K * operand_bytes  (= (K-1) * result_bytes)
    all-to-all         (K-1)/K * bytes
    collective-permute            bytes
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

__all__ = ["CollectiveOp", "CollectiveSummary", "parse_collectives"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute")

# `%x.1 = (bf16[8,128]{1,0}, bf16[4]{0}) all-reduce-start(...)` etc.
_LINE = re.compile(
    r"=\s*(?P<result>.{1,2000}?)\s+"
    r"(?P<op>" + "|".join(_OPS) + r")(?P<async>-start)?\(")
_SHAPE = re.compile(r"(?P<dt>[a-z]\d*[a-z]*\d*)\[(?P<dims>[\d,]*)\]")
_GROUPS = re.compile(r"replica_groups=\{\{(?P<first>[\d,]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(?P<ndims>\d+),(?P<size>\d+)\]")


def _shape_bytes(result: str) -> int:
    total = 0
    for m in _SHAPE.finditer(result):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS.search(line)
    if m:
        first = m.group("first")
        return len([x for x in first.split(",") if x]) or 1
    m = _GROUPS_IOTA.search(line)              # iota format [n, size]<=[...]
    if m:
        return int(m.group("size"))
    return 1


@dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    # f32 all-reduce of bf16-dot partial sums (CPU-backend artifact; the
    # TPU backend reduces these in bf16 — see hlo_costs.parse_module_costs)
    f32_dot_partial: bool = False

    @property
    def wire_bytes(self) -> float:
        """Per-device bytes on the interconnect (ring model)."""
        k, b = max(self.group_size, 1), float(self.result_bytes)
        if self.kind == "collective-permute":
            return b            # point-to-point: no replica_groups
        if k == 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * (k - 1) / k * b
        if self.kind == "all-gather":
            return (k - 1) / k * b
        if self.kind == "reduce-scatter":
            return (k - 1) * b                  # operand = K * result
        if self.kind == "all-to-all":
            return (k - 1) / k * b
        return b                                # collective-permute


@dataclass
class CollectiveSummary:
    ops: list[CollectiveOp] = field(default_factory=list)

    @property
    def total_wire_bytes(self) -> float:
        return sum(o.wire_bytes for o in self.ops)

    @property
    def total_wire_bytes_tpu(self) -> float:
        """f32 dot-partial all-reduces counted at bf16 width (TPU dtype)."""
        return sum(o.wire_bytes * (0.5 if o.f32_dot_partial else 1.0)
                   for o in self.ops)

    def by_kind(self) -> dict[str, dict]:
        agg: dict[str, dict] = defaultdict(
            lambda: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0})
        for o in self.ops:
            a = agg[o.kind]
            a["count"] += 1
            a["result_bytes"] += o.result_bytes
            a["wire_bytes"] += o.wire_bytes
        return dict(agg)

    def to_dict(self) -> dict:
        return {"total_wire_bytes": self.total_wire_bytes,
                "total_wire_bytes_tpu": self.total_wire_bytes_tpu,
                "by_kind": self.by_kind(), "n_ops": len(self.ops)}


def parse_collectives(hlo_text: str) -> CollectiveSummary:
    out = CollectiveSummary()
    for line in hlo_text.splitlines():
        m = _LINE.search(line)
        if not m:
            continue
        out.ops.append(CollectiveOp(
            kind=m.group("op"),
            result_bytes=_shape_bytes(m.group("result")),
            group_size=_group_size(line),
        ))
    return out
