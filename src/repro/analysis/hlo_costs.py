"""Loop-aware executed-cost analysis of optimized HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE — a
scan-over-layers transformer therefore under-reports FLOPs by ~n_layers x
n_microbatches.  This parser rebuilds true executed costs from the module
text:

* computations are parsed with their instructions (name -> result shape);
* the call graph (``body=/condition=/calls=``) is walked from ENTRY with
  per-computation execution **multipliers**, taking while trip counts from
  ``backend_config={"known_trip_count":{"n":...}}`` (emitted by XLA for
  lax.scan loops);
* FLOPs: every ``dot`` contributes ``2 * result_elems * contraction`` x
  multiplier (CPU backend keeps dots unfused, so this is exhaustive);
* bytes: every costed instruction contributes (operands + result) bytes x
  multiplier — fusions count only boundary buffers, matching HBM-traffic
  semantics;
* collectives: wire bytes per device via the ring factors of
  :mod:`repro.analysis.hlo`, x multiplier.

Everything is per-device (the module is the SPMD-partitioned program).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

from .hlo import _DTYPE_BYTES, CollectiveOp, CollectiveSummary, _group_size

__all__ = ["ModuleCosts", "parse_module_costs"]

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"([a-z]\d*[a-z]*\d*)\[([\d,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLEE = re.compile(r"(?:body|calls)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_OPCODE = re.compile(r"^(?:\(.*?\)|[a-z]\d*[a-z]*\d*\[[\d,]*\](?:\{[\d,]*\})?"
                     r"(?:\s*,?\s*)?)+\s*([a-z][\w\-]*)\(")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# Opcodes whose operand/result traffic is charged to the memory term.
# The CPU backend leaves elementwise chains unfused that the TPU backend
# fuses into neighboring ops, so charging EVERY instruction would inflate
# HBM bytes ~10-50x; this whitelist is the TPU-fusion proxy: matmuls,
# fusion boundaries, data movement and reductions are real HBM traffic,
# bare elementwise/broadcast/convert are assumed fused.
_COSTED_OPS = {"dot", "convolution", "fusion", "copy", "transpose",
               "dynamic-slice", "dynamic-update-slice", "gather",
               "scatter", "reduce", "reduce-window", "sort", "select",
               "pad", "concatenate", "slice",
               *_COLLECTIVES, *(c + "-start" for c in _COLLECTIVES)}


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = nbytes = 0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclass
class _Instr:
    name: str
    opcode: str
    result: str            # result type string
    operands: list[str]
    line: str


@dataclass
class ModuleCosts:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collectives: CollectiveSummary = field(
        default_factory=CollectiveSummary)
    n_dots: int = 0
    unknown_loops: int = 0

    def to_dict(self) -> dict:
        return {"flops": self.flops, "bytes_accessed": self.bytes_accessed,
                "n_dots": self.n_dots, "unknown_loops": self.unknown_loops,
                "collectives": self.collectives.to_dict()}


def _parse_computations(text: str):
    comps: dict[str, list[_Instr]] = {}
    entry = None
    cur: list[_Instr] | None = None
    cur_name = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip()) if line.strip().endswith("{") \
                else None
            if line.strip().endswith("{"):
                m = _COMP_HDR.match(line.strip())
            if m:
                cur_name = m.group(2)
                cur = []
                if m.group(1):
                    entry = cur_name
            continue
        if line.startswith("}") or line.strip() == "}":
            comps[cur_name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = _OPCODE.match(rhs)
        if not om:
            continue
        opcode = om.group(1)
        # operand names: %tokens between the opcode's '(' and its ')'
        seg = rhs.split(opcode + "(", 1)
        ops: list[str] = []
        if len(seg) == 2:
            depth, buf = 1, []
            for ch in seg[1]:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                buf.append(ch)
            ops = re.findall(r"%([\w.\-]+)", "".join(buf))
        result = rhs[:rhs.find(opcode + "(")].strip().rstrip(",").strip()
        cur.append(_Instr(name, opcode, result, ops, line))
    return comps, entry


def _dot_flops(instr: _Instr, shapes: dict[str, str]) -> float:
    relems, _ = _shape_elems_bytes(instr.result)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
    if not m or not instr.operands:
        return 2.0 * relems                      # degenerate
    lhs = shapes.get(instr.operands[0], "")
    sm = _SHAPE.search(lhs)
    if not sm:
        return 2.0 * relems
    dims = [int(d) for d in sm.group(2).split(",") if d]
    contract = 1
    for i in (int(x) for x in m.group(1).split(",") if x):
        if i < len(dims):
            contract *= dims[i]
    return 2.0 * relems * contract


def parse_module_costs(text: str) -> ModuleCosts:
    comps, entry = _parse_computations(text)
    out = ModuleCosts()
    if entry is None:
        return out

    # ---- execution multipliers over the call graph -------------------------
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        comp = order[i]
        i += 1
        for ins in comps.get(comp, ()):
            trips = 1.0
            tm = _TRIP.search(ins.line)
            callees = []
            if ins.opcode == "while":
                bm = _CALLEE.search(ins.line)
                cm = _COND.search(ins.line)
                if tm:
                    trips = float(tm.group(1))
                else:
                    out.unknown_loops += 1
                if bm:
                    callees.append((bm.group(1), trips))
                if cm:
                    callees.append((cm.group(1), trips + 1.0))
            elif ins.opcode in ("fusion", "call", "conditional"):
                for cm2 in _CALLEE.finditer(ins.line):
                    callees.append((cm2.group(1), 1.0))
            for callee, t in callees:
                mult[callee] += mult[comp] * t
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)

    # ---- costed instructions ------------------------------------------------
    for comp, instrs in comps.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        shapes = {i_.name: i_.result for i_ in instrs}
        for ins in instrs:
            if ins.opcode not in _COSTED_OPS and ins.opcode != "dot":
                continue
            _, rbytes = _shape_elems_bytes(ins.result)
            obytes = sum(_shape_elems_bytes(shapes.get(o, ""))[1]
                         for o in ins.operands)
            out.bytes_accessed += (rbytes + obytes) * m
            if ins.opcode == "dot":
                out.flops += _dot_flops(ins, shapes) * m
                out.n_dots += 1
            base = ins.opcode[:-6] if ins.opcode.endswith("-start") \
                else ins.opcode
            if base in _COLLECTIVES:
                # TPU-dtype note: the CPU backend computes bf16 dots in f32
                # and GSPMD reduces the partial sums BEFORE the convert, so
                # dot-partial all-reduces appear as f32 here while the TPU
                # backend (native bf16 MXU output) reduces bf16.  Flag them
                # so the roofline can report the TPU-adjusted wire bytes.
                f32_dot = ("f32[" in ins.result
                           and "dot_general" in ins.line
                           and base == "all-reduce")
                for _ in range(int(m)):
                    out.collectives.ops.append(CollectiveOp(
                        kind=base,
                        result_bytes=rbytes,
                        group_size=_group_size(ins.line),
                        f32_dot_partial=f32_dot))
    return out
