"""Three-term roofline from dry-run artifacts (TPU v5e target).

    compute    = HLO_FLOPs / (chips x 197e12 FLOP/s)
    memory     = HLO_bytes / (chips x 819e9 B/s)
    collective = wire_bytes_per_device / 5e10 B/s-per-link  (ICI ring)

``cost_analysis()`` on the SPMD-partitioned module reports *per-device*
FLOPs/bytes in current jax, so no further division by chip count is applied
— the artifact records which convention was detected (per-device if the
module was partitioned, whole-program otherwise).

MODEL_FLOPS uses the 6*N*D rule (6*N_active*D for MoE) per training step
(3x forward for fwd+bwd; serving steps use 2*N*D per generated/processed
token).  The ratio MODEL_FLOPS / HLO_FLOPs exposes remat and dispatch-
einsum overheads.
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["V5EConstants", "RooflineTerms", "roofline_from_artifact",
           "model_flops"]


@dataclass(frozen=True)
class V5EConstants:
    peak_flops: float = 197e12          # bf16 / chip
    hbm_bw: float = 819e9               # B/s / chip
    ici_bw: float = 5e10                # B/s / link
    hbm_per_chip: float = 16e9


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    useful_ratio: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic (perfect overlap): max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / bound step time — the score we hillclimb."""
        if self.step_time_s <= 0:
            return 0.0
        ideal = (self.model_flops / max(self.hlo_flops, 1.0)) \
            * self.compute_s
        return ideal / self.step_time_s

    @property
    def roofline_fraction_cc(self) -> float:
        """Compute-vs-collective fraction (memory term excluded: the
        CPU-backend byte parse is an upper bound, while FLOPs and wire
        bytes are exact — this is the primary hillclimb metric)."""
        bound = max(self.compute_s, self.collective_s)
        if bound <= 0:
            return 0.0
        return (self.model_flops / max(self.hlo_flops, 1.0)) \
            * self.compute_s / bound

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(n_params_active: float, tokens: float, *,
                training: bool) -> float:
    """6*N*D (train: fwd+bwd) or 2*N*D (serve forward) per step."""
    return (6.0 if training else 2.0) * n_params_active * tokens


def roofline_from_artifact(art: dict, *, hw: V5EConstants = V5EConstants()
                           ) -> RooflineTerms:
    """``art`` is one dry-run JSON artifact (see launch/dryrun.py)."""
    cost = art["cost_analysis"]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    per_device = art.get("cost_is_per_device", True)
    chips = art["n_devices"]
    if not per_device:
        flops /= chips
        nbytes /= chips
    coll = art["collectives"]
    wire = float(coll.get("total_wire_bytes_tpu",
                          coll["total_wire_bytes"]))
    mf = float(art["model_flops"]) / chips
    return RooflineTerms(
        compute_s=flops / hw.peak_flops,
        memory_s=nbytes / hw.hbm_bw,
        collective_s=wire / hw.ici_bw,
        model_flops=mf,
        hlo_flops=max(flops, 1.0),
        useful_ratio=mf / max(flops, 1.0),
    )
