"""Re-derive executed costs for existing dry-run artifacts from their
stored ``.hlo.gz`` modules (no recompilation).

    PYTHONPATH=src python -m repro.analysis.reanalyze [--dir artifacts/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from .hlo import parse_collectives
from .hlo_costs import parse_module_costs


def reanalyze(path: str) -> dict:
    with open(path) as f:
        art = json.load(f)
    hlo_path = path[:-5] + ".hlo.gz"
    with gzip.open(hlo_path, "rt") as f:
        hlo = f.read()
    executed = parse_module_costs(hlo)
    art["cost_analysis"] = {
        "flops": executed.flops,
        "bytes accessed": executed.bytes_accessed,
        "n_dots": executed.n_dots,
        "unknown_loops": executed.unknown_loops,
    }
    art["collectives"] = executed.collectives.to_dict()
    art["collectives_static"] = parse_collectives(hlo).to_dict()
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    return art


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args(argv)
    n = 0
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        if not os.path.exists(path[:-5] + ".hlo.gz"):
            continue
        art = reanalyze(path)
        c = art["cost_analysis"]
        print(f"{os.path.basename(path):60s} flops={c['flops']:.3e} "
              f"bytes={c['bytes accessed']:.3e} "
              f"wire={art['collectives']['total_wire_bytes']:.3e}")
        n += 1
    print(f"reanalyzed {n} artifacts")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
