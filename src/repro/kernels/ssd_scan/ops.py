from __future__ import annotations

import jax

from .kernel import ssd_chunk_fwd

__all__ = ["ssd_chunk"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.jit
def ssd_chunk(x, b, c, da):
    """Chunk-local SSD (Pallas on TPU; interpret elsewhere)."""
    return ssd_chunk_fwd(x, b, c, da, interpret=not _on_tpu())
