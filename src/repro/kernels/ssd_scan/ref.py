"""Pure-jnp oracle for the SSD chunk kernel (mirrors models.mamba2)."""

from __future__ import annotations

import jax.numpy as jnp


def ssd_chunk_ref(x, b, c, da):
    """Same contract as :func:`..kernel.ssd_chunk_fwd`."""
    xf = x.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    cum = jnp.cumsum(da.astype(jnp.float32), axis=-1)       # [B,NC,H,cs]
    seg = cum[..., :, None] - cum[..., None, :]
    cs = x.shape[3]
    tril = jnp.tril(jnp.ones((cs, cs), bool))
    L = jnp.where(tril, jnp.exp(seg), 0.0)
    y = jnp.einsum("bzhin,bzhjn,bzhij,bzhjp->bzhip", cf, bf, L, xf)
    decay = jnp.exp(cum[..., -1:] - cum)
    s = jnp.einsum("bzhjp,bzhjn,bzhj->bzhpn", xf, bf, decay)
    return y.astype(x.dtype), s
