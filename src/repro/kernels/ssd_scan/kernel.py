"""Mamba-2 SSD chunk-local core (Pallas TPU).

The quadratic intra-chunk work — ``(C B^T ∘ L) X`` plus the chunk-state
contraction — is the MXU hot spot of the SSD layer.  One grid step
processes one ``(batch, chunk, head)`` cell entirely in VMEM:

    y_diag[i] = sum_{j<=i} exp(cum_i - cum_j) * (c_i . b_j) * x_j
    state     = X^T (B * exp(cum_last - cum))          [p, n]

The O(n_chunks) inter-chunk recurrence stays in jnp (it is tiny and
sequential); ``repro.models.mamba2.ssd_chunked`` is the oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_chunk_fwd"]


def _kernel(x_ref, b_ref, c_ref, da_ref, y_ref, s_ref):
    x = x_ref[0, 0, 0].astype(jnp.float32)            # [cs, p]
    b = b_ref[0, 0, 0].astype(jnp.float32)            # [cs, n]
    c = c_ref[0, 0, 0].astype(jnp.float32)            # [cs, n]
    da = da_ref[0, 0, 0].astype(jnp.float32)          # [cs]
    cs = x.shape[0]

    cum = jnp.cumsum(da)                              # [cs]
    seg = cum[:, None] - cum[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
    L = jnp.where(tril, jnp.exp(seg), 0.0)            # [cs, cs]

    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(cb * L, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    decay = jnp.exp(cum[-1] - cum)[:, None]           # [cs, 1]
    s = jax.lax.dot_general(x, b * decay, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s_ref[0, 0, 0] = s                                 # [p, n]


def ssd_chunk_fwd(x: jax.Array, b: jax.Array, c: jax.Array,
                  da: jax.Array, *, interpret: bool = False
                  ) -> tuple[jax.Array, jax.Array]:
    """x ``[B, NC, H, cs, p]``; b/c ``[B, NC, H, cs, n]``; da ``[B, NC, H,
    cs]`` -> (y_diag ``[B, NC, H, cs, p]``, states ``[B, NC, H, p, n]``)."""
    B, NC, H, cs, p = x.shape
    n = b.shape[-1]
    grid = (B, NC, H)
    idx5 = lambda i, j, k: (i, j, k, 0, 0)
    idx4 = lambda i, j, k: (i, j, k, 0)
    y, s = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, cs, p), idx5),
            pl.BlockSpec((1, 1, 1, cs, n), idx5),
            pl.BlockSpec((1, 1, 1, cs, n), idx5),
            pl.BlockSpec((1, 1, 1, cs), idx4),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, cs, p), idx5),
            pl.BlockSpec((1, 1, 1, p, n), idx5),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, NC, H, cs, p), x.dtype),
            jax.ShapeDtypeStruct((B, NC, H, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, b, c, da)
    return y, s
