from .ops import ssd_chunk
from .ref import ssd_chunk_ref

__all__ = ["ssd_chunk", "ssd_chunk_ref"]
