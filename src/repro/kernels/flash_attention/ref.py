"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  scale: float | None = None) -> jax.Array:
    """Naive GQA attention.  q ``[b, sq, n_q, hd]``, k/v ``[b, sk, n_kv,
    hd]``."""
    b, sq, n_q, hd = q.shape
    _, sk, n_kv, _ = k.shape
    g = n_q // n_kv
    scale = (hd ** -0.5) if scale is None else scale
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqnh,bsnh->bnqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnqs,bsnh->bqnh", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
