"""Jitted public wrapper: Pallas on TPU, interpret-mode elsewhere."""

from __future__ import annotations

import functools

import jax

from .kernel import flash_attention_fwd

__all__ = ["flash_attention"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 256):
    """Blockwise causal GQA attention (forward).

    On this CPU container the kernel body executes under
    ``interpret=True`` — numerically identical, used by the test sweeps;
    on TPU the same call compiles to the Mosaic kernel.
    """
    return flash_attention_fwd(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=not _on_tpu())
