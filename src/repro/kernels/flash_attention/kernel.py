"""Blockwise causal GQA flash-attention forward (Pallas TPU).

TPU adaptation of the classic algorithm: the grid is
``(batch*q_heads, q_blocks, k_blocks)`` with the k dimension innermost —
TPU grid steps execute *sequentially*, so the online-softmax running state
(max ``m``, normalizer ``l``, accumulator ``acc``) lives in VMEM scratch
across k steps instead of CUDA-style thread-block shared memory (the
hardware-adaptation note in DESIGN.md §2).

Blocks are VMEM tiles: q ``[block_q, head_dim]``, k/v
``[block_k, head_dim]`` — block sizes default to 128/256, multiples of the
MXU's 128 lanes.  GQA is handled in the kv index map (query head ``h``
reads kv head ``h // group``), so no repeated-KV materialization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_fwd"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_q: int, block_k: int, seq_k: int,
            causal: bool):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                  # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                  # [bk, hd]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < seq_k
    if causal:
        mask &= k_pos <= q_pos
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]                               # [bq]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, scale: float | None = None,
                        block_q: int = 128, block_k: int = 256,
                        interpret: bool = False) -> jax.Array:
    """q ``[b, sq, n_q, hd]``, k/v ``[b, sk, n_kv, hd]`` -> ``[b, sq, n_q,
    hd]``.  Forward only (serving / prefill hot path)."""
    b, sq, n_q, hd = q.shape
    _, sk, n_kv, _ = k.shape
    assert n_q % n_kv == 0
    g = n_q // n_kv
    scale = (hd ** -0.5) if scale is None else scale

    qf = jnp.moveaxis(q, 2, 1).reshape(b * n_q, sq, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * n_kv, sk, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * n_kv, sk, hd)

    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    nq_blk = qf.shape[1] // block_q
    nk_blk = kf.shape[1] // block_k

    def kv_index(bh, iq, ik):
        return ((bh // n_q) * n_kv + (bh % n_q) // g, ik, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, seq_k=sk, causal=causal),
        grid=(b * n_q, nq_blk, nk_blk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * n_q, qf.shape[1], hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)

    out = out[:, :sq].reshape(b, n_q, sq, hd)
    return jnp.moveaxis(out, 1, 2)
