"""Blockwise paged-KV decode attention (Pallas TPU).

One query token per slot attends a KV stream stored in fixed-size
**pages** of a global pool: slot ``b``'s logical positions
``[i * page_size, (i + 1) * page_size)`` live in pool page
``block_tables[b, i]``.  The grid is ``(slots, kv_heads, max_blocks)``
with the page dimension innermost — TPU grid steps execute sequentially,
so the online-softmax running state (max ``m``, normalizer ``l``,
accumulator ``acc``) lives in VMEM scratch across page steps, exactly
like the flash-attention forward next door.

The page gather is done by the *index maps*: ``block_tables`` (and the
per-slot valid length ``kv_len``) are scalar-prefetch operands
(``pltpu.PrefetchScalarGridSpec``), available before the kernel body
runs, so the k/v BlockSpecs can DMA page ``block_tables[b, ik]`` directly
— no repacked contiguous KV is ever materialized.  GQA is layout-native:
``q`` arrives ``[slots, kv_heads, group, head_dim]`` so one grid step
processes the whole query-head group of one kv head against one page.

See DESIGN.md in this directory for the grid/layout rationale.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_attention_fwd"]

_NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
            l_ref, *, scale: float, page_size: int, window: int | None,
            skip_pages: bool):
    b = pl.program_id(0)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _page_step():
        q = q_ref[0, 0].astype(jnp.float32)           # [g, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)        # [ps, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        kv_len = len_ref[b]                           # valid positions
        k_pos = ik * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], page_size), 1)
        mask = k_pos < kv_len                         # causal == valid here
        if window is not None:
            mask &= k_pos > kv_len - 1 - window       # q pos = kv_len-1
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                           # [g]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    if skip_pages:
        # page skip: slot b's stream ends at page ceil(kv_len/ps) - 1;
        # later grid steps are pure no-ops for this slot (a fully-masked
        # page contributes alpha=1, p=0, so skipping is bitwise-neutral)
        # and their k/v index maps re-request the previous page, so the
        # DMA is elided too — the innermost loop effectively stops at
        # ceil(kv_len / page_size) instead of scanning all max_blocks.
        pl.when(ik * page_size < len_ref[b])(_page_step)
    else:
        _page_step()

    @pl.when(ik == nk - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_attention_fwd(q: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, block_tables: jax.Array,
                        kv_len: jax.Array, *, scale: float | None = None,
                        window: int | None = None,
                        skip_pages: bool = True,
                        interpret: bool = False) -> jax.Array:
    """Single-token decode attention through a per-slot block table.

    q ``[slots, n_q, hd]``; k/v pages ``[n_pages, page_size, n_kv, hd]``;
    ``block_tables [slots, max_blocks]`` int32 page ids; ``kv_len
    [slots]`` int32 — positions ``< kv_len[b]`` are attended (the query
    sits at position ``kv_len[b] - 1``).  Returns ``[slots, n_q, hd]``.

    ``skip_pages`` (default on) stops slot ``b``'s innermost page loop
    at ``ceil(kv_len[b] / page_size)`` pages instead of scanning all
    ``max_blocks``: past-the-stream grid steps skip the compute body
    (bitwise-neutral — their pages would be fully masked anyway) and
    clamp the k/v index maps to the slot's last valid page, so Mosaic's
    revisiting check elides the DMA.  Ragged short-``kv_len`` slots in
    a deep pool stop paying the long tail's page traffic.
    """
    slots, n_q, hd = q.shape
    n_pages, page_size, n_kv, _ = k_pages.shape
    max_blocks = block_tables.shape[1]
    assert n_q % n_kv == 0, (n_q, n_kv)
    g = n_q // n_kv
    scale = (hd ** -0.5) if scale is None else scale

    qg = q.reshape(slots, n_kv, g, hd)       # head h attends kv head h // g

    if skip_pages:
        def kv_page(b, h, ik, bt, kl):
            # clamp to the slot's last valid page: grid steps past the
            # stream re-request the previous block, eliding the copy
            last = jnp.maximum((kl[b] - 1) // page_size, 0)
            return (bt[b, jnp.minimum(ik, last)], 0, h, 0)
    else:
        def kv_page(b, h, ik, bt, kl):
            return (bt[b, ik], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,               # block_tables, kv_len
        grid=(slots, n_kv, max_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b, h, ik, bt, kl: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd), kv_page),
            pl.BlockSpec((1, page_size, 1, hd), kv_page),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b, h, ik, bt, kl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, page_size=page_size,
                          window=window, skip_pages=skip_pages),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((slots, n_kv, g, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), kv_len.astype(jnp.int32),
      qg, k_pages, v_pages)

    return out.reshape(slots, n_q, hd)
