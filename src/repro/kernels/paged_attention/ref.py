"""Pure-jnp oracle for the paged-attention kernel.

This is also the **engine path on non-TPU backends** (see ops.py), so the
attention math deliberately mirrors :func:`repro.models.layers.gqa_attention`
op-for-op (same einsum strings, f32 score accumulation, ``-1e30`` mask
fill, f32 softmax cast back to the activation dtype): the serve engine's
greedy paged-vs-contiguous token-for-token equivalence depends on the two
paths being bitwise identical on the same valid KV entries.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def write_token_to_pages(pages: jax.Array, block_tables: jax.Array,
                         pos: jax.Array, active: jax.Array,
                         values: jax.Array) -> jax.Array:
    """Write one token's cache entry per slot into the page pool.

    pages ``[n_pages, page_size, ...]``; ``block_tables [slots,
    max_blocks]``; ``pos [slots]`` logical write position; ``values
    [slots, ...]``.  The ``active`` mask routes retired lanes' writes to
    the reserved trash page (page 0) — the invariant that keeps a
    retired slot's stale block table from corrupting pages that have
    since been re-allocated to a new tenant.  Keep every paged cache
    write on this helper so that gating lives in exactly one place.
    """
    page_size = pages.shape[1]
    blk = jnp.take_along_axis(block_tables, (pos // page_size)[:, None],
                              axis=1)[:, 0]
    page_ids = jnp.where(active, blk, 0)
    return pages.at[page_ids, pos % page_size].set(
        values.astype(pages.dtype))


def gather_pages(pages: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Rebuild each slot's logical KV stream from the page pool.

    pages ``[n_pages, page_size, ...]``, block_tables ``[slots,
    max_blocks]`` -> ``[slots, max_blocks * page_size, ...]`` in position
    order (entries past a slot's allocated blocks gather the trash page —
    callers mask them by valid length).
    """
    slots, max_blocks = block_tables.shape
    g = pages[block_tables]                  # [slots, mb, ps, ...]
    return g.reshape((slots, max_blocks * pages.shape[1])
                     + pages.shape[2:])


def paged_attention_ref(q: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, block_tables: jax.Array,
                        kv_len: jax.Array, *, scale: float | None = None,
                        window: int | None = None) -> jax.Array:
    """q ``[slots, n_q, hd]``; k/v pages ``[n_pages, ps, n_kv, hd]``;
    returns ``[slots, n_q, hd]`` (query at position ``kv_len - 1``)."""
    slots, n_q, hd = q.shape
    n_kv = k_pages.shape[2]
    scale = (hd ** -0.5) if scale is None else scale

    k = gather_pages(k_pages, block_tables)  # [slots, L, n_kv, hd]
    v = gather_pages(v_pages, block_tables)
    if n_kv != n_q:
        k = jnp.repeat(k, n_q // n_kv, axis=2)
        v = jnp.repeat(v, n_q // n_kv, axis=2)
    sk = k.shape[1]

    qc = q[:, None]                          # [slots, 1, n_q, hd]
    scores = jnp.einsum("bqnh,bsnh->bnqs", qc, k,
                        preferred_element_type=jnp.float32) * scale
    qpm = (kv_len - 1)[:, None, None, None]
    kpm = jnp.arange(sk)[None, None, None, :]
    mask = jnp.ones((slots, 1, 1, sk), bool)
    mask &= kpm <= qpm
    if window is not None:
        mask &= kpm > qpm - window
    mask &= kpm < kv_len[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnqs,bsnh->bqnh", probs, v)
    return out[:, 0]
