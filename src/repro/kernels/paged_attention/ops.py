"""Jitted public wrapper: Pallas on TPU, vectorized-XLA gather elsewhere.

Unlike the training-side kernels, paged attention sits on the serving hot
path, so the non-TPU fallback is the **ref** implementation (one fused
gather + einsum program), not interpret mode: Pallas interpret executes
the ``slots x kv_heads x max_blocks`` grid as a Python-level loop, which
is fine for parity sweeps but orders of magnitude too slow for a decode
tick.  The kernel-vs-ref parity tests pass ``impl="interpret"``
explicitly.
"""

from __future__ import annotations

import functools

import jax

from .kernel import paged_attention_fwd
from .ref import paged_attention_ref

__all__ = ["paged_attention"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("window", "impl",
                                             "skip_pages"))
def paged_attention(q, k_pages, v_pages, block_tables, kv_len, *,
                    window: int | None = None, impl: str | None = None,
                    skip_pages: bool = True):
    """Paged-KV single-token decode attention.

    q ``[slots, n_q, hd]``, k/v pages ``[n_pages, page_size, n_kv, hd]``,
    ``block_tables [slots, max_blocks]``, ``kv_len [slots]``.  ``impl``:
    ``None`` (auto: Mosaic kernel on TPU, ref elsewhere), ``"pallas"``,
    ``"interpret"`` (kernel body under the Pallas interpreter, for parity
    tests), or ``"ref"``.  ``skip_pages`` (kernel impls only) stops each
    slot's page loop at ``ceil(kv_len / page_size)`` pages — bitwise-
    equal output, less page traffic; the ref path always gathers exactly
    the table's pages.
    """
    if impl is None:
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return paged_attention_ref(q, k_pages, v_pages, block_tables,
                                   kv_len, window=window)
    if impl not in ("pallas", "interpret"):
        raise ValueError(f"unknown paged_attention impl {impl!r}")
    return paged_attention_fwd(q, k_pages, v_pages, block_tables, kv_len,
                               window=window, skip_pages=skip_pages,
                               interpret=impl == "interpret")
