"""Paged-KV decode attention: block-table gather through a global page
pool (the serve engine's ``kv_backend="paged"`` hot path)."""

from .kernel import paged_attention_fwd
from .ops import paged_attention
from .ref import gather_pages, paged_attention_ref, write_token_to_pages

__all__ = ["paged_attention", "paged_attention_fwd",
           "paged_attention_ref", "gather_pages",
           "write_token_to_pages"]
