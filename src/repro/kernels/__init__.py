"""Pallas TPU kernels for the compute hot spots (interpret-validated on CPU).

flash_attention  blockwise causal GQA attention forward (prefill hot path)
paged_attention  block-table decode attention over a paged KV pool
                 (serve engine kv_backend="paged" hot path)
fused_adam_sync  one-pass fused AdamW update (HBM-bound optimizer step)
ssd_scan         Mamba-2 SSD chunk-local core (MXU quadratic block)
int8_quant       per-row int8 quant/dequant (pod-axis compression wire fmt)
"""
