"""Fused AdamW step (Pallas TPU) — the HBM-bound optimizer hot spot.

The unfused update streams p, g, m, v through HBM several times (one pass
per elementwise op XLA fails to fuse across the dtype boundaries: bf16
params, f32 moments).  This kernel makes ONE pass: each grid step loads a
``[rows, 128*k]`` VMEM tile of all four tensors, computes the update in
registers and writes p', m', v' — 7 HBM transfers per element total, the
streaming lower bound.

Hyper-parameters arrive as a ``[6]`` float32 operand (lr, beta1, beta2,
eps, weight-decay, step) so a changing learning rate never recompiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_adamw"]


def _kernel(h_ref, p_ref, g_ref, m_ref, v_ref, p_out, m_out, v_out):
    lr, b1, b2, eps, wd, t = (h_ref[i] for i in range(6))
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...]
    v = v_ref[...]
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * g * g
    bc1 = 1.0 - jnp.power(b1, t)
    bc2 = 1.0 - jnp.power(b2, t)
    upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
    p2 = p * (1.0 - lr * wd) - lr * upd
    p_out[...] = p2.astype(p_out.dtype)
    m_out[...] = m2
    v_out[...] = v2


def fused_adamw(p: jax.Array, g: jax.Array, m: jax.Array, v: jax.Array, *,
                lr: float | jax.Array, beta1: float = 0.9,
                beta2: float = 0.999, eps: float = 1e-8,
                weight_decay: float = 0.0, step: jax.Array | int = 0,
                block: int = 1024, interpret: bool = False):
    """One fused AdamW step on a flat (any-shape) tensor quartet."""
    shape, dtype = p.shape, p.dtype
    n = p.size
    pad = (-n) % block
    flat = lambda x, dt: jnp.pad(x.reshape(-1).astype(dt), (0, pad))
    pf = flat(p, dtype)
    gf = flat(g, jnp.float32)
    mf = flat(m, jnp.float32)
    vf = flat(v, jnp.float32)
    hyper = jnp.asarray([lr, beta1, beta2, eps, weight_decay,
                         jnp.asarray(step, jnp.float32) + 1.0], jnp.float32)

    grid = (pf.size // block,)
    p2, m2, v2 = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((6,), lambda i: (0,)),         # hyper (broadcast)
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(pf.shape, dtype),
            jax.ShapeDtypeStruct(mf.shape, jnp.float32),
            jax.ShapeDtypeStruct(vf.shape, jnp.float32),
        ],
        interpret=interpret,
    )(hyper, pf, gf, mf, vf)
    unflat = lambda x, dt: x[:n].reshape(shape).astype(dt)
    return unflat(p2, dtype), unflat(m2, jnp.float32), \
        unflat(v2, jnp.float32)
