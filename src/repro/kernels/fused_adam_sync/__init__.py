from .ops import fused_adamw_step, fused_adamw_tree
from .ref import adamw_ref

__all__ = ["fused_adamw_step", "fused_adamw_tree", "adamw_ref"]
