"""Pure-jnp oracle for the fused AdamW kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_ref(p, g, m, v, *, lr, beta1=0.9, beta2=0.999, eps=1e-8,
              weight_decay=0.0, step=0):
    t = jnp.asarray(step, jnp.float32) + 1.0
    g = g.astype(jnp.float32)
    m2 = beta1 * m + (1 - beta1) * g
    v2 = beta2 * v + (1 - beta2) * g * g
    upd = (m2 / (1 - beta1 ** t)) / (jnp.sqrt(v2 / (1 - beta2 ** t)) + eps)
    p2 = p.astype(jnp.float32) * (1 - lr * weight_decay) - lr * upd
    return p2.astype(p.dtype), m2, v2
