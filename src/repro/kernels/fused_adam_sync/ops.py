"""Jitted wrapper: Pallas on TPU, interpret elsewhere; tree-level helper."""

from __future__ import annotations

import functools

import jax

from .kernel import fused_adamw

__all__ = ["fused_adamw_step", "fused_adamw_tree"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("beta1", "beta2", "eps", "weight_decay",
                                    "block"))
def fused_adamw_step(p, g, m, v, lr, step, *, beta1=0.9, beta2=0.999,
                     eps=1e-8, weight_decay=0.0, block=1024):
    return fused_adamw(p, g, m, v, lr=lr, beta1=beta1, beta2=beta2,
                       eps=eps, weight_decay=weight_decay, step=step,
                       block=block, interpret=not _on_tpu())


def fused_adamw_tree(params, grads, ms, vs, lr, step, **kw):
    """Apply the fused kernel leaf-wise over a parameter pytree."""
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(ms)
    flat_v = treedef.flatten_up_to(vs)
    out_p, out_m, out_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v, strict=True):
        p2, m2, v2 = fused_adamw_step(p, g, m, v, lr, step, **kw)
        out_p.append(p2)
        out_m.append(m2)
        out_v.append(v2)
    unf = treedef.unflatten
    return unf(out_p), unf(out_m), unf(out_v)
