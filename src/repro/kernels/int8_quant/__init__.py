from .ops import dequantize, quantize
from .ref import dequantize_rows_ref, quantize_rows_ref

__all__ = ["quantize", "dequantize", "quantize_rows_ref",
           "dequantize_rows_ref"]
