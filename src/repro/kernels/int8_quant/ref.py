"""Pure-jnp oracle (mirrors repro.parallel.compression)."""

from __future__ import annotations

import jax.numpy as jnp


def quantize_rows_ref(x):
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_rows_ref(q, s, dtype=jnp.float32):
    return (q.astype(jnp.float32) * s).astype(dtype)
