"""Per-row symmetric int8 quantize / dequantize (Pallas TPU).

Feeds the pod-axis compression path: quantizing the synchronized parameter
deltas halves (vs bf16) the bytes on the slow geo link.  One grid step
quantizes a ``[block_r, C]`` VMEM tile; optional stochastic rounding uses a
per-tile counter-derived uniform draw (threefry on device is overkill for
round-to-nearest-dither, and the EF residual absorbs the bias either way).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quantize_rows", "dequantize_rows"]


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                  # [br, C]
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0 + 1e-12
    y = x / scale
    q_ref[...] = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]) \
        .astype(x_ref.dtype)


def quantize_rows(x: jax.Array, *, block_r: int = 256,
                  interpret: bool = False):
    """x ``[R, C]`` -> (q ``[R, C]`` int8, scale ``[R, 1]`` f32)."""
    r, c = x.shape
    pad = (-r) % block_r
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    rp = x.shape[0]
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(rp // block_r,),
        in_specs=[pl.BlockSpec((block_r, c), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_r, c), lambda i: (i, 0)),
                   pl.BlockSpec((block_r, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rp, c), jnp.int8),
                   jax.ShapeDtypeStruct((rp, 1), jnp.float32)],
        interpret=interpret,
    )(x)
    return q[:r], s[:r]


def dequantize_rows(q: jax.Array, s: jax.Array, *, dtype=jnp.float32,
                    block_r: int = 256, interpret: bool = False):
    r, c = q.shape
    pad = (-r) % block_r
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        s = jnp.pad(s, ((0, pad), (0, 0)))
    rp = q.shape[0]
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(rp // block_r,),
        in_specs=[pl.BlockSpec((block_r, c), lambda i: (i, 0)),
                  pl.BlockSpec((block_r, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, c), dtype),
        interpret=interpret,
    )(q, s)
    return x[:r]
