from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import dequantize_rows, quantize_rows

__all__ = ["quantize", "dequantize"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.jit
def quantize(x):
    return quantize_rows(x, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("dtype",))
def dequantize(q, s, dtype=jnp.float32):
    return dequantize_rows(q, s, dtype=dtype, interpret=not _on_tpu())
