"""Human and JSON rendering of a lint run."""

from __future__ import annotations

import json

from .findings import ERROR, Finding
from .registry import all_rules


def summarize(new: list[Finding], baselined: list[Finding]) -> dict:
    return {
        "new": len(new),
        "errors": sum(1 for f in new if f.severity == ERROR),
        "warnings": sum(1 for f in new if f.severity != ERROR),
        "baselined": len(baselined),
        "rules": sorted({f.rule for f in new}),
    }


def render_human(new: list[Finding], baselined: list[Finding]) -> str:
    lines = [f.render() for f in new]
    s = summarize(new, baselined)
    tail = (f"{s['new']} finding(s): {s['errors']} error(s), "
            f"{s['warnings']} warning(s)")
    if baselined:
        tail += f"; {s['baselined']} baselined finding(s) not shown"
    if not new:
        tail = "clean" if not baselined else \
            f"clean ({s['baselined']} baselined finding(s) not shown)"
    lines.append(tail)
    return "\n".join(lines)


def render_json(new: list[Finding], baselined: list[Finding]) -> str:
    payload = {
        "version": 1,
        "findings": [f.to_json() for f in new],
        "baselined": [f.to_json() for f in baselined],
        "summary": summarize(new, baselined),
    }
    return json.dumps(payload, indent=2)


def render_rule_list() -> str:
    lines = []
    for name, rule in all_rules().items():
        lines.append(f"{name:18s} [{rule.severity}] {rule.summary}")
    return "\n".join(lines)
