"""Baseline I/O: grandfathered findings that don't gate CI.

The baseline is a committed JSON file of finding fingerprints
(rule + path + enclosing function + normalized source line — stable
across unrelated line-number churn).  ``python -m repro.lint
--write-baseline`` regenerates it; a finding not in the baseline fails
the run.  Duplicate fingerprints (two identical lines in one function)
are handled by count: the baseline absorbs as many occurrences as it
recorded, no more.
"""

from __future__ import annotations

import json
from collections import Counter
from pathlib import Path

from .findings import Finding

VERSION = 1


def load(path: str | Path) -> Counter:
    """Fingerprint -> grandfathered occurrence count (empty if the file
    doesn't exist — an absent baseline means 'everything gates')."""
    p = Path(path)
    if not p.exists():
        return Counter()
    data = json.loads(p.read_text())
    if data.get("version") != VERSION:
        raise ValueError(f"unsupported baseline version in {p}: "
                         f"{data.get('version')!r}")
    return Counter(f["fingerprint"] for f in data.get("findings", []))


def save(path: str | Path, findings: list[Finding]) -> None:
    entries = [{
        "fingerprint": f.fingerprint(),
        "rule": f.rule,
        "path": f.path,
        "context": f.context,
        "line_text": f.line_text,
    } for f in findings]
    payload = {"version": VERSION, "findings": entries}
    Path(path).write_text(json.dumps(payload, indent=2,
                                     sort_keys=True) + "\n")


def partition(findings: list[Finding], grandfathered: Counter
              ) -> tuple[list[Finding], list[Finding]]:
    """Split findings into (new, baselined), consuming baseline counts."""
    budget = Counter(grandfathered)
    new: list[Finding] = []
    old: list[Finding] = []
    for f in findings:
        fp = f.fingerprint()
        if budget[fp] > 0:
            budget[fp] -= 1
            old.append(f)
        else:
            new.append(f)
    return new, old
