"""Analysis driver: file discovery, parsing, pragma handling, rule
dispatch.  Pure stdlib — importing this package never imports jax, so
the linter runs anywhere (CI lint job, pre-commit, bare containers).
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Sequence

from . import astutil
from .findings import ERROR, Finding
from .hotpath import EXTRA_HOT_PATHS
from .registry import all_rules

__all__ = ["ModuleContext", "FunctionInfo", "lint_text", "lint_paths",
           "iter_py_files"]

# `# repro-lint: disable=RULE-A,RULE-B -- justification`
# `# repro-lint: disable` (all rules) — justification text after `--`
# is free-form and encouraged.
_PRAGMA = re.compile(
    r"#\s*repro-lint:\s*disable(?:=(?P<rules>[A-Za-z0-9_\-, ]+))?")
_ALL = "*"


@dataclass(frozen=True)
class FunctionInfo:
    node: ast.AST                  # FunctionDef | AsyncFunctionDef
    qualname: str
    is_hot: bool
    decorators: tuple[str, ...]    # resolved dotted names ('' unresolved)


@dataclass
class ModuleContext:
    path: Path
    relpath: str                   # posix style; what findings report
    module: str                    # dotted module guess ("" if unknown)
    source: str
    lines: list[str]
    tree: ast.Module
    aliases: dict[str, str]
    functions: list[FunctionInfo] = field(default_factory=list)

    def resolve(self, node: ast.AST) -> str | None:
        """Dotted path of a name/attribute chain through import aliases
        (``np.asarray`` -> ``numpy.asarray``)."""
        return astutil.dotted(node, self.aliases)

    def qualname_of(self, fn_node: ast.AST) -> str:
        for info in self.functions:
            if info.node is fn_node:
                return info.qualname
        return getattr(fn_node, "name", "<lambda>")

    def function_info(self, fn_node: ast.AST) -> FunctionInfo | None:
        for info in self.functions:
            if info.node is fn_node:
                return info
        return None

    def hot_functions(self) -> list[FunctionInfo]:
        return [f for f in self.functions if f.is_hot]

    def calls(self, *dotted_names: str) -> Iterable[ast.Call]:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call) \
                    and self.resolve(node.func) in dotted_names:
                yield node


def _collect_functions(ctx: ModuleContext) -> None:
    def visit(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                decs = tuple(ctx.resolve(d) or "" for d in
                             child.decorator_list)
                hot = any(d == "hot_path" or d.endswith(".hot_path")
                          for d in decs)
                hot = hot or f"{ctx.module}:{qual}" in EXTRA_HOT_PATHS
                ctx.functions.append(FunctionInfo(
                    node=child, qualname=qual, is_hot=hot, decorators=decs))
                visit(child, f"{qual}.")
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}{child.name}.")
            else:
                visit(child, prefix)

    visit(ctx.tree, "")


def _pragma_map(lines: list[str]) -> dict[int, set[str]]:
    """Line number -> suppressed rule names ('*' = all).  A pragma on a
    code line covers that line; a standalone comment pragma covers the
    next code line (skipping continuation comments and blanks, so a
    multi-line justification comment still lands on the statement)."""
    out: dict[int, set[str]] = {}
    for i, line in enumerate(lines, start=1):
        m = _PRAGMA.search(line)
        if not m:
            continue
        rules = m.group("rules")
        if rules is not None:
            # drop the free-form `-- justification` tail (rule names use
            # single hyphens only)
            rules = rules.split("--")[0]
        names = ({_ALL} if rules is None else
                 {r.strip().upper() for r in rules.split(",") if r.strip()})
        target = i
        if line.strip().startswith("#"):
            target = i + 1
            while target <= len(lines) and (
                    not lines[target - 1].strip()
                    or lines[target - 1].strip().startswith("#")):
                target += 1
        out.setdefault(target, set()).update(names)
    return out


def _module_guess(relpath: str) -> str:
    parts = Path(relpath).with_suffix("").parts
    if "src" in parts:
        parts = parts[parts.index("src") + 1:]
    elif "repro" in parts:
        parts = parts[parts.index("repro"):]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def build_context(source: str, path: str | Path) -> ModuleContext:
    p = Path(path)
    relpath = p.as_posix()
    tree = ast.parse(source, filename=relpath)
    astutil.attach_parents(tree)
    ctx = ModuleContext(
        path=p, relpath=relpath, module=_module_guess(relpath),
        source=source, lines=source.splitlines(),
        tree=tree, aliases=astutil.collect_aliases(tree))
    _collect_functions(ctx)
    return ctx


def _run_rules(ctx: ModuleContext, select: Sequence[str] | None,
               ignore: Sequence[str] | None) -> list[Finding]:
    findings: list[Finding] = []
    for name, rule in all_rules().items():
        if select and name not in select:
            continue
        if ignore and name in ignore:
            continue
        if rule.applies(ctx):
            findings.extend(rule.check(ctx))
    pragmas = _pragma_map(ctx.lines)
    kept = [f for f in findings
            if not (pragmas.get(f.line, set()) & {_ALL, f.rule})]
    return sorted(kept, key=lambda f: (f.path, f.line, f.col, f.rule))


def lint_text(source: str, path: str | Path = "snippet.py", *,
              select: Sequence[str] | None = None,
              ignore: Sequence[str] | None = None) -> list[Finding]:
    """Analyze one module given as text (the test-suite entry point).
    ``path`` matters: path-scoped rules (PALLAS, SIM-DETERMINISM) key
    off it."""
    try:
        ctx = build_context(source, path)
    except SyntaxError as e:
        return [Finding(rule="PARSE", severity=ERROR,
                        path=Path(path).as_posix(), line=e.lineno or 1,
                        col=(e.offset or 0) + 1,
                        message=f"syntax error: {e.msg}")]
    return _run_rules(ctx, select, ignore)


def iter_py_files(paths: Iterable[str | Path]) -> list[Path]:
    out: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(f for f in sorted(p.rglob("*.py"))
                       if "__pycache__" not in f.parts)
        elif p.suffix == ".py":
            out.append(p)
    return out


def lint_paths(paths: Iterable[str | Path], *,
               select: Sequence[str] | None = None,
               ignore: Sequence[str] | None = None) -> list[Finding]:
    """Analyze files/directories; returns pragma-filtered findings
    (baseline filtering is the CLI's job)."""
    findings: list[Finding] = []
    for f in iter_py_files(paths):
        findings.extend(lint_text(f.read_text(), f, select=select,
                                  ignore=ignore))
    return findings
