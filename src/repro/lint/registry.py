"""Rule registry: rules self-register at import; the engine runs every
registered rule whose scope matches the module under analysis."""

from __future__ import annotations

import ast
from typing import TYPE_CHECKING, Iterable

from . import astutil
from .findings import ERROR, Finding

if TYPE_CHECKING:                                     # pragma: no cover
    from .engine import ModuleContext

_RULES: dict[str, "Rule"] = {}


class Rule:
    """One hazard class.  Subclasses set ``name`` (the id used in
    pragmas/``--select``), ``severity``, a one-line ``summary``, and
    implement :meth:`check`."""

    name: str = ""
    severity: str = ERROR
    summary: str = ""

    def applies(self, ctx: "ModuleContext") -> bool:
        return True

    def check(self, ctx: "ModuleContext") -> Iterable[Finding]:
        raise NotImplementedError

    # ------------------------------------------------------------- helpers
    def finding(self, ctx: "ModuleContext", node: ast.AST, message: str,
                *, severity: str | None = None) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        fn = astutil.enclosing_function(node)
        context = ctx.qualname_of(fn) if fn is not None else ""
        text = ctx.lines[line - 1].strip() if line <= len(ctx.lines) else ""
        return Finding(rule=self.name, severity=severity or self.severity,
                       path=ctx.relpath, line=line, col=col + 1,
                       message=message, context=context, line_text=text)


def register(cls: type[Rule]) -> type[Rule]:
    if not cls.name:
        raise ValueError(f"rule {cls.__name__} has no name")
    if cls.name in _RULES:
        raise ValueError(f"duplicate rule name {cls.name}")
    _RULES[cls.name] = cls()
    return cls


def all_rules() -> dict[str, Rule]:
    from . import rules  # noqa: F401  (import side effect: registration)
    return dict(sorted(_RULES.items()))
