"""CLI: ``python -m repro.lint [paths...]``.

Exit codes: 0 — clean (or every finding baselined / warning-only),
1 — new error findings (new warnings too, under ``--strict``),
2 — usage or internal error.
"""

from __future__ import annotations

import argparse
import sys
from collections import Counter
from pathlib import Path

from . import baseline as baseline_io
from . import report
from .engine import lint_paths
from .findings import ERROR

DEFAULT_BASELINE = ".repro-lint-baseline.json"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="JAX-aware static analysis: host-sync, recompile, "
                    "donation, PRNG-key, Pallas, and sim-determinism "
                    "hazard rules (see src/repro/lint/README.md)")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories (default: src/repro)")
    ap.add_argument("--format", choices=("human", "json"),
                    default="human")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default: {DEFAULT_BASELINE} "
                         "next to the first path's repo root, if present)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the current findings as the new baseline "
                         "and exit 0")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule names to run")
    ap.add_argument("--ignore", default=None,
                    help="comma-separated rule names to skip")
    ap.add_argument("--strict", action="store_true",
                    help="warnings also gate (exit 1)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(report.render_rule_list())
        return 0

    select = [s.strip().upper() for s in args.select.split(",")] \
        if args.select else None
    ignore = [s.strip().upper() for s in args.ignore.split(",")] \
        if args.ignore else None

    findings = lint_paths(args.paths, select=select, ignore=ignore)

    baseline_path = args.baseline
    if baseline_path is None and Path(DEFAULT_BASELINE).exists():
        baseline_path = DEFAULT_BASELINE

    if args.write_baseline:
        out = baseline_path or DEFAULT_BASELINE
        baseline_io.save(out, findings)
        print(f"wrote {len(findings)} finding(s) to {out}")
        return 0

    grandfathered = (baseline_io.load(baseline_path) if baseline_path
                     else Counter())
    new, old = baseline_io.partition(findings, grandfathered)

    out = report.render_human(new, old) if args.format == "human" \
        else report.render_json(new, old)
    print(out)

    gating = [f for f in new
              if f.severity == ERROR or args.strict]
    return 1 if gating else 0


if __name__ == "__main__":
    sys.exit(main())
