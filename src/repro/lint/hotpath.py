"""Hot-path marking: the contract between runtime code and HOST-SYNC.

Functions on a dispatch-overlap-critical path (the fused period loop,
the serve decode tick, the prefetcher) are marked with :func:`hot_path`.
The HOST-SYNC and RECOMPILE rules only police marked functions, so the
rest of the codebase can ``float()`` metrics freely — the analyzer's job
is to keep *implicit* device syncs out of exactly the regions whose
performance depends on async dispatch (see runtime/DESIGN.md).

The decorator is a pure annotation — zero runtime overhead, no wrapper
frame — detected *statically* by the analyzer (any decorator whose
dotted name ends in ``hot_path``).  ``EXTRA_HOT_PATHS`` covers functions
that cannot carry a decorator (generated code, third-party subclass
overrides): add ``"<module>:<qualname>"`` entries, e.g.
``"repro.runtime.runner:Runner._run_fused"``.
"""

from __future__ import annotations

from typing import Callable, TypeVar

F = TypeVar("F", bound=Callable)

# "<dotted.module>:<qualname>" entries for functions that can't be
# decorated.  Checked by the engine next to the decorator scan.
EXTRA_HOT_PATHS: frozenset[str] = frozenset()


def hot_path(fn: F) -> F:
    """Mark ``fn`` as dispatch-overlap critical.

    Inside a hot function the analyzer rejects implicit device syncs
    (``np.asarray`` / ``float()`` / ``.item()`` / ``.tolist()`` /
    ``print`` of device values) and per-call ``jax.jit``.  Intentional
    syncs use the explicit forms — ``jax.block_until_ready`` /
    ``jax.device_get`` — or a ``# repro-lint: disable=HOST-SYNC``
    pragma with a justification.
    """
    fn.__repro_hot_path__ = True
    return fn
