"""Finding/severity types shared by the analyzer, rules, and reporters."""

from __future__ import annotations

import hashlib
from dataclasses import dataclass

ERROR = "error"
WARNING = "warning"


@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location.

    ``context`` is the qualname of the enclosing function ('' at module
    level); ``line_text`` the stripped source line.  Both feed the
    baseline fingerprint so grandfathered findings survive unrelated
    line-number churn (see :mod:`repro.lint.baseline`).
    """

    rule: str
    severity: str
    path: str                     # posix-style, as handed to the engine
    line: int
    col: int
    message: str
    context: str = ""
    line_text: str = ""

    def fingerprint(self) -> str:
        key = "\x1f".join([self.rule, self.path, self.context,
                           " ".join(self.line_text.split())])
        return hashlib.sha1(key.encode()).hexdigest()[:16]

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "severity": self.severity,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "context": self.context,
            "line_text": self.line_text,
            "fingerprint": self.fingerprint(),
        }

    def render(self) -> str:
        where = f" [{self.context}]" if self.context else ""
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"{self.severity}: {self.message}{where}")
