"""KEY-REUSE: the same PRNG key consumed by more than one primitive.

JAX keys are not stateful: feeding one key to two primitives gives
*correlated* streams (identical, for the same primitive), which is how
"random" dropout masks end up equal across layers and sampled tokens
repeat across slots.  Every consumption must be preceded by a fresh
``jax.random.split`` / ``fold_in``.

The rule tracks, per function scope, names bound from
``jax.random.PRNGKey`` / ``key`` / ``split`` / ``fold_in`` (including
tuple unpacking and constant-index subscripts of split results) and
flags the second consumption of the same key identity without an
intervening rebind.  Consumption = the key appearing as an argument to
any call (``jax.random.*`` primitives, jitted closures, samplers — all
consume).  Loop bodies are scanned twice, so a key defined outside a
loop and consumed inside it without a per-iteration split is caught.
"""

from __future__ import annotations

import ast
from typing import Iterable

from .. import astutil
from ..engine import ModuleContext
from ..findings import Finding
from ..registry import Rule, register

_KEY_SOURCES = {"jax.random.PRNGKey", "jax.random.key",
                "jax.random.split", "jax.random.fold_in",
                "jax.random.wrap_key_data"}
_KEY_KWARGS = {"key", "rng", "prng_key", "seed_key"}


def _key_identity(node: ast.AST, keys: set[str]) -> str | None:
    """A trackable key identity: a known key name, or a constant-index
    subscript of one (``keys[0]``).  Slices and computed indices are
    untracked (conservatively silent)."""
    if isinstance(node, ast.Name) and node.id in keys:
        return node.id
    if isinstance(node, ast.Subscript) \
            and isinstance(node.value, ast.Name) \
            and node.value.id in keys:
        idx = node.slice
        if isinstance(idx, ast.Constant) and isinstance(idx.value, int):
            return f"{node.value.id}[{idx.value}]"
    return None


@register
class KeyReuseRule(Rule):
    name = "KEY-REUSE"
    summary = ("the same PRNGKey / split result consumed twice without "
               "an intervening split")

    # parameters with these names are presumed to be PRNG keys even
    # though no jax.random call binds them in this scope
    PARAM_KEY_NAMES = frozenset({"key", "rng", "prng_key", "rng_key"})

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        for info in ctx.functions:
            seed = set(astutil.param_names(info.node)) \
                & self.PARAM_KEY_NAMES
            yield from self._scan(info.node.body, ctx, seed)
        yield from self._scan(ctx.tree.body, ctx, set())

    def _scan(self, body: list[ast.stmt], ctx: ModuleContext,
              seed_keys: set[str]) -> Iterable[Finding]:
        keys: set[str] = set(seed_keys)
        consumed: dict[str, int] = {}          # identity -> first line
        flagged: set[int] = set()
        for stmt in astutil.iter_statements(body, unroll_loops=2):
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            for call in astutil.stmt_nodes(stmt):
                if not isinstance(call, ast.Call):
                    continue
                dot = ctx.resolve(call.func)
                is_random = dot is not None \
                    and dot.startswith("jax.random.")
                args = list(call.args) + [
                    kw.value for kw in call.keywords
                    if kw.arg is None or kw.arg in _KEY_KWARGS
                    or is_random]
                for arg in args:
                    ident = _key_identity(arg, keys)
                    if ident is None:
                        continue
                    if ident in consumed and id(arg) not in flagged:
                        flagged.add(id(arg))
                        yield self.finding(
                            ctx, arg,
                            f"PRNG key `{ident}` is consumed again "
                            f"(first consumed line {consumed[ident]}) "
                            "without an intervening jax.random.split — "
                            "the two streams are correlated")
                    consumed.setdefault(ident, arg.lineno)
            # (re)binds: fresh keys from key sources; any rebind clears
            # the consumption record for that name and its subscripts
            targets = astutil.assign_target_names(stmt)
            value = stmt.value if isinstance(stmt, ast.Assign) else None
            is_key_bind = isinstance(value, ast.Call) and \
                ctx.resolve(value.func) in _KEY_SOURCES
            for name in targets:
                for ident in [c for c in consumed
                              if c == name or c.startswith(f"{name}[")]:
                    del consumed[ident]
                if is_key_bind:
                    keys.add(name)
