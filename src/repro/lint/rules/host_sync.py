"""HOST-SYNC: implicit device synchronization inside hot-path functions.

DreamDDP's overlap argument (and the serve engine's goodput) rests on
async dispatch: the host queues a whole period / decode block and syncs
ONCE at the boundary.  Any implicit transfer inside the hot region —
``np.asarray(x)``, ``float(x)``, ``x.item()``, ``x.tolist()``,
``print(x)`` on a device value — silently blocks the host mid-period
and serializes exactly the communication the scheduler planned to hide.

The rule polices only functions marked with ``@hot_path``
(:mod:`repro.lint.hotpath`).  The *explicit* sync forms —
``jax.block_until_ready`` and ``jax.device_get`` — are the blessed
escape hatches: one deliberate, batched transfer per drain point.
Values produced by ``jax.device_get`` (and taints derived from them)
are tracked as host-side, so post-drain ``float()`` conversion of
already-materialized metrics does not fire.
"""

from __future__ import annotations

import ast
from typing import Iterable

from .. import astutil
from ..engine import ModuleContext
from ..findings import Finding, WARNING
from ..registry import Rule, register

DEVICE, HOST, UNKNOWN = "device", "host", "unknown"

# Calls whose result is host-resident (or plain Python).
_HOST_CALLS = {
    "jax.device_get", "numpy.asarray", "numpy.array", "numpy.shape",
    "float", "int", "bool", "str", "len", "range", "enumerate", "sorted",
    "list", "tuple", "dict", "set", "min", "max", "sum", "abs", "zip",
    "time.perf_counter", "time.monotonic", "time.time", "isinstance",
    "getattr", "hasattr", "repr",
}
# Implicit syncs that are flagged regardless of provenance: in this
# codebase a hot-path numpy materialization is always a device read.
_ALWAYS_SYNC = {"numpy.asarray", "numpy.array"}
_SYNC_METHODS = {"item", "tolist"}
_CONVERSIONS = {"float", "int", "bool"}

_SUPPRESS = ("; make it explicit and batched (one jax.device_get / "
             "jax.block_until_ready per drain), move it off the hot "
             "path, or add `# repro-lint: disable=HOST-SYNC -- why`")


def _classify(node: ast.AST, env: dict[str, str],
              ctx: ModuleContext) -> str:
    """HOST / DEVICE / UNKNOWN provenance of an expression, given the
    per-function name environment.  Conservative: unresolvable calls in
    a hot function are presumed to return device values (they are
    usually jitted executables)."""
    if isinstance(node, ast.Constant):
        return HOST
    if isinstance(node, ast.Name):
        return env.get(node.id, UNKNOWN)
    if isinstance(node, (ast.Subscript, ast.Attribute, ast.Starred)):
        root = astutil.root_name(node)
        if root is not None:
            return env.get(root, UNKNOWN)
        return UNKNOWN
    if isinstance(node, ast.Call):
        dot = ctx.resolve(node.func)
        if dot in _HOST_CALLS:
            return HOST
        if dot is not None:
            root = dot.split(".")[0]
            if dot.startswith("jax.") or root == "jax":
                return DEVICE
            if root in ("numpy", "math", "time", "itertools",
                        "functools", "operator", "collections",
                        "statistics"):
                return HOST
            if root in env:                 # method of / call through a
                base = env[root]            # locally-classified value
                return DEVICE if base == UNKNOWN else base
        # self._jitted_step(...), steps[h](...), project helpers: in a
        # hot function, presume an unrecognized callable returns device
        # values — that's what hot paths dispatch
        return DEVICE
    if isinstance(node, (ast.BinOp, ast.BoolOp, ast.Compare, ast.UnaryOp,
                         ast.IfExp, ast.Tuple, ast.List, ast.Dict,
                         ast.JoinedStr, ast.FormattedValue)):
        kinds = [_classify(c, env, ctx) for c in ast.iter_child_nodes(node)
                 if isinstance(c, ast.expr)]
        if DEVICE in kinds:
            return DEVICE
        if kinds and all(k == HOST for k in kinds):
            return HOST
        return UNKNOWN
    return UNKNOWN


def _build_env(fn: ast.AST, ctx: ModuleContext) -> dict[str, str]:
    """One forward pass (source order, control flow ignored) assigning
    HOST/DEVICE provenance to local names."""
    env: dict[str, str] = {}
    nodes: list[ast.AST] = sorted(
        astutil.walk_no_nested_functions(fn),
        key=lambda n: (getattr(n, "lineno", 0),
                       getattr(n, "col_offset", 0)))
    for node in nodes:
        if isinstance(node, ast.Assign):
            kind = _classify(node.value, env, ctx)
            for name in astutil.assign_target_names(node):
                env[name] = kind
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            if isinstance(node.target, ast.Name):
                env[node.target.id] = _classify(node.value, env, ctx)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            kind = _classify(node.iter, env, ctx)
            for name in astutil.assign_target_names(node):
                env[name] = kind
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for comp in node.generators:
                kind = _classify(comp.iter, env, ctx)
                for t in ast.walk(comp.target):
                    if isinstance(t, ast.Name):
                        env[t.id] = kind
    return env


@register
class HostSyncRule(Rule):
    name = "HOST-SYNC"
    summary = ("implicit device sync (np.asarray / float / .item / "
               ".tolist / print of a device value) inside a @hot_path "
               "function")

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        for info in ctx.hot_functions():
            env = _build_env(info.node, ctx)
            for node in astutil.walk_no_nested_functions(info.node):
                if not isinstance(node, ast.Call):
                    continue
                yield from self._check_call(node, env, ctx)
            # nested defs inside a hot function run on the same path
            for node in ast.walk(info.node):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) \
                        and node is not info.node:
                    nested_env = _build_env(node, ctx)
                    for sub in astutil.walk_no_nested_functions(node):
                        if isinstance(sub, ast.Call):
                            yield from self._check_call(sub, nested_env,
                                                        ctx)

    def _check_call(self, node: ast.Call, env: dict[str, str],
                    ctx: ModuleContext) -> Iterable[Finding]:
        dot = ctx.resolve(node.func)
        if dot in _ALWAYS_SYNC:
            yield self.finding(
                ctx, node,
                f"`{dot}` in a hot path forces a blocking device->host "
                f"read per call{_SUPPRESS}")
            return
        if dot == "print":
            args = [a for a in node.args
                    if _classify(a, env, ctx) != HOST]
            if args:
                yield self.finding(
                    ctx, node,
                    "`print` of a possibly-device value blocks dispatch "
                    f"in a hot path{_SUPPRESS}", severity=WARNING)
            return
        if dot in _CONVERSIONS and len(node.args) == 1:
            if _classify(node.args[0], env, ctx) == DEVICE:
                yield self.finding(
                    ctx, node,
                    f"`{dot}()` of a device value is an implicit "
                    f"blocking transfer in a hot path{_SUPPRESS}")
            return
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _SYNC_METHODS and not node.args:
            if _classify(node.func.value, env, ctx) != HOST:
                yield self.finding(
                    ctx, node,
                    f"`.{node.func.attr}()` synchronously materializes "
                    f"a device value in a hot path{_SUPPRESS}")
