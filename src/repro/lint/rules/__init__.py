"""Rule modules — importing this package registers every rule."""

from . import (donation, host_sync, key_reuse, pallas,  # noqa: F401
               recompile, sim_determinism)
