"""SIM-DETERMINISM: nondeterminism sources in the simulator/scheduler.

SimNet traces are canonical JSON pinned by conformance tests, and the
schedule solver's output is compared against a brute-force optimum —
both must be bit-stable across runs and Python versions.  Two hazard
classes are rejected inside ``src/repro/sim/`` and
``src/repro/core/schedule.py``:

* **wall-clock / ambient randomness** — ``time.time`` /
  ``perf_counter`` / ``datetime.now`` / stdlib ``random.*`` leak host
  timing or unseeded state into simulated time;
* **unordered iteration feeding output** — iterating a ``set`` (or
  materializing one with ``list()``/``tuple()``) makes trace/schedule
  ordering hash-dependent.  Order-insensitive consumers (``sorted``,
  ``min``/``max``/``sum``/``len``/``any``/``all``/``set``) are exempt;
  everything else must go through ``sorted(...)``.
"""

from __future__ import annotations

import ast
from typing import Iterable

from .. import astutil
from ..engine import ModuleContext
from ..findings import Finding
from ..registry import Rule, register

_SCOPES = ("repro/sim/", "repro/core/schedule.py", "repro/hier/")

_WALLCLOCK = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "time.process_time", "datetime.datetime.now",
    "datetime.datetime.utcnow", "datetime.date.today", "uuid.uuid4",
}
_ORDER_FREE = {"sorted", "set", "frozenset", "sum", "min", "max", "len",
               "any", "all"}
_MATERIALIZERS = {"list", "tuple"}
_SET_METHODS = {"union", "intersection", "difference",
                "symmetric_difference", "copy"}


def _is_set_typed(node: ast.AST, set_names: set[str],
                  ctx: ModuleContext) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Name):
        return node.id in set_names
    if isinstance(node, ast.Call):
        dot = ctx.resolve(node.func)
        if dot in ("set", "frozenset"):
            return True
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _SET_METHODS:
            return _is_set_typed(node.func.value, set_names, ctx)
        return False
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.Sub, ast.BitOr, ast.BitAnd, ast.BitXor)):
        return _is_set_typed(node.left, set_names, ctx) \
            or _is_set_typed(node.right, set_names, ctx)
    if isinstance(node, ast.Attribute):
        return astutil.dotted(node, {}) in set_names
    return False


@register
class SimDeterminismRule(Rule):
    name = "SIM-DETERMINISM"
    summary = ("wall-clock reads and unordered set iteration inside the "
               "simulator / schedule solver")

    def applies(self, ctx: ModuleContext) -> bool:
        return any(s in ctx.relpath for s in _SCOPES)

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        set_names = self._set_typed_names(ctx)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                dot = ctx.resolve(node.func)
                if dot in _WALLCLOCK:
                    yield self.finding(
                        ctx, node,
                        f"`{dot}` reads the wall clock inside the "
                        "deterministic simulator; thread simulated time "
                        "through explicitly")
                elif dot is not None and dot.startswith("random.") \
                        and dot != "random.Random":
                    # random.Random(seed) is the sanctioned seeded
                    # generator; the module-level functions share
                    # ambient global state
                    yield self.finding(
                        ctx, node,
                        f"stdlib `{dot}` uses ambient global RNG state; "
                        "use a seeded generator carried in the scenario")
                elif dot in _MATERIALIZERS and len(node.args) == 1 \
                        and _is_set_typed(node.args[0], set_names, ctx):
                    yield self.finding(
                        ctx, node,
                        f"`{dot}()` of a set materializes hash order "
                        "into trace/schedule output; use sorted(...)")
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                if _is_set_typed(node.iter, set_names, ctx):
                    yield self.finding(
                        ctx, node,
                        "iteration over an unordered set feeds "
                        "simulator output in hash order; iterate "
                        "sorted(...) for a canonical order")
            elif isinstance(node, (ast.ListComp, ast.SetComp,
                                   ast.DictComp, ast.GeneratorExp)):
                yield from self._check_comprehension(node, set_names, ctx)

    def _check_comprehension(self, node, set_names, ctx
                             ) -> Iterable[Finding]:
        for comp in node.generators:
            if not _is_set_typed(comp.iter, set_names, ctx):
                continue
            par = astutil.parent(node)
            if isinstance(par, ast.Call) \
                    and ctx.resolve(par.func) in _ORDER_FREE:
                continue                 # sorted(x for x in s) etc.
            if isinstance(node, ast.SetComp):
                continue                 # set -> set: still unordered
            yield self.finding(
                ctx, comp.iter,
                "comprehension over an unordered set feeds simulator "
                "output in hash order; wrap the source in sorted(...)")

    @staticmethod
    def _set_typed_names(ctx: ModuleContext) -> set[str]:
        """Names (and ``self.x`` dotted attributes) assigned a set
        anywhere in the module — cross-method, best effort."""
        names: set[str] = set()

        def _set_ann(ann: ast.AST | None) -> bool:
            return (isinstance(ann, ast.Name)
                    and ann.id in ("set", "frozenset")) or \
                (isinstance(ann, ast.Subscript)
                 and isinstance(ann.value, ast.Name)
                 and ann.value.id in ("set", "frozenset"))

        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                for a in (args.posonlyargs + args.args + args.kwonlyargs):
                    if _set_ann(a.annotation):
                        names.add(a.arg)
            value = None
            targets: list[ast.AST] = []
            if isinstance(node, ast.Assign):
                value, targets = node.value, node.targets
            elif isinstance(node, ast.AnnAssign):
                is_set_ann = _set_ann(node.annotation)
                if is_set_ann or node.value is not None:
                    value, targets = node.value, [node.target]
                if is_set_ann:
                    for t in targets:
                        d = astutil.dotted(t, {})
                        if d:
                            names.add(d)
            if value is not None and _is_set_typed(value, names, ctx):
                for t in targets:
                    d = astutil.dotted(t, {})
                    if d:
                        names.add(d)
        return names
