"""PALLAS: kernel-module hazards around ``pl.pallas_call``.

Three checks, all scoped to modules that import
``jax.experimental.pallas`` (in this repo: ``src/repro/kernels/*/``):

* **index_map arity** — every ``BlockSpec`` index_map must take one
  argument per grid dimension, *plus* one per scalar-prefetch operand
  when the call uses ``pltpu.PrefetchScalarGridSpec`` (the scalar refs
  are prepended to the index-map signature).  An arity mismatch maps
  boundary blocks to the wrong pages and is invisible until a
  real-shape run.
* **out dtype** — a store into an output ref whose ``.astype`` dtype
  contradicts the literal dtype declared in ``out_shape`` truncates
  silently in interpret mode and miscompiles on Mosaic.
* **grid-position branches** — Python ``if``/``while`` on
  ``pl.program_id`` / ``pl.num_programs`` (directly or via a local
  binding) inside a kernel body: grid positions are traced, so the
  branch either fails or applies to every grid step; boundary
  loads/stores must be predicated with ``pl.when``.
"""

from __future__ import annotations

import ast
from typing import Iterable

from .. import astutil
from ..engine import ModuleContext
from ..findings import Finding, WARNING
from ..registry import Rule, register

_PALLAS = "jax.experimental.pallas"
_GRID_FNS = ("program_id", "num_programs")

_DTYPE_NAMES = {
    "jax.numpy.float32": "float32", "jax.numpy.float16": "float16",
    "jax.numpy.bfloat16": "bfloat16", "jax.numpy.int8": "int8",
    "jax.numpy.int32": "int32", "jax.numpy.uint32": "uint32",
    "jax.numpy.float64": "float64", "jax.numpy.int16": "int16",
    "numpy.float32": "float32", "numpy.int8": "int8",
    "numpy.int32": "int32", "numpy.float16": "float16",
}


def _literal_dtype(node: ast.AST | None, ctx: ModuleContext) -> str | None:
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    dot = ctx.resolve(node)
    return _DTYPE_NAMES.get(dot) if dot else None


def _local_assignments(ctx: ModuleContext) -> dict[str, ast.AST]:
    """name -> last assigned value expression (module + function scopes;
    best effort for resolving ``grid=grid`` style indirection)."""
    out: dict[str, ast.AST] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out[t.id] = node.value
    return out


class _CallInfo:
    """Resolved shape of one pallas_call: grid rank, scalar-prefetch
    count, specs, out_shape entries, kernel def."""

    def __init__(self, call: ast.Call, ctx: ModuleContext,
                 assigns: dict[str, ast.AST],
                 defs: dict[str, ast.AST]):
        self.call = call
        self.rank: int | None = None
        self.n_scalar = 0
        self.specs: list[ast.Call] = []
        self.out_shapes: list[ast.Call] = []
        self.kernel: ast.AST | None = None

        def deref(node: ast.AST | None) -> ast.AST | None:
            if isinstance(node, ast.Name):
                return assigns.get(node.id)
            return node

        grid_src = call
        spec = deref(astutil.keyword(call, "grid_spec"))
        if isinstance(spec, ast.Call) and (ctx.resolve(spec.func) or "") \
                .endswith("PrefetchScalarGridSpec"):
            grid_src = spec
            n = astutil.keyword(spec, "num_scalar_prefetch")
            if isinstance(n, ast.Constant) and isinstance(n.value, int):
                self.n_scalar = n.value
        grid = deref(astutil.keyword(grid_src, "grid"))
        if isinstance(grid, (ast.Tuple, ast.List)):
            self.rank = len(grid.elts)
        elif isinstance(grid, ast.Constant) and isinstance(grid.value, int):
            self.rank = 1

        for kw_name in ("in_specs", "out_specs"):
            val = deref(astutil.keyword(grid_src, kw_name))
            items = val.elts if isinstance(val, (ast.Tuple, ast.List)) \
                else [val] if val is not None else []
            for item in items:
                if isinstance(item, ast.Call) and \
                        (ctx.resolve(item.func) or "").endswith("BlockSpec"):
                    self.specs.append(item)
        self.n_in, self.n_out = self._spec_counts(grid_src, deref, ctx)

        out_shape = deref(astutil.keyword(call, "out_shape"))
        items = out_shape.elts \
            if isinstance(out_shape, (ast.Tuple, ast.List)) \
            else [out_shape] if out_shape is not None else []
        self.out_shapes = [
            i for i in items if isinstance(i, ast.Call)
            and (ctx.resolve(i.func) or "").endswith("ShapeDtypeStruct")]

        if call.args:
            k = call.args[0]
            if isinstance(k, ast.Name):
                self.kernel = defs.get(k.id)
            elif isinstance(k, (ast.FunctionDef, ast.Lambda)):
                self.kernel = k

    @staticmethod
    def _spec_counts(grid_src, deref, ctx) -> tuple[int, int]:
        counts = []
        for kw_name in ("in_specs", "out_specs"):
            val = deref(astutil.keyword(grid_src, kw_name))
            if isinstance(val, (ast.Tuple, ast.List)):
                counts.append(len(val.elts))
            elif val is not None:
                counts.append(1)
            else:
                counts.append(0)
        return counts[0], counts[1]


@register
class PallasRule(Rule):
    name = "PALLAS"
    summary = ("BlockSpec index_map arity vs grid rank, out_shape dtype "
               "mismatches, Python branches on pl.program_id")

    def applies(self, ctx: ModuleContext) -> bool:
        return any(v.startswith(_PALLAS) for v in ctx.aliases.values())

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        assigns = _local_assignments(ctx)
        defs = {info.node.name: info.node for info in ctx.functions}
        for call in ast.walk(ctx.tree):
            if not (isinstance(call, ast.Call)
                    and (ctx.resolve(call.func) or "")
                    .endswith("pallas_call")):
                continue
            info = _CallInfo(call, ctx, assigns, defs)
            yield from self._check_arity(info, ctx, assigns)
            yield from self._check_dtypes(info, ctx)
        # grid-position branches: any function in a pallas module that
        # touches program_id/num_programs is kernel code, whether or not
        # this module also holds its pallas_call site
        for fn_info in ctx.functions:
            yield from self._check_grid_branches(fn_info.node, ctx)

    # ------------------------------------------------------ index_map arity
    def _check_arity(self, info: _CallInfo, ctx: ModuleContext,
                     assigns: dict[str, ast.AST]) -> Iterable[Finding]:
        if info.rank is None:
            return
        expected = info.rank + info.n_scalar
        for spec in info.specs:
            imap = spec.args[1] if len(spec.args) > 1 \
                else astutil.keyword(spec, "index_map")
            if isinstance(imap, ast.Name):
                imap = assigns.get(imap.id, imap)
            if not isinstance(imap, (ast.Lambda, ast.FunctionDef)):
                continue
            arity = len(astutil.param_names(imap))
            if arity != expected:
                extra = (f" + {info.n_scalar} scalar-prefetch ref(s)"
                         if info.n_scalar else "")
                yield self.finding(
                    ctx, spec,
                    f"BlockSpec index_map takes {arity} argument(s) but "
                    f"the grid has rank {info.rank}{extra} (expected "
                    f"{expected}); boundary blocks will be mapped to the "
                    "wrong slabs")

    # ------------------------------------------------------------- dtypes
    def _check_dtypes(self, info: _CallInfo, ctx: ModuleContext
                      ) -> Iterable[Finding]:
        if info.kernel is None or not info.out_shapes:
            return
        declared: list[str | None] = []
        for sds in info.out_shapes:
            dt = sds.args[1] if len(sds.args) > 1 \
                else astutil.keyword(sds, "dtype")
            declared.append(_literal_dtype(dt, ctx))
        if not any(declared):
            return
        params = astutil.param_names(info.kernel)
        lo = info.n_scalar + info.n_in
        out_params = params[lo:lo + len(declared)]
        by_name = dict(zip(out_params, declared, strict=False))
        for node in ast.walk(info.kernel):
            if not (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Subscript)
                    and isinstance(node.targets[0].value, ast.Name)):
                continue
            ref = node.targets[0].value.id
            want = by_name.get(ref)
            if want is None:
                continue
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Call) \
                        and isinstance(sub.func, ast.Attribute) \
                        and sub.func.attr == "astype" and sub.args:
                    got = _literal_dtype(sub.args[0], ctx)
                    if got is not None and got != want:
                        yield self.finding(
                            ctx, node,
                            f"kernel stores {got} into `{ref}` but "
                            f"out_shape declares {want}; the value is "
                            "silently converted at the ref boundary",
                            severity=WARNING)

    # ------------------------------------------------- pl.when vs Python if
    def _check_grid_branches(self, kernel: ast.AST, ctx: ModuleContext
                             ) -> Iterable[Finding]:
        grid_names: set[str] = set()
        for node in astutil.walk_no_nested_functions(kernel):
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call):
                dot = ctx.resolve(node.value.func) or ""
                if dot.endswith(_GRID_FNS):
                    grid_names.update(astutil.assign_target_names(node))
        for node in astutil.walk_no_nested_functions(kernel):
            if not isinstance(node, (ast.If, ast.While)):
                continue
            hit = None
            for sub in ast.walk(node.test):
                if isinstance(sub, ast.Call):
                    dot = ctx.resolve(sub.func) or ""
                    if dot.endswith(_GRID_FNS):
                        hit = dot.rsplit(".", 1)[-1]
                elif isinstance(sub, ast.Name) and sub.id in grid_names:
                    hit = sub.id
            if hit is not None:
                yield self.finding(
                    ctx, node,
                    f"Python branch on grid position `{hit}` inside a "
                    "Pallas kernel is evaluated at trace time, not per "
                    "grid step; predicate boundary loads/stores with "
                    "pl.when")
