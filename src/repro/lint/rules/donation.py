"""DONATE: use of a buffer after passing it to a donating executable.

``jax.jit(fn, donate_argnums=...)`` invalidates the donated operand's
buffers the moment the call is dispatched — a later read returns
garbage or raises, and on the fused training path the read also forces
a defensive copy that defeats the donation.  The canonical safe shape
is the rebind: ``state, m = step(state, batch)``.

The rule tracks executables created in the same module via
``g = jax.jit(f, donate_argnums=...)`` (plain-name or ``self.x``
targets) and then linearly scans each scope: after ``g(x)`` donates
``x``, any read of ``x`` before a rebind is flagged.  Loop bodies are
scanned twice so a donation at the bottom of iteration *n* catches the
read at the top of iteration *n+1*.
"""

from __future__ import annotations

import ast
from typing import Iterable

from .. import astutil
from ..engine import ModuleContext
from ..findings import Finding
from ..registry import Rule, register


def _donating_callables(ctx: ModuleContext) -> dict[str, tuple[int, ...]]:
    """Dotted callable name (``g`` / ``self._step``) -> donated
    positional indices."""
    out: dict[str, tuple[int, ...]] = {}
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)
                and ctx.resolve(node.value.func) in ("jax.jit",
                                                     "jax.pmap")):
            continue
        kw = astutil.keyword(node.value, "donate_argnums")
        if kw is None:
            continue
        positions = astutil.int_tuple(kw)
        if not positions:
            continue
        for t in node.targets:
            dotted = astutil.dotted(t, {})
            if dotted:
                out[dotted] = positions
    return out


@register
class DonationRule(Rule):
    name = "DONATE"
    summary = ("argument read after being passed to a donate_argnums "
               "executable (use-after-donate)")

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        donors = _donating_callables(ctx)
        if not donors:
            return
        scopes: list[list[ast.stmt]] = [ctx.tree.body]
        scopes += [info.node.body for info in ctx.functions]
        for body in scopes:
            yield from self._scan_scope(body, donors, ctx)

    def _scan_scope(self, body: list[ast.stmt],
                    donors: dict[str, tuple[int, ...]],
                    ctx: ModuleContext) -> Iterable[Finding]:
        dead: dict[str, tuple[str, int]] = {}      # name -> (callee, line)
        flagged: set[int] = set()
        for stmt in astutil.iter_statements(body, unroll_loops=2):
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            donated_args: list[tuple[ast.Name, str]] = []
            for call in astutil.stmt_nodes(stmt):
                if not isinstance(call, ast.Call):
                    continue
                callee = astutil.dotted(call.func, {})
                if callee not in donors:
                    continue
                for pos in donors[callee]:
                    if pos < len(call.args) \
                            and isinstance(call.args[pos], ast.Name):
                        donated_args.append((call.args[pos], callee))
            # reads of names killed by an EARLIER statement (`dead` is
            # updated below, so a statement's own donation occurrences
            # never see their own kill — the rebind idiom stays clean,
            # while re-donating an already-dead buffer is flagged)
            for node in astutil.stmt_nodes(stmt):
                if isinstance(node, ast.Name) \
                        and isinstance(node.ctx, ast.Load) \
                        and node.id in dead \
                        and id(node) not in flagged:
                    flagged.add(id(node))
                    callee, line = dead[node.id]
                    yield self.finding(
                        ctx, node,
                        f"`{node.id}` is read after being donated to "
                        f"`{callee}` (line {line}); donated buffers are "
                        "invalid after dispatch — rebind the result "
                        "(`x, ... = fn(x, ...)`) or copy before the call")
            for name_node, callee in donated_args:
                dead.setdefault(name_node.id,
                                (callee, name_node.lineno))
            for rebound in astutil.assign_target_names(stmt):
                dead.pop(rebound, None)
