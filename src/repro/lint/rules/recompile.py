"""RECOMPILE: constructs that re-trace or re-compile on the hot path.

Three hazard shapes this stack has actually hit:

* ``jax.jit`` (or ``jax.pmap``) called inside a ``for``/``while`` loop
  or inside a ``@hot_path`` function — every call makes a *new* jitted
  callable with an empty cache, so every call re-traces.  Executables
  must be built once and cached (the engine/runner pattern: build in
  ``__init__`` / ``_build_steps``, call in the loop).  Building a list
  of executables ONCE via a comprehension is fine and not flagged.
* Python ``if``/``while`` on a traced argument inside a jit-compiled
  function — fails at trace time (TracerBoolConversionError) or, when
  the value is marked static, recompiles per distinct value.  Shape/
  dtype attribute branches and ``is None`` checks are static and
  exempt; so are ``static_argnames``/``static_argnums`` parameters.
* Unhashable static arguments: a call site passing a ``list``/``dict``/
  ``set`` literal at a position the executable declared static raises
  at runtime; caught here at lint time.
"""

from __future__ import annotations

import ast
from typing import Iterable

from .. import astutil
from ..engine import ModuleContext
from ..findings import Finding, WARNING
from ..registry import Rule, register

_JIT = {"jax.jit", "jax.pmap"}
# attribute reads on a traced value that produce static python values
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}


def _jit_static_params(call: ast.Call, fn: ast.AST | None
                       ) -> tuple[tuple[int, ...], tuple[str, ...]]:
    nums = astutil.int_tuple(astutil.keyword(call, "static_argnums")
                             or ast.Tuple(elts=[])) or ()
    names = astutil.str_tuple(astutil.keyword(call, "static_argnames")
                              or ast.Tuple(elts=[])) or ()
    if fn is not None and nums:
        params = astutil.param_names(fn)
        names = names + tuple(params[i] for i in nums if i < len(params))
    return nums, names


def _jit_decorator(fn: ast.FunctionDef | ast.AsyncFunctionDef,
                   ctx: ModuleContext) -> ast.Call | None | bool:
    """jit decoration of ``fn``: the decorating Call (to read static
    args), True for a bare ``@jax.jit``, None if not jitted."""
    for dec in fn.decorator_list:
        if ctx.resolve(dec) in _JIT:
            return True
        if isinstance(dec, ast.Call):
            dot = ctx.resolve(dec.func)
            if dot in _JIT:
                return dec
            if dot in ("functools.partial", "partial") and dec.args \
                    and ctx.resolve(dec.args[0]) in _JIT:
                return dec
    return None


@register
class RecompileRule(Rule):
    name = "RECOMPILE"
    summary = ("jax.jit per call site (in a loop / hot path), Python "
               "branches on traced arguments, unhashable static args")

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        yield from self._jit_call_sites(ctx)
        yield from self._traced_branches(ctx)
        yield from self._unhashable_statics(ctx)

    # -------------------------------------------------- jit-in-loop/hot-path
    def _jit_call_sites(self, ctx: ModuleContext) -> Iterable[Finding]:
        for call in ctx.calls(*_JIT):
            fn = astutil.enclosing_function(call)
            if astutil.enclosing_loop(call) is not None:
                yield self.finding(
                    ctx, call,
                    "jax.jit inside a loop builds a fresh executable "
                    "(and re-traces) every iteration; hoist it and cache "
                    "the jitted callable")
                continue
            info = ctx.function_info(fn) if fn is not None else None
            if info is not None and info.is_hot:
                yield self.finding(
                    ctx, call,
                    "jax.jit inside a @hot_path function compiles per "
                    "call; build the executable once at setup and call "
                    "it here")

    # ------------------------------------------------------- traced branches
    def _traced_functions(self, ctx: ModuleContext
                          ) -> Iterable[tuple[ast.AST, tuple[str, ...]]]:
        defs = {info.node.name: info.node for info in ctx.functions}
        seen: set[ast.AST] = set()
        for info in ctx.functions:
            dec = _jit_decorator(info.node, ctx)
            if dec is not None and info.node not in seen:
                seen.add(info.node)
                call = dec if isinstance(dec, ast.Call) else \
                    ast.Call(func=ast.Name(id="jit"), args=[], keywords=[])
                _, static = _jit_static_params(call, info.node)
                yield info.node, static
        # jax.jit(fn, ...) over a module-local def
        for call in ctx.calls(*_JIT):
            if call.args and isinstance(call.args[0], ast.Name):
                fn = defs.get(call.args[0].id)
                if fn is not None and fn not in seen:
                    seen.add(fn)
                    _, static = _jit_static_params(call, fn)
                    yield fn, static

    def _traced_branches(self, ctx: ModuleContext) -> Iterable[Finding]:
        for fn, static in self._traced_functions(ctx):
            params = set(astutil.param_names(fn)) - set(static)
            for node in astutil.walk_no_nested_functions(fn):
                if not isinstance(node, (ast.If, ast.While)):
                    continue
                name = self._traced_name_in_test(node.test, params)
                if name is not None:
                    yield self.finding(
                        ctx, node,
                        f"Python branch on traced argument `{name}` "
                        "inside a jit-compiled function fails at trace "
                        "time or forces per-value recompilation; use "
                        "jax.lax.cond / jnp.where, or mark the argument "
                        "static", severity=WARNING)

    @staticmethod
    def _traced_name_in_test(test: ast.AST, params: set[str]
                             ) -> str | None:
        if isinstance(test, ast.Compare) and \
                any(isinstance(op, (ast.Is, ast.IsNot))
                    for op in test.ops):
            return None                         # `x is None` is static
        for node in ast.walk(test):
            if isinstance(node, ast.Call):
                dot_ok = isinstance(node.func, ast.Name) and \
                    node.func.id in ("isinstance", "len", "callable")
                if dot_ok:
                    return None                 # static-shaped predicate
            if isinstance(node, ast.Name) and node.id in params:
                par = astutil.parent(node)
                if isinstance(par, ast.Attribute) \
                        and par.attr in _STATIC_ATTRS:
                    continue                    # x.shape / x.ndim: static
                return node.id
        return None

    # --------------------------------------------------- unhashable statics
    def _unhashable_statics(self, ctx: ModuleContext) -> Iterable[Finding]:
        jitted: dict[str, tuple[tuple[int, ...], tuple[str, ...]]] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call) \
                    and ctx.resolve(node.value.func) in _JIT:
                nums, names = _jit_static_params(node.value, None)
                if not (nums or names):
                    continue
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        jitted[t.id] = (nums, names)
        if not jitted:
            return
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in jitted):
                continue
            nums, names = jitted[node.func.id]
            bad: list[ast.AST] = []
            bad += [a for i, a in enumerate(node.args) if i in nums
                    and isinstance(a, (ast.List, ast.Dict, ast.Set))]
            bad += [kw.value for kw in node.keywords if kw.arg in names
                    and isinstance(kw.value, (ast.List, ast.Dict,
                                              ast.Set))]
            for arg in bad:
                yield self.finding(
                    ctx, arg,
                    f"unhashable literal passed at a static position of "
                    f"`{node.func.id}`; static args must be hashable "
                    "(use a tuple / frozenset)")
