"""AST conveniences shared by the rules: parent links, import-alias
resolution, dotted-name rendering, and lightweight value provenance.

Everything here is best-effort static analysis: when a construct can't
be resolved (dynamic attribute, re-exported name, computed call) the
helpers return ``None`` and rules stay silent rather than guess.
"""

from __future__ import annotations

import ast
from typing import Iterator

_PARENT = "_repro_lint_parent"


def attach_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            setattr(child, _PARENT, node)


def parent(node: ast.AST) -> ast.AST | None:
    return getattr(node, _PARENT, None)


def ancestors(node: ast.AST) -> Iterator[ast.AST]:
    cur = parent(node)
    while cur is not None:
        yield cur
        cur = parent(cur)


def enclosing_function(node: ast.AST) -> ast.AST | None:
    for anc in ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return anc
    return None


def enclosing_loop(node: ast.AST, *, stop: ast.AST | None = None
                   ) -> ast.AST | None:
    """Nearest For/While statement ancestor, not crossing ``stop`` (nor
    any function boundary — a loop outside the enclosing function does
    not make a call site "inside a loop")."""
    for anc in ancestors(node):
        if anc is stop or isinstance(anc, (ast.FunctionDef,
                                           ast.AsyncFunctionDef,
                                           ast.Lambda)):
            return None
        if isinstance(anc, (ast.For, ast.While)):
            return anc
    return None


def collect_aliases(tree: ast.AST) -> dict[str, str]:
    """Local name -> dotted module path, from every import statement.

    ``import jax.numpy as jnp`` -> ``{"jnp": "jax.numpy"}``;
    ``from jax import random`` -> ``{"random": "jax.random"}``;
    relative imports are left as their bare names (never a hazard
    target here).
    """
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    root = a.name.split(".")[0]
                    aliases[root] = root
        elif isinstance(node, ast.ImportFrom) and node.module \
                and not node.level:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def dotted(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Render an attribute chain as a dotted path with the root name
    expanded through the import aliases; ``None`` if the chain bottoms
    out in anything but a plain name."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(aliases.get(node.id, node.id))
        return ".".join(reversed(parts))
    return None


def root_name(node: ast.AST) -> str | None:
    """The base ``Name`` under a Subscript/Attribute/Call chain."""
    while isinstance(node, (ast.Subscript, ast.Attribute, ast.Starred)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def keyword(call: ast.Call, name: str) -> ast.AST | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def const_value(node: ast.AST):
    """The value of a Constant node, else a ``_MISSING`` sentinel."""
    if isinstance(node, ast.Constant):
        return node.value
    return _MISSING


_MISSING = object()


def int_tuple(node: ast.AST) -> tuple[int, ...] | None:
    """Literal int or tuple-of-ints, e.g. ``donate_argnums=(0, 2)``."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if not (isinstance(elt, ast.Constant)
                    and isinstance(elt.value, int)):
                return None
            out.append(elt.value)
        return tuple(out)
    return None


def str_tuple(node: ast.AST) -> tuple[str, ...] | None:
    """Literal str or tuple/list-of-str, e.g. static_argnames."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if not (isinstance(elt, ast.Constant)
                    and isinstance(elt.value, str)):
                return None
            out.append(elt.value)
        return tuple(out)
    return None


def param_names(fn: ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda
                ) -> list[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def iter_statements(body: list[ast.stmt], *, unroll_loops: int = 1
                    ) -> Iterator[ast.stmt]:
    """Flatten a statement list in source order, descending into
    compound statements.  ``unroll_loops=2`` yields each loop body
    twice, which lets linear-scan rules catch wrap-around hazards
    (a key consumed every iteration, a read at the top of iteration
    *n+1* of a buffer donated at the bottom of iteration *n*)."""
    for stmt in body:
        yield stmt
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            for _ in range(unroll_loops):
                yield from iter_statements(stmt.body,
                                           unroll_loops=unroll_loops)
            yield from iter_statements(stmt.orelse,
                                       unroll_loops=unroll_loops)
        elif isinstance(stmt, ast.If):
            yield from iter_statements(stmt.body, unroll_loops=unroll_loops)
            yield from iter_statements(stmt.orelse,
                                       unroll_loops=unroll_loops)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            yield from iter_statements(stmt.body, unroll_loops=unroll_loops)
        elif isinstance(stmt, ast.Try):
            for blk in (stmt.body, stmt.orelse, stmt.finalbody):
                yield from iter_statements(blk, unroll_loops=unroll_loops)
            for handler in stmt.handlers:
                yield from iter_statements(handler.body,
                                           unroll_loops=unroll_loops)


def stmt_nodes(stmt: ast.stmt) -> Iterator[ast.AST]:
    """AST nodes belonging to one statement, excluding nested statement
    bodies — compound-statement children are visited when
    :func:`iter_statements` yields them, so linear-scan rules that pair
    the two don't double-count."""
    if isinstance(stmt, (ast.If, ast.While)):
        roots: list[ast.AST] = [stmt.test]
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        roots = [stmt.target, stmt.iter]
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        roots = [i.context_expr for i in stmt.items]
        roots += [i.optional_vars for i in stmt.items if i.optional_vars]
    elif isinstance(stmt, ast.Try):
        roots = []
    else:
        roots = [stmt]
    for r in roots:
        yield from ast.walk(r)


def walk_no_nested_functions(node: ast.AST) -> Iterator[ast.AST]:
    """Walk ``node`` without descending into nested function/class
    definitions (their scopes are analyzed separately)."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        cur = stack.pop()
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda, ast.ClassDef)):
            continue
        yield cur
        stack.extend(ast.iter_child_nodes(cur))


def assign_target_names(stmt: ast.stmt) -> list[str]:
    """Plain names (re)bound by an assignment-like statement."""
    targets: list[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        targets = [stmt.target]
    out: list[str] = []

    def add(t: ast.AST) -> None:
        if isinstance(t, ast.Name):
            out.append(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for elt in t.elts:
                add(elt)
        elif isinstance(t, ast.Starred):
            add(t.value)

    for t in targets:
        add(t)
    return out
