"""repro.lint — JAX-aware static analysis for this repo's invariants.

Pure stdlib (``ast``): importing this package never imports jax, so the
linter runs in bare CI containers.  Entry points::

    python -m repro.lint src/repro          # CLI (scripts/lint.py wraps)
    from repro.lint import lint_text        # test / tooling API
    from repro.lint import hot_path         # runtime hot-path marker

Rule catalogue and suppression syntax: ``src/repro/lint/README.md``.
"""

from .engine import lint_paths, lint_text
from .findings import ERROR, WARNING, Finding
from .hotpath import EXTRA_HOT_PATHS, hot_path
from .registry import Rule, all_rules, register

__all__ = ["lint_paths", "lint_text", "Finding", "ERROR", "WARNING",
           "hot_path", "EXTRA_HOT_PATHS", "Rule", "all_rules",
           "register"]
