"""The pre-engine serving loop, preserved as reference semantics.

This is the old ``InferenceSession.generate`` verbatim: one fixed batch at
a time, a fresh full-size KV cache per call, a Python decode loop that
runs every sequence to ``max_new_tokens`` with no EOS exit.  It exists so
the engine has an oracle (greedy-equivalence tests) and a baseline
(``benchmarks/bench_serve.py``) — production code should use
:class:`~repro.serve.engine.ServeEngine`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..runtime.step import make_decode_step, make_prefill_step

__all__ = ["NaiveLoop", "naive_generate"]


class NaiveLoop:
    """Per-batch greedy decoding with jitted prefill/decode steps."""

    def __init__(self, model, params, *, frontend: str | None = None):
        self.model = model
        self.params = params
        self.frontend = frontend
        self.prefill = jax.jit(make_prefill_step(model,
                                                 with_frontend=frontend))
        self.decode = jax.jit(make_decode_step(model))

    def generate(self, tokens: jax.Array, max_new_tokens: int = 16,
                 *extra) -> jax.Array:
        """Prefill ``tokens`` ``[B, S]`` then decode greedily to the full
        budget (no EOS exit — the old loop's behavior)."""
        b, s = tokens.shape
        if max_new_tokens <= 0:
            return jnp.zeros((b, 0), jnp.int32)
        # vision prefixes occupy cache positions before the prompt
        prefix = extra[0].shape[1] if (self.frontend == "vision"
                                       and extra) else 0
        cache = self.model.init_cache(b, prefix + s + max_new_tokens)
        logits, cache = self.prefill(self.params, tokens, cache, *extra)
        out = [jnp.argmax(logits, -1).astype(jnp.int32)]
        for i in range(max_new_tokens - 1):
            pos = jnp.full((b,), prefix + s + i, jnp.int32)
            logits, cache = self.decode(self.params, cache, out[-1], pos)
            out.append(jnp.argmax(logits, -1).astype(jnp.int32))
        return jnp.concatenate(out, axis=1)


def naive_generate(model, params, tokens, max_new_tokens: int = 16,
                   *extra, frontend: str | None = None) -> jax.Array:
    """One-shot helper around :class:`NaiveLoop` (re-jits per call, like
    the old ``Session.serve()`` did)."""
    return NaiveLoop(model, params, frontend=frontend).generate(
        tokens, max_new_tokens, *extra)
