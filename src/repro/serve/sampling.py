"""Per-slot token sampling: greedy / temperature / top-k, seeded.

All three modes compile into one branch-free executable so a batch can mix
greedy and sampled requests lane-by-lane: temperature 0 selects the argmax
path via ``jnp.where``, ``top_k == 0`` disables truncation by using the
full vocabulary as the cutoff rank.  Each lane carries its own PRNG key
(split once per emitted token), so a request's token stream depends only
on its own ``SamplingParams.seed`` — never on batch composition.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["make_token_sampler"]


def make_token_sampler(vocab: int):
    """Build ``sample(logits [S, V], temp [S], top_k [S], key [S, 2]) ->
    tokens [S]`` (vmapped over the slot axis)."""

    def sample_one(logits, temp, top_k, key):
        greedy = jnp.argmax(logits).astype(jnp.int32)
        k = jnp.where(top_k > 0, top_k, vocab)
        desc = jnp.sort(logits)[::-1]
        thresh = desc[jnp.clip(k - 1, 0, vocab - 1)]
        masked = jnp.where(logits >= thresh, logits, -jnp.inf)
        scaled = masked / jnp.maximum(temp, 1e-6)
        sampled = jax.random.categorical(key, scaled).astype(jnp.int32)
        return jnp.where(temp > 0.0, sampled, greedy)

    return jax.vmap(sample_one)
