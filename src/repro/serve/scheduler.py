"""Continuous-batching scheduler: waiting queue -> slots -> completions.

Decode-priority policy: running requests decode every tick; at each tick
boundary the scheduler admits waiting requests into freed slots, FIFO, up
to the per-tick prefill budget and the engine's ``max_batch`` — so a long
prefill backlog interleaves with decoding instead of stalling it (the
DreamDDP lesson applied to serving: schedule heterogeneous work
fine-grained instead of in monolithic batches).

The scheduler is pure bookkeeping (host-side); all device work lives in
the engine.  Per-request progress is tracked in :class:`RequestState`.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Hashable

from .cache import CachePool
from .types import Request

__all__ = ["RequestState", "Scheduler"]


@dataclass
class RequestState:
    """Host-side progress record for one submitted request."""

    request: Request
    on_token: Callable | None = None       # (request_id, token, index)
    submit_t: float = 0.0
    first_token_t: float | None = None
    slot: int | None = None
    tokens: list[int] = field(default_factory=list)
    finish_reason: str = "length"
    need_tokens: int = 0                   # worst-case cache footprint

    @property
    def n_generated(self) -> int:
        return len(self.tokens)

    def emit(self, token: int) -> None:
        self.tokens.append(token)
        if self.on_token is not None:
            self.on_token(self.request.request_id, token,
                          len(self.tokens) - 1)


class Scheduler:
    """FIFO admission into a :class:`CachePool`, decode-priority."""

    def __init__(self, pool: CachePool, *, max_batch: int,
                 max_prefills_per_tick: int | None = None):
        self.pool = pool
        self.max_batch = max_batch
        self.max_prefills_per_tick = max_prefills_per_tick
        self.waiting: deque[RequestState] = deque()
        self.running: dict[int, RequestState] = {}     # slot -> state
        self.in_flight_ids: set[Any] = set()           # waiting + running

    # --------------------------------------------------------------- queues
    def submit(self, rs: RequestState) -> None:
        rid = rs.request.request_id
        if rid in self.in_flight_ids:
            raise ValueError(
                f"request_id {rid!r} is already in flight — completions "
                "are keyed by id, so a duplicate would be silently "
                "dropped; wait for the first submission to finish or use "
                "a fresh id")
        self.in_flight_ids.add(rid)
        self.waiting.append(rs)

    def admissions(self) -> list[tuple[int, RequestState]]:
        """Pop (slot, request) pairs admissible this tick.

        Admission is FIFO and capacity-aware: the head request's
        worst-case footprint (``need_tokens``) is offered to the pool,
        and a paged pool that cannot commit enough pages rejects the
        admission — the request stays queued (head-of-line, so ordering
        is preserved) until retirements free capacity.
        """
        budget = self.max_prefills_per_tick
        out: list[tuple[int, RequestState]] = []
        while self.waiting and len(self.running) < self.max_batch \
                and (budget is None or len(out) < budget):
            slot = self.pool.alloc(self.waiting[0].need_tokens)
            if slot is None:
                break
            rs = self.waiting.popleft()
            rs.slot = slot
            self.running[slot] = rs
            out.append((slot, rs))
        return out

    def admission_groups(self, key: Callable[[RequestState], Hashable]
                         ) -> list[tuple[Hashable, list[tuple[int,
                                                              "RequestState"]]]]:
        """Pop this tick's admissions and group them by prefill bucket.

        Admission itself stays FIFO and capacity-aware (exactly
        :meth:`admissions` — grouping never changes *who* is admitted,
        only how the admitted set is executed): the popped set is
        partitioned by ``key(rs)`` — the engine's prefill-shape bucket
        (padded prompt length, refeed-or-not, frontend extra shapes) —
        so each group can prefill in one slot-batched call.  Groups come
        back in first-appearance order; members keep FIFO order.
        """
        groups: dict[Hashable, list[tuple[int, RequestState]]] = {}
        for slot, rs in self.admissions():
            groups.setdefault(key(rs), []).append((slot, rs))
        return list(groups.items())

    def finish(self, slot: int) -> RequestState:
        """Retire the request in ``slot`` and free the slot for reuse."""
        rs = self.running.pop(slot)
        rs.slot = None
        self.in_flight_ids.discard(rs.request.request_id)
        self.pool.free(slot)
        return rs

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def reset(self) -> None:
        self.waiting.clear()
        self.running.clear()
        self.in_flight_ids.clear()
        self.pool.reset()
