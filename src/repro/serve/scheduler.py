"""Continuous-batching scheduler: waiting queue -> slots -> completions.

Decode-priority policy: running requests decode every tick; at each tick
boundary the scheduler admits waiting requests into freed slots, FIFO, up
to the per-tick prefill budget and the engine's ``max_batch`` — so a long
prefill backlog interleaves with decoding instead of stalling it (the
DreamDDP lesson applied to serving: schedule heterogeneous work
fine-grained instead of in monolithic batches).

The scheduler is pure bookkeeping (host-side); all device work lives in
the engine.  Per-request progress is tracked in :class:`RequestState`.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable

from .cache import CachePool
from .types import Request

__all__ = ["RequestState", "Scheduler"]


@dataclass
class RequestState:
    """Host-side progress record for one submitted request."""

    request: Request
    on_token: Callable | None = None       # (request_id, token, index)
    submit_t: float = 0.0
    first_token_t: float | None = None
    slot: int | None = None
    tokens: list[int] = field(default_factory=list)
    finish_reason: str = "length"
    need_tokens: int = 0                   # worst-case cache footprint

    @property
    def n_generated(self) -> int:
        return len(self.tokens)

    def emit(self, token: int) -> None:
        self.tokens.append(token)
        if self.on_token is not None:
            self.on_token(self.request.request_id, token,
                          len(self.tokens) - 1)


class Scheduler:
    """FIFO admission into a :class:`CachePool`, decode-priority."""

    def __init__(self, pool: CachePool, *, max_batch: int,
                 max_prefills_per_tick: int | None = None):
        self.pool = pool
        self.max_batch = max_batch
        self.max_prefills_per_tick = max_prefills_per_tick
        self.waiting: deque[RequestState] = deque()
        self.running: dict[int, RequestState] = {}     # slot -> state

    # --------------------------------------------------------------- queues
    def submit(self, rs: RequestState) -> None:
        self.waiting.append(rs)

    def admissions(self) -> list[tuple[int, RequestState]]:
        """Pop (slot, request) pairs admissible this tick.

        Admission is FIFO and capacity-aware: the head request's
        worst-case footprint (``need_tokens``) is offered to the pool,
        and a paged pool that cannot commit enough pages rejects the
        admission — the request stays queued (head-of-line, so ordering
        is preserved) until retirements free capacity.
        """
        budget = self.max_prefills_per_tick
        out: list[tuple[int, RequestState]] = []
        while self.waiting and len(self.running) < self.max_batch \
                and (budget is None or len(out) < budget):
            slot = self.pool.alloc(self.waiting[0].need_tokens)
            if slot is None:
                break
            rs = self.waiting.popleft()
            rs.slot = slot
            self.running[slot] = rs
            out.append((slot, rs))
        return out

    def finish(self, slot: int) -> RequestState:
        """Retire the request in ``slot`` and free the slot for reuse."""
        rs = self.running.pop(slot)
        rs.slot = None
        self.pool.free(slot)
        return rs

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def reset(self) -> None:
        self.waiting.clear()
        self.running.clear()
        self.pool.reset()
