"""EngineConfig — the (hashable) shape contract of a ``ServeEngine``.

Everything that determines a compiled executable's shapes lives here, so
one config = one warm set of jitted steps: the KV arena is ``[layers,
n_slots, max_seq, ...]``, the fused decode block always runs over all
``n_slots`` lanes, and prefill compiles once per distinct prompt length
(or once per ``prefill_chunk`` bucket when chunked prefill is enabled).
Admitting or finishing a request never changes a shape, so it never
recompiles and never reallocates.
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["EngineConfig"]


@dataclass(frozen=True)
class EngineConfig:
    """Static serving-engine shape/scheduling parameters.

    ``max_batch``
        Cap on concurrently *running* requests (scheduler admission limit).
    ``max_seq``
        Per-slot cache capacity; every request needs
        ``prefix + len(prompt) + max_new_tokens <= max_seq`` (``prefix`` =
        vision patch count for VLM frontends, else 0).
    ``n_slots``
        KV-cache slots in the arena (``None`` = ``max_batch``).  The fused
        decode step is compiled for exactly this width.
    ``prefill_chunk``
        If set, prompt lengths are right-padded up to a multiple of this
        value so at most ``max_seq / prefill_chunk`` prefill executables
        ever exist; the true last-prompt-token logits are recovered with
        one extra decode step.  Only valid for position-indexed
        (attention-KV) caches — recurrent-state families (mamba2,
        recurrentgemma) fold padding steps into their state, so the
        engine rejects the option for models without
        ``kv_position_indexed`` (use the default, ``None``).
    ``decode_block``
        Decode ticks fused into one jitted ``lax.while_loop`` between
        scheduler interventions (admission happens at block boundaries).
        The block exits early once every lane is inactive.
    ``max_prefills_per_tick``
        Admission budget per scheduler tick (``None`` = fill every free
        slot).  Lower values keep decode latency smooth under a prefill
        backlog ("decode-priority" interleave).
    """

    max_batch: int = 8
    max_seq: int = 256
    n_slots: int | None = None
    prefill_chunk: int | None = None
    decode_block: int = 8
    max_prefills_per_tick: int | None = None

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.n_slots is not None and self.n_slots < self.max_batch:
            raise ValueError("n_slots must be >= max_batch")
        if self.decode_block < 1:
            raise ValueError("decode_block must be >= 1")
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")

    @property
    def slots(self) -> int:
        return self.n_slots if self.n_slots is not None else self.max_batch
