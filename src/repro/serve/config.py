"""EngineConfig — the (hashable) shape contract of a ``ServeEngine``.

Everything that determines a compiled executable's shapes lives here, so
one config = one warm set of jitted steps: the KV arena is ``[layers,
n_slots, max_seq, ...]``, the fused decode block always runs over all
``n_slots`` lanes, and prefill compiles once per distinct prompt length
(or once per ``prefill_chunk`` bucket when chunked prefill is enabled).
Admitting or finishing a request never changes a shape, so it never
recompiles and never reallocates.
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["EngineConfig"]


@dataclass(frozen=True)
class EngineConfig:
    """Static serving-engine shape/scheduling parameters.

    ``max_batch``
        Cap on concurrently *running* requests (scheduler admission limit).
    ``max_seq``
        Per-slot cache capacity; every request needs
        ``prefix + len(prompt) + max_new_tokens <= max_seq`` (``prefix`` =
        vision patch count for VLM frontends, else 0).
    ``n_slots``
        KV-cache slots in the arena (``None`` = ``max_batch``).  The fused
        decode step is compiled for exactly this width.
    ``prefill_chunk``
        If set, prompt lengths are right-padded up to a multiple of this
        value so at most ``max_seq / prefill_chunk`` prefill executables
        ever exist; the true last-prompt-token logits are recovered with
        one extra decode step.  Only valid for position-indexed
        (attention-KV) caches — recurrent-state families (mamba2,
        recurrentgemma) fold padding steps into their state, so the
        engine rejects the option for models without
        ``kv_position_indexed`` (use the default, ``None``).
    ``decode_block``
        Decode ticks fused into one jitted ``lax.while_loop`` between
        scheduler interventions (admission happens at block boundaries).
        The block exits early once every lane is inactive.
    ``max_prefills_per_tick``
        Admission budget per scheduler tick (``None`` = fill every free
        slot).  Lower values keep decode latency smooth under a prefill
        backlog ("decode-priority" interleave).
    ``kv_backend``
        ``"contiguous"`` (default): one ``max_seq``-deep lane per slot.
        ``"paged"``: KV lives in ``page_size``-token pages of a shared
        pool addressed through per-slot block tables
        (:class:`repro.serve.cache.PagedCachePool`), so each request only
        holds its own footprint.  KV-cache families (transformer / moe /
        mla, incl. the vision frontend) support it; recurrent-state
        families (mamba2, recurrentgemma) and the audio cross-KV decoder
        have fixed-size lanes with nothing to page and reject it.
    ``page_size``
        Tokens per KV page (paged backend only).  ``max_seq`` must be a
        multiple of it.
    ``kv_pages``
        Total pages in the pool, including the reserved trash page
        (``None`` = worst case, ``n_slots * max_seq / page_size + 1`` —
        the contiguous footprint).  Sizing it below worst case is where
        the memory win comes from: admission defers (requests queue)
        instead of over-committing when pages run short.
    ``batched_admission``
        ``True`` (default): each tick's admissions are grouped by
        prefill-shape bucket and every group prefills in ONE
        slot-batched call, with all first tokens of the tick landing in
        a single host sync — the fix for per-request prefill dispatch
        serializing admission-heavy traffic.  ``False`` keeps the
        original one-prefill-one-sync-per-request path (the equivalence
        oracle; token streams are identical under greedy decoding).
    ``completed_cap``
        Retained-history bound for completions nobody drains: the
        engine keeps at most this many finished :class:`Completion`
        records for :meth:`~repro.serve.engine.ServeEngine.take_completed`
        (oldest dropped first), so a long-running server that never
        calls ``reset()`` holds bounded memory.
    """

    max_batch: int = 8
    max_seq: int = 256
    n_slots: int | None = None
    prefill_chunk: int | None = None
    decode_block: int = 8
    max_prefills_per_tick: int | None = None
    kv_backend: str = "contiguous"
    page_size: int = 16
    kv_pages: int | None = None
    batched_admission: bool = True
    completed_cap: int = 1024

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.n_slots is not None and self.n_slots < self.max_batch:
            raise ValueError("n_slots must be >= max_batch")
        if self.decode_block < 1:
            raise ValueError("decode_block must be >= 1")
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if self.completed_cap < 1:
            raise ValueError("completed_cap must be >= 1")
        if self.kv_backend not in ("contiguous", "paged"):
            raise ValueError(
                f"kv_backend must be 'contiguous' or 'paged', "
                f"got {self.kv_backend!r}")
        if self.kv_backend == "paged":
            if self.page_size < 1:
                raise ValueError("page_size must be >= 1")
            if self.max_seq % self.page_size:
                raise ValueError(
                    f"max_seq={self.max_seq} must be a multiple of "
                    f"page_size={self.page_size}")
            if self.kv_pages is not None and self.kv_pages < 2:
                raise ValueError("kv_pages must be >= 2 (page 0 is "
                                 "the reserved trash page)")

    @property
    def slots(self) -> int:
        return self.n_slots if self.n_slots is not None else self.max_batch
