"""Serving request/response dataclasses — the engine's public data model.

A :class:`Request` is pure data: prompt tokens, a generation budget, a
:class:`SamplingParams`, an optional EOS token, and optional frontend
``extra`` inputs (audio frames / vision patch embeddings, unbatched).  The
engine answers with a :class:`Completion` and aggregates run-level numbers
into :class:`EngineStats`.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

__all__ = ["SamplingParams", "Request", "Completion", "EngineStats"]

_ids = itertools.count()


@dataclass(frozen=True)
class SamplingParams:
    """How the next token is chosen from the logits.

    ``temperature == 0`` is greedy argmax (the default, and the mode the
    engine/naive equivalence guarantees cover).  With ``temperature > 0``
    the distribution is optionally truncated to the ``top_k`` highest
    logits (``0`` = no truncation) and sampled with a PRNG stream derived
    from ``seed`` — the same request with the same seed always yields the
    same tokens, regardless of what else shares the batch.
    """

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


@dataclass
class Request:
    """One generation request (pure data; the engine never mutates it)."""

    tokens: Sequence[int]                  # prompt token ids
    max_new_tokens: int = 16
    sampling: SamplingParams = field(default_factory=SamplingParams)
    eos_id: int | None = None              # stop token (None: run to budget)
    extra: tuple = ()                      # frontend inputs, each [n, d]
    request_id: int = field(default_factory=lambda: next(_ids))


@dataclass
class Completion:
    """The engine's answer for one request."""

    request_id: int
    tokens: list[int]                      # generated ids (EOS included)
    n_prompt: int
    finish_reason: str                     # "stop" (EOS) | "length"
    ttft_s: float = 0.0                    # submit -> first token
    latency_s: float = 0.0                 # submit -> finished


@dataclass
class EngineStats:
    """Aggregate serving statistics, reported by ``ServeEngine.stats``."""

    requests_completed: int = 0
    prompt_tokens: int = 0
    generated_tokens: int = 0
    prefill_time_s: float = 0.0
    decode_time_s: float = 0.0
    decode_ticks: int = 0                  # fused-block invocations
    prefill_batches: int = 0               # slot-batched prefill launches
    admit_ticks: int = 0                   # ticks that admitted >= 1 request
                                           # (= shared first-token host syncs
                                           # under batched admission)
    slot_ticks_active: int = 0             # sum over ticks of active slots
    slot_ticks_total: int = 0              # ticks x slots (utilization denom)
    ttft_s: list[float] = field(default_factory=list)
    latency_s: list[float] = field(default_factory=list)

    # ------------------------------------------------------------- derived
    @property
    def decode_tokens(self) -> int:
        """Tokens emitted by decode ticks (each active slot-tick emits
        exactly one); excludes the per-request first token, which prefill
        produces."""
        return self.slot_ticks_active

    @property
    def decode_tokens_per_s(self) -> float:
        return self.decode_tokens / self.decode_time_s \
            if self.decode_time_s > 0 else 0.0

    @property
    def total_time_s(self) -> float:
        return self.prefill_time_s + self.decode_time_s

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / self.total_time_s \
            if self.total_time_s > 0 else 0.0

    @property
    def mean_ttft_s(self) -> float:
        return sum(self.ttft_s) / len(self.ttft_s) if self.ttft_s else 0.0

    @property
    def mean_latency_s(self) -> float:
        return sum(self.latency_s) / len(self.latency_s) \
            if self.latency_s else 0.0

    @property
    def slot_utilization(self) -> float:
        return self.slot_ticks_active / self.slot_ticks_total \
            if self.slot_ticks_total else 0.0

    def as_dict(self) -> dict[str, Any]:
        return {
            "requests_completed": self.requests_completed,
            "prompt_tokens": self.prompt_tokens,
            "generated_tokens": self.generated_tokens,
            "prefill_time_s": self.prefill_time_s,
            "decode_time_s": self.decode_time_s,
            "decode_ticks": self.decode_ticks,
            "prefill_batches": self.prefill_batches,
            "admit_ticks": self.admit_ticks,
            "decode_tokens": self.decode_tokens,
            "decode_tokens_per_s": self.decode_tokens_per_s,
            "tokens_per_s": self.tokens_per_s,
            "mean_ttft_s": self.mean_ttft_s,
            "mean_latency_s": self.mean_latency_s,
            "slot_utilization": self.slot_utilization,
        }


OnToken = Callable[[int, int, int], None]  # (request_id, token, index)
