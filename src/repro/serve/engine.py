"""ServeEngine — continuous-batching inference over a slot-pooled cache.

The engine replaces the ad-hoc per-batch greedy loop with a declarative
pipeline: requests are **data** (:class:`~repro.serve.types.Request`), the
admission policy is an object (:class:`~repro.serve.scheduler.Scheduler`),
and the decode hot path is one fused, jitted ``lax.while_loop`` over the
whole slot set with per-slot EOS/length masking — finished lanes stop
emitting and the block exits early once every lane is done.

Shapes are fixed by :class:`~repro.serve.config.EngineConfig`: each tick's
admissions are grouped by prefill-shape bucket and every group prefills
its slots in ONE slot-batched launch (compiled once per (group size,
bucket length)), all first tokens of the tick reaching the host in a
single sync; every decode tick runs the same ``[n_slots]``-wide
executable regardless of how many requests are in flight — admission and
retirement never reallocate, and the executable set stays bounded.
``EngineConfig(batched_admission=False)`` keeps the original
one-prefill-per-request path, the equivalence oracle: both paths emit
token-for-token identical streams under greedy decoding.

Two entry points::

    engine.generate(requests)              # synchronous, list[Completion]
    rid = engine.submit(req, on_token=cb)  # incremental / streaming
    while engine.has_work:
        engine.step()                      # one admission + decode tick
"""

from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..lint import hot_path
from ..runtime.step import (make_prefill_step, make_slot_decode_step,
                            make_slot_decode_step_paged,
                            make_slot_prefill_step,
                            make_slot_prefill_step_batched,
                            make_slot_refeed_step,
                            make_slot_refeed_step_batched)
from .cache import (CachePool, PagedCachePool, make_prefill_scatter,
                    make_prefill_scatter_batched)
from .config import EngineConfig
from .sampling import make_token_sampler
from .scheduler import RequestState, Scheduler
from .types import Completion, EngineStats, Request, SamplingParams

__all__ = ["ServeEngine"]

PyTree = Any


class _SlotState(NamedTuple):
    """Per-slot decode state, all arrays ``[n_slots]`` (``key``: ``[n_slots,
    2]``).  ``pos`` is the next KV write index; ``token`` the last sampled
    token (fed to the next decode tick)."""

    token: jax.Array
    pos: jax.Array
    ngen: jax.Array
    active: jax.Array
    temp: jax.Array
    top_k: jax.Array
    key: jax.Array
    eos: jax.Array
    max_gen: jax.Array


def _init_slot_state(n_slots: int) -> _SlotState:
    i32 = jnp.int32
    return _SlotState(
        token=jnp.zeros((n_slots,), i32),
        pos=jnp.zeros((n_slots,), i32),
        ngen=jnp.zeros((n_slots,), i32),
        active=jnp.zeros((n_slots,), bool),
        temp=jnp.zeros((n_slots,), jnp.float32),
        top_k=jnp.zeros((n_slots,), i32),
        key=jnp.zeros((n_slots, 2), jnp.uint32),
        eos=jnp.full((n_slots,), -1, i32),
        max_gen=jnp.zeros((n_slots,), i32),
    )


def _make_decode_block(model, vocab: int, n_steps: int, *,
                       paged: bool = False):
    """Fused multi-token decode: ``n_steps`` slot-wide ticks in one
    ``lax.while_loop``, exiting early when no lane is active.

    Inactive lanes are masked, not skipped: their emitted token is ``-1``,
    their ``pos``/``ngen``/``token`` freeze, and whatever their decode
    lane writes into the arena lands beyond any active frontier (masked by
    ``kv_valid_len`` / overwritten by the next prefill), so it is
    unobservable.  With ``paged=True`` the arena is the page pool and the
    block takes the per-slot block tables as an extra operand (constant
    across the block's ticks — page extension happens at block
    boundaries); inactive lanes' writes are routed to the trash page by
    the ``active`` mask instead of landing beyond a frontier.
    """
    slot_decode = (make_slot_decode_step_paged(model) if paged
                   else make_slot_decode_step(model))
    sampler = make_token_sampler(vocab)

    def block(params, arena, st: _SlotState, block_tables=None):
        n_slots = st.token.shape[0]
        out0 = jnp.full((n_steps, n_slots), -1, jnp.int32)

        def cond(carry):
            i, _, s, _ = carry
            return (i < n_steps) & jnp.any(s.active)

        def sampled(s, logits):
            split = jax.vmap(jax.random.split)(s.key)        # [S, 2, 2]
            return (sampler(logits, s.temp, s.top_k, split[:, 0]),
                    split[:, 1])

        def greedy(s, logits):
            return jnp.argmax(logits, -1).astype(jnp.int32), s.key

        def body(carry):
            i, arena, s, out = carry
            if paged:
                logits, arena = slot_decode(params, arena, s.token, s.pos,
                                            block_tables, s.active)
            else:
                logits, arena = slot_decode(params, arena, s.token, s.pos)
            # greedy fast path: the top-k sort + categorical draw is ~10x
            # an argmax, so skip it unless some active lane samples.  A
            # sampling lane's key still splits exactly once per tick it
            # is active for (it forces the branch itself), so its stream
            # stays batch-independent.
            tok, key_next = jax.lax.cond(
                jnp.any(s.active & (s.temp > 0.0)), sampled, greedy,
                s, logits)
            was = s.active
            emitted = jnp.where(was, tok, -1)
            out = jax.lax.dynamic_update_index_in_dim(out, emitted, i, 0)
            ngen = s.ngen + was.astype(jnp.int32)
            active = was & (tok != s.eos) & (ngen < s.max_gen)
            new = _SlotState(
                token=jnp.where(was, tok, s.token),
                pos=s.pos + was.astype(jnp.int32),
                ngen=ngen, active=active, temp=s.temp, top_k=s.top_k,
                key=jnp.where(was[:, None], key_next, s.key),
                eos=s.eos, max_gen=s.max_gen)
            return i + 1, arena, new, out

        i, arena, st, out = jax.lax.while_loop(
            cond, body, (jnp.int32(0), arena, st, out0))
        return arena, st, out, i

    return block


class ServeEngine:
    """Continuous-batching generation engine for one model replica."""

    def __init__(self, model, params: PyTree,
                 config: EngineConfig | None = None, *,
                 frontend: str | None = None):
        self.model = model
        self.params = params
        self.config = config or EngineConfig()
        self.frontend = frontend
        vocab = model.cfg.vocab
        if self.config.prefill_chunk and \
                not getattr(model, "kv_position_indexed", False):
            raise ValueError(
                "prefill_chunk requires a position-indexed KV cache; "
                f"{type(model).__name__} carries recurrent state that "
                "right-padded prefill would corrupt — use exact prefill "
                "(prefill_chunk=None)")

        self._paged = self.config.kv_backend == "paged"
        if self._paged:
            self.pool: CachePool = PagedCachePool(
                model, self.config.slots, self.config.max_seq,
                page_size=self.config.page_size,
                n_pages=self.config.kv_pages)
        else:
            self.pool = CachePool(model, self.config.slots,
                                  self.config.max_seq)
        self.scheduler = Scheduler(
            self.pool, max_batch=self.config.max_batch,
            max_prefills_per_tick=self.config.max_prefills_per_tick)
        self._state = _init_slot_state(self.config.slots)
        self._stats = EngineStats()
        self._completed: deque[Completion] = deque(
            maxlen=self.config.completed_cap)

        # compiled once per engine; prefill additionally caches one
        # executable per distinct prompt length (or chunk bucket).  With
        # the paged backend, prefill/refeed run in the pool's single
        # contiguous scratch lane and one scatter copies the finished
        # blocks into the slot's pages.
        self._slot_prefill = jax.jit(
            make_slot_prefill_step(model, with_frontend=frontend))
        self._refeed = jax.jit(make_slot_refeed_step(model))
        self._decode_block = jax.jit(
            _make_decode_block(model, vocab, self.config.decode_block,
                               paged=self._paged))
        if self._paged:
            self._prefill_scatter = jax.jit(
                make_prefill_scatter(self.config.page_size))
        sampler = make_token_sampler(vocab)

        def first_sample(logits, temp, top_k, seed):
            keys = jax.random.split(jax.random.PRNGKey(seed))
            tok = sampler(logits[:, 0], temp[None], top_k[None],
                          keys[:1])[0]
            return tok, keys[1]

        self._first_sample = jax.jit(first_sample)

        def admit_update(st: _SlotState, slot, token, pos, active, temp,
                         top_k, key, eos, max_gen):
            return _SlotState(
                token=st.token.at[slot].set(token),
                pos=st.pos.at[slot].set(pos),
                ngen=st.ngen.at[slot].set(1),
                active=st.active.at[slot].set(active),
                temp=st.temp.at[slot].set(temp),
                top_k=st.top_k.at[slot].set(top_k),
                key=st.key.at[slot].set(key),
                eos=st.eos.at[slot].set(eos),
                max_gen=st.max_gen.at[slot].set(max_gen))

        self._admit_update = jax.jit(admit_update)

        # ------- batched admission: one launch + one sync per tick group.
        # Each jitted step below is the K-wide counterpart of a serial
        # step above; executables are keyed by (K, S) with K <= max_batch
        # and S bounded by the prompt-length buckets, so the cache stays
        # bounded exactly like the serial path's.
        self._prefill_batched = jax.jit(
            make_slot_prefill_step_batched(model, with_frontend=frontend))
        self._refeed_batched = jax.jit(make_slot_refeed_step_batched(model))

        def first_sample_batched(logits, temp, top_k, seed, eos, max_gen):
            # identical per-request streams to the serial path: every lane
            # splits its own PRNGKey(seed), so seeded sampling stays
            # batch-independent by construction
            keys = jax.vmap(
                lambda s: jax.random.split(jax.random.PRNGKey(s)))(seed)
            tok = sampler(logits, temp, top_k, keys[:, 0])
            # liveness on device so the whole tick needs ONE host sync
            # (eos is -1 for "no stop token"; sampled ids are >= 0)
            active = (max_gen > 1) & (tok != eos)
            return tok, keys[:, 1], active

        self._first_sample_batched = jax.jit(first_sample_batched)

        def admit_update_batched(st: _SlotState, slots, token, pos, active,
                                 temp, top_k, key, eos, max_gen):
            return _SlotState(
                token=st.token.at[slots].set(token),
                pos=st.pos.at[slots].set(pos),
                ngen=st.ngen.at[slots].set(1),
                active=st.active.at[slots].set(active),
                temp=st.temp.at[slots].set(temp),
                top_k=st.top_k.at[slots].set(top_k),
                key=st.key.at[slots].set(key),
                eos=st.eos.at[slots].set(eos),
                max_gen=st.max_gen.at[slots].set(max_gen))

        self._admit_update_batched = jax.jit(admit_update_batched)

        if self._paged:
            # the batched paged admit is ONE jitted call end to end: a
            # transient K-lane contiguous cache is built *inside* the
            # trace (init_cache is zeros + broadcast, so nothing
            # persistent grows — the pool's single scratch lane remains
            # the only provisioned prefill memory), prefilled, optionally
            # refed, and every lane's finished blocks land in the pages
            # through one batched scatter.
            raw_prefill = make_prefill_step(model, with_frontend=frontend)
            scatter_b = make_prefill_scatter_batched(self.config.page_size)
            refeed_lanes = make_slot_refeed_step_batched(model)
            max_seq = self.config.max_seq

            def paged_admit(params, pages, tokens, bt_rows, *extra):
                lanes = model.init_cache(tokens.shape[0], max_seq)
                logits, lanes = raw_prefill(params, tokens, lanes, *extra)
                return logits[:, 0], scatter_b(pages, lanes, bt_rows)

            def paged_admit_refeed(params, pages, tokens, bt_rows,
                                   rf_tok, rf_pos, *extra):
                k = tokens.shape[0]
                lanes = model.init_cache(k, max_seq)
                _, lanes = raw_prefill(params, tokens, lanes, *extra)
                logits, lanes = refeed_lanes(params, lanes, jnp.arange(k),
                                             rf_tok, rf_pos)
                return logits, scatter_b(pages, lanes, bt_rows)

            self._paged_admit = jax.jit(paged_admit)
            self._paged_admit_refeed = jax.jit(paged_admit_refeed)

    # ----------------------------------------------------------- submission
    def _prefix_len(self, req: Request) -> int:
        """Cache positions consumed before the prompt (vision patches are
        prepended to the decoder sequence; audio frames cache cross-KV)."""
        if self.frontend == "vision" and req.extra:
            return int(np.shape(req.extra[0])[0])
        return 0

    def submit(self, request: Request,
               on_token: Callable | None = None, *,
               submit_t: float | None = None) -> int:
        """Queue a request; returns its id.  ``on_token(request_id, token,
        index)`` streams every generated token as it is harvested.

        ``submit_t`` (``time.perf_counter()`` domain) backdates the
        request's arrival so traffic replay preserves queueing delay in
        TTFT/latency; default is now.
        """
        s = len(request.tokens)
        if not s:
            raise ValueError("empty prompt")
        if request.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        prefix = self._prefix_len(request)
        padded = s
        if self.config.prefill_chunk:
            chunk = self.config.prefill_chunk
            padded = s + (-s) % chunk
        # Lane depth and pool commitment are different bounds.  The lane
        # must be deep enough for every position prefill or decode ever
        # *writes* — chunk padding included, hence the max() — but pad
        # positions never materialize pages (the scatter routes them to
        # the trash page), so the pool is offered only the real footprint:
        # committing the padded depth would wrongly defer admission for
        # requests that do fit, exactly at the capacity boundary.
        lane_depth = prefix + max(s + request.max_new_tokens, padded)
        if lane_depth > self.config.max_seq:
            raise ValueError(
                f"request {request.request_id} needs {lane_depth} cache "
                f"slots (> max_seq={self.config.max_seq}); raise "
                f"EngineConfig.max_seq or shorten the request")
        rs = RequestState(
            request, on_token=on_token,
            submit_t=time.perf_counter() if submit_t is None else submit_t,
            need_tokens=prefix + s + request.max_new_tokens)
        self.scheduler.submit(rs)
        return request.request_id

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    @property
    def stats(self) -> EngineStats:
        return self._stats

    def compile_stats(self) -> dict[str, int]:
        """Live jit-cache sizes — the recompile detector the slot-reuse
        tests assert on (admission into a freed slot must not miss)."""
        out = {}
        fns = [("prefill", self._slot_prefill),
               ("refeed", self._refeed),
               ("prefill_batched", self._prefill_batched),
               ("refeed_batched", self._refeed_batched),
               ("decode_block", self._decode_block),
               ("first_sample", self._first_sample),
               ("first_sample_batched", self._first_sample_batched),
               ("admit_update", self._admit_update),
               ("admit_update_batched", self._admit_update_batched)]
        if self._paged:
            fns += [("prefill_scatter", self._prefill_scatter),
                    ("paged_admit", self._paged_admit),
                    ("paged_admit_refeed", self._paged_admit_refeed)]
        for name, fn in fns:
            size = getattr(fn, "_cache_size", None)
            out[name] = size() if callable(size) else -1
        return out

    # ------------------------------------------------------------ admission
    @hot_path
    def _admit(self, slot: int, rs: RequestState,
               finished: list[Completion]) -> None:
        req = rs.request
        t0 = time.perf_counter()
        tokens = jnp.asarray(req.tokens, jnp.int32)[None]
        extra = tuple(jnp.asarray(a)[None] for a in req.extra)
        s = tokens.shape[1]
        prefix = self._prefix_len(req)

        if self._paged:
            # back the prompt's pages, prefill the contiguous scratch
            # lane, then scatter the finished blocks into the pages
            # (chunk-pad blocks past the allocation land on the trash
            # page; pad entries inside the last prompt page are masked
            # by kv_len until decode overwrites them — the same
            # unreadable-stale-data invariant as the contiguous arena)
            self.pool.extend(slot, prefix + s)
            target, slot_idx = self.pool.scratch, jnp.int32(0)
        else:
            target, slot_idx = self.pool.arena, jnp.int32(slot)
        chunk = self.config.prefill_chunk
        pad = (-s) % chunk if chunk else 0
        if pad:
            padded = jnp.pad(tokens, ((0, 0), (0, pad)))
            logits, arena = self._slot_prefill(
                self.params, target, padded, slot_idx, *extra)
            # recover the true last-prompt-token logits (see EngineConfig)
            logits, arena = self._refeed(
                self.params, arena, slot_idx,
                jnp.int32(req.tokens[-1]), jnp.int32(prefix + s - 1))
        else:
            logits, arena = self._slot_prefill(
                self.params, target, tokens, slot_idx, *extra)
        if self._paged:
            self.pool.scratch = arena
            self.pool.arena = self._prefill_scatter(
                self.pool.arena, arena, self.pool.block_table_row(slot))
        else:
            self.pool.arena = arena

        sp = req.sampling or SamplingParams()
        eos = -1 if req.eos_id is None else int(req.eos_id)
        tok0_dev, carry_key = self._first_sample(
            logits, jnp.float32(sp.temperature), jnp.int32(sp.top_k),
            jnp.int32(sp.seed))
        # repro-lint: disable=HOST-SYNC -- intentional: the first token
        # must reach the host here; this sync IS the TTFT measurement.
        tok0 = int(tok0_dev)
        now = time.perf_counter()
        rs.first_token_t = now
        self._stats.prefill_time_s += now - t0
        self._stats.prompt_tokens += s
        rs.emit(tok0)

        active = req.max_new_tokens > 1 and tok0 != eos
        self._state = self._admit_update(
            self._state, jnp.int32(slot), jnp.int32(tok0),
            jnp.int32(prefix + s), jnp.bool_(active),
            jnp.float32(sp.temperature), jnp.int32(sp.top_k), carry_key,
            jnp.int32(eos), jnp.int32(req.max_new_tokens))
        if not active:
            finished.append(self._finish_slot(slot))

    def _bucket_key(self, rs: RequestState):
        """Prefill-shape bucket: requests in one group share one
        executable.  (padded prompt length, needs-refeed, frontend extra
        shapes) — the three things that decide the traced shapes and
        whether a refeed step follows, so grouping can never mix a padded
        lane into an exact-prefill launch (which would change tokens)."""
        s = len(rs.request.tokens)
        chunk = self.config.prefill_chunk
        padded = s + (-s) % chunk if chunk else s
        return (padded, padded != s,
                tuple(np.shape(a) for a in rs.request.extra))

    @hot_path
    def _admit_batch(self, groups, finished: list[Completion]) -> None:
        """Admit one tick's admissions: ONE slot-batched prefill launch
        per shape bucket, and ONE host sync for every first token of the
        tick.

        Token-for-token equivalent to running :meth:`_admit` serially
        over the same set (greedy; seeded sampling streams are per-lane
        identical — see ``first_sample_batched``): the batched prefill
        runs the model's native batched ``prefill`` over the gathered
        lanes, every lane writing from position 0 exactly as its own
        serial call would.
        """
        t0 = time.perf_counter()
        pending = []
        for _key, members in groups:
            slots = [slot for slot, _ in members]
            reqs = [rs.request for _, rs in members]
            k = len(members)
            lens = [len(r.tokens) for r in reqs]
            chunk = self.config.prefill_chunk
            padded = lens[0] + (-lens[0]) % chunk if chunk else lens[0]
            needs_refeed = padded != lens[0]
            toks = np.zeros((k, padded), np.int32)
            for i, r in enumerate(reqs):
                toks[i, :len(r.tokens)] = r.tokens
            extra = tuple(
                jnp.asarray(np.stack([r.extra[j] for r in reqs]))
                for j in range(len(reqs[0].extra)))
            prefix = self._prefix_len(reqs[0])
            pos = [prefix + s for s in lens]
            sps = [r.sampling or SamplingParams() for r in reqs]
            temp = jnp.asarray([sp.temperature for sp in sps], jnp.float32)
            top_k = jnp.asarray([sp.top_k for sp in sps], jnp.int32)
            seed = jnp.asarray([sp.seed for sp in sps], jnp.int32)
            eos = jnp.asarray([-1 if r.eos_id is None else r.eos_id
                               for r in reqs], jnp.int32)
            max_gen = jnp.asarray([r.max_new_tokens for r in reqs],
                                  jnp.int32)
            rf_tok = jnp.asarray([r.tokens[-1] for r in reqs], jnp.int32)
            rf_pos = jnp.asarray([p - 1 for p in pos], jnp.int32)
            tokens_dev = jnp.asarray(toks)
            slots_dev = jnp.asarray(slots, jnp.int32)

            if self._paged:
                self.pool.extend_many(
                    (slot, prefix + s)
                    for slot, s in zip(slots, lens, strict=True))
                bt_rows = self.pool.block_table_rows(slots)
                if needs_refeed:
                    logits, self.pool.arena = self._paged_admit_refeed(
                        self.params, self.pool.arena, tokens_dev, bt_rows,
                        rf_tok, rf_pos, *extra)
                else:
                    logits, self.pool.arena = self._paged_admit(
                        self.params, self.pool.arena, tokens_dev, bt_rows,
                        *extra)
            else:
                logits, arena = self._prefill_batched(
                    self.params, self.pool.arena, tokens_dev, slots_dev,
                    *extra)
                if needs_refeed:
                    logits, arena = self._refeed_batched(
                        self.params, arena, slots_dev, rf_tok, rf_pos)
                self.pool.arena = arena

            tok, key, active = self._first_sample_batched(
                logits, temp, top_k, seed, eos, max_gen)
            self._state = self._admit_update_batched(
                self._state, slots_dev, tok, jnp.asarray(pos, jnp.int32),
                active, temp, top_k, key, eos, max_gen)
            self._stats.prefill_batches += 1
            self._stats.prompt_tokens += sum(lens)
            pending.append((members, tok, active))

        # ONE host sync for the whole tick: every group's first tokens
        # and liveness land in a single transfer, and its completion is
        # the shared first-token timestamp — each request's TTFT is still
        # measured from its own submit_t, so queueing delay stays
        # per-request.
        host = jax.device_get([(tok, act) for _, tok, act in pending])
        now = time.perf_counter()
        self._stats.prefill_time_s += now - t0
        self._stats.admit_ticks += 1
        for (members, _, _), (tok_h, act_h) in zip(pending, host,
                                                   strict=True):
            for (slot, rs), t, a in zip(members, tok_h.tolist(),
                                        act_h.tolist(), strict=True):
                rs.first_token_t = now
                rs.emit(t)
                if not a:
                    finished.append(self._finish_slot(slot))

    def _finish_slot(self, slot: int) -> Completion:
        rs = self.scheduler.finish(slot)
        req = rs.request
        stop = req.eos_id is not None and rs.tokens \
            and rs.tokens[-1] == req.eos_id
        now = time.perf_counter()
        comp = Completion(
            request_id=req.request_id, tokens=list(rs.tokens),
            n_prompt=len(req.tokens),
            finish_reason="stop" if stop else "length",
            ttft_s=(rs.first_token_t or now) - rs.submit_t,
            latency_s=now - rs.submit_t)
        st = self._stats
        st.requests_completed += 1
        st.generated_tokens += len(rs.tokens)
        st.ttft_s.append(comp.ttft_s)
        st.latency_s.append(comp.latency_s)
        return comp

    # ----------------------------------------------------------- stepping
    @hot_path
    def step(self) -> list[Completion]:
        """One scheduling tick: admit into free slots, then run one fused
        decode block.  Returns requests that finished this tick."""
        finished: list[Completion] = []
        if self.config.batched_admission:
            groups = self.scheduler.admission_groups(self._bucket_key)
            if groups:
                self._admit_batch(groups, finished)
        else:
            admitted = self.scheduler.admissions()
            if admitted:
                self._stats.admit_ticks += 1
            for slot, rs in admitted:
                self._admit(slot, rs, finished)

        if self.scheduler.running:
            if self._paged:
                # materialize pages for the block's worst-case frontier
                # advance (block tables are constant within a block, so
                # extension happens here, at the boundary; it cannot
                # fail — admission committed the worst case)
                for slot, rs in self.scheduler.running.items():
                    pos = self._prefix_len(rs.request) \
                        + len(rs.request.tokens) + len(rs.tokens) - 1
                    self.pool.extend(slot, pos + self.config.decode_block)
                extra = (self.pool.device_block_tables(),)
            else:
                extra = ()
            t0 = time.perf_counter()
            arena, state, out, iters = self._decode_block(
                self.params, self.pool.arena, self._state, *extra)
            # ONE batched device sync per decode tick: emitted tokens,
            # per-slot liveness, and the early-exit tick count land in a
            # single transfer (three implicit per-array reads before)
            out_host, active_host, n_iters = jax.device_get(
                (out, state.active, iters))
            self._stats.decode_time_s += time.perf_counter() - t0
            self.pool.arena = arena
            self._state = state
            st = self._stats
            st.decode_ticks += 1
            st.slot_ticks_total += int(n_iters) * self.config.slots
            for slot in list(self.scheduler.running):
                col = out_host[:, slot]
                toks = col[col >= 0]
                st.slot_ticks_active += len(toks)
                rs = self.scheduler.running[slot]
                for t in toks:
                    rs.emit(int(t))
                if not active_host[slot]:
                    finished.append(self._finish_slot(slot))
        self._completed.extend(finished)
        return finished

    # ----------------------------------------------------------- frontends
    def generate(self, requests, max_new_tokens: int | None = None,
                 *extra, sampling: SamplingParams | None = None,
                 eos_id: int | None = None):
        """Run requests to completion.

        Two forms:

        * ``generate(list[Request])`` -> ``list[Completion]`` in request
          order (the engine API);
        * ``generate(tokens [B, S], max_new_tokens, *extra)`` -> token
          array ``[B, max_new_tokens]`` (legacy convenience, greedy unless
          ``sampling`` is given; requires ``eos_id=None`` so every row
          decodes the full budget).
        """
        if not isinstance(requests, (list, tuple)):
            return self._generate_array(requests, max_new_tokens, extra,
                                        sampling, eos_id)
        pending = {r.request_id for r in requests}
        done: dict[int, Completion] = {}
        for r in requests:
            self.submit(r)
        while self.has_work and pending - set(done):
            for c in self.step():
                done[c.request_id] = c
        return [done[r.request_id] for r in requests]

    def _generate_array(self, tokens, max_new_tokens, extra, sampling,
                        eos_id):
        tokens = np.asarray(tokens)
        if max_new_tokens is None:
            max_new_tokens = 16
        b = tokens.shape[0]
        if max_new_tokens <= 0:
            return jnp.zeros((b, 0), jnp.int32)
        reqs = [Request(tokens=[int(t) for t in tokens[i]],
                        max_new_tokens=max_new_tokens,
                        sampling=sampling or SamplingParams(),
                        eos_id=eos_id,
                        extra=tuple(np.asarray(a)[i] for a in extra))
                for i in range(b)]
        comps = self.generate(reqs)
        width = max(len(c.tokens) for c in comps)
        out = np.zeros((b, width), np.int32)
        for i, c in enumerate(comps):
            out[i, :len(c.tokens)] = c.tokens
            if len(c.tokens) < width:               # early EOS: pad with it
                out[i, len(c.tokens):] = c.tokens[-1]
        return jnp.asarray(out)

    # -------------------------------------------------------------- control
    def take_completed(self) -> list[Completion]:
        """Drain and return the retained completion history, oldest first.

        The engine keeps at most ``config.completed_cap`` finished
        requests (oldest dropped); draining transfers ownership to the
        caller, so a long-running server loop that polls this holds
        bounded memory instead of accreting every completion forever.
        """
        out = list(self._completed)
        self._completed.clear()
        return out

    def drain(self) -> list[Completion]:
        """Step until idle; returns everything that finished."""
        out: list[Completion] = []
        while self.has_work:
            out.extend(self.step())
        return out

    def reset(self, *, params: PyTree | None = None) -> "ServeEngine":
        """Clear queues/stats (keeping the arena and every compiled step);
        optionally swap in fresh params (e.g. after more training)."""
        if params is not None:
            self.params = params
        self.scheduler.reset()
        self._state = _init_slot_state(self.config.slots)
        self._stats = EngineStats()
        self._completed.clear()
        return self
