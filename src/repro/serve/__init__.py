"""repro.serve — continuous-batching inference engine.

The serving counterpart of the :mod:`repro.api` training redesign:
requests, sampling, and engine shapes are **data**; the scheduler is an
object; the decode hot path is one fused slot-wide executable.

Quick start::

    from repro.api import JobConfig, Session
    from repro.serve import EngineConfig, Request, SamplingParams

    sess = Session(JobConfig(arch="qwen3-1.7b")).fit(100)
    engine = sess.serve(config=EngineConfig(max_batch=8, max_seq=256))
    comps = engine.generate([
        Request(tokens=[1, 2, 3], max_new_tokens=32, eos_id=7),
        Request(tokens=[4, 5], max_new_tokens=8,
                sampling=SamplingParams(temperature=0.8, top_k=40,
                                        seed=13)),
    ])
    print(comps[0].tokens, engine.stats.decode_tokens_per_s)

Streaming / incremental::

    engine.submit(req, on_token=lambda rid, tok, i: print(rid, tok))
    while engine.has_work:
        engine.step()
"""

from .cache import CachePool, PagedCachePool
from .config import EngineConfig
from .engine import ServeEngine
from .naive import NaiveLoop, naive_generate
from .sampling import make_token_sampler
from .scheduler import RequestState, Scheduler
from .types import Completion, EngineStats, Request, SamplingParams

__all__ = [
    "Request", "SamplingParams", "Completion", "EngineStats",
    "EngineConfig", "ServeEngine", "CachePool", "PagedCachePool",
    "Scheduler", "RequestState", "NaiveLoop", "naive_generate",
    "make_token_sampler",
]
