"""KV-cache pools: contiguous slot arena and paged page pool.

:class:`CachePool` is the original backend: the arena is the model's own
cache pytree, allocated **once** for ``n_slots`` lanes (every model family
puts the batch axis at axis 1 of each leaf, behind the stacked layer
axis).  Requests are admitted into a free slot and release it when they
finish; the arrays never change shape, so admission/retirement never
reallocates device memory and never invalidates a compiled executable.

Stale contents in a freed slot are harmless by construction: prefill
rewrites positions ``[0, prompt_len)`` wholesale (recurrent families
rebuild their state from scratch), and attention masks every position
beyond the slot's write frontier (``kv_valid_len``), so a reused slot can
never read the previous tenant's KV.  The slot-reuse tests pin this.

:class:`PagedCachePool` applies the DreamDDP decomposition to the memory
axis: instead of every slot paying a full contiguous ``max_seq`` lane,
KV lives in fixed-size **pages** of a shared pool and each slot maps its
logical blocks to physical pages through a block table.  A request only
ever holds ``ceil(need / page_size)`` pages, so short requests stop
subsidizing long ones and the same device memory admits more slots.
``admit`` (:meth:`alloc`) reserves a worst-case page *commitment*,
``extend`` materializes pages lazily as the decode frontier advances
(never failing, by the commitment invariant), and ``free`` returns both
— none of which ever reallocates the pool or recompiles an executable.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CachePool", "PagedCachePool", "make_prefill_scatter",
           "make_prefill_scatter_batched"]

PyTree = Any

SLOT_AXIS = 1  # cache leaves are [layers, batch, ...] across all families

TRASH_PAGE = 0  # reserved page: absorbs masked/inactive writes, never read


class CachePool:
    """Fixed arena of ``n_slots`` cache lanes + a host-side free list."""

    backend = "contiguous"

    def __init__(self, model, n_slots: int, max_seq: int):
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.arena: PyTree = model.init_cache(n_slots, max_seq)
        for leaf in jax.tree_util.tree_leaves(self.arena):
            if leaf.ndim <= SLOT_AXIS or leaf.shape[SLOT_AXIS] != n_slots:
                raise ValueError(
                    f"cache leaf {leaf.shape} does not carry the slot axis "
                    f"at axis {SLOT_AXIS}; CachePool requires "
                    f"[layers, slots, ...] cache layouts")
        self._init_slots(n_slots)

    def _init_slots(self, n_slots: int) -> None:
        self._free: list[int] = list(range(n_slots - 1, -1, -1))
        # O(1) double-free detection (a `slot in self._free` scan is
        # O(n_slots) per retirement — it shows once pools carry hundreds
        # of lanes/pages)
        self._is_free = bytearray([1]) * n_slots

    # ------------------------------------------------------------ free list
    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, need_tokens: int = 0) -> int | None:
        """Pop a free slot id, or None when the arena is full.

        ``need_tokens`` (the request's worst-case cache footprint) is
        ignored here — every contiguous lane is ``max_seq`` deep — but
        paged pools use it for admission control, so the scheduler always
        passes it.
        """
        if not self._free:
            return None
        slot = self._free.pop()
        self._is_free[slot] = 0
        return slot

    def free(self, slot: int) -> None:
        if not 0 <= slot < self.n_slots or self._is_free[slot]:
            raise ValueError(f"double free / bad slot {slot}")
        self._is_free[slot] = 1
        self._free.append(slot)

    def reset(self) -> None:
        """Release every slot (arena contents are left as-is: stale data
        is unreadable by construction, see module docstring)."""
        self._init_slots(self.n_slots)

    # ----------------------------------------------------------- accounting
    def kv_bytes(self) -> int:
        """Device bytes held by the cache arrays."""
        return sum(leaf.nbytes
                   for leaf in jax.tree_util.tree_leaves(self.arena))


def make_prefill_scatter(page_size: int):
    """Build the (jittable) copy of a freshly prefilled scratch lane into
    the page pool.

    ``pages`` leaves are ``[layers, n_pages, page_size, ...]``; ``scratch``
    leaves ``[layers, 1, max_seq, ...]``; ``bt_row [max_blocks]`` is the
    slot's block-table row.  Every block is scattered unconditionally —
    rows are trash-page-padded past the allocated prefix, so pad blocks
    land on page 0 and one executable serves every prompt length.
    """

    def scatter(pages: PyTree, scratch: PyTree, bt_row) -> PyTree:
        def one(pg, sc):
            blocks = sc[:, 0].reshape(
                (pg.shape[0], bt_row.shape[0], page_size) + sc.shape[3:])
            return pg.at[:, bt_row].set(blocks.astype(pg.dtype))

        return jax.tree.map(one, pages, scratch)

    return scatter


def make_prefill_scatter_batched(page_size: int):
    """Batched :func:`make_prefill_scatter`: copy K freshly prefilled
    lanes into the page pool in ONE scatter.

    ``lanes`` leaves are ``[layers, K, max_seq, ...]`` (the transient
    prefill lanes of one admission group); ``bt_rows [K, max_blocks]``
    the admitted slots' block-table rows.  Every block of every lane is
    scattered unconditionally — rows are trash-page-padded past each
    slot's allocated prefix, so pad blocks land on page 0 (which is
    never read; colliding trash writes across lanes are harmless).
    """

    def scatter(pages: PyTree, lanes: PyTree, bt_rows) -> PyTree:
        k, max_blocks = bt_rows.shape

        def one(pg, ln):
            blocks = ln.reshape(
                (pg.shape[0], k, max_blocks, page_size) + ln.shape[3:])
            return pg.at[:, bt_rows].set(blocks.astype(pg.dtype))

        return jax.tree.map(one, pages, lanes)

    return scatter


class PagedCachePool(CachePool):
    """Block-table KV pool: slots share ``n_pages`` fixed-size pages.

    Device state (allocated once, shapes never change):

    * ``arena`` — the model's page pool, leaves ``[layers, n_pages,
      page_size, ...]`` (page 0 is the reserved trash page);
    * ``scratch`` — one contiguous ``max_seq`` lane; prefill (and the
      chunked-prefill refeed) run in it unchanged, then one scatter
      copies the finished blocks into the slot's pages.

    Host state: ``block_tables`` (``[n_slots, max_blocks]`` numpy int32,
    shipped to the device each decode tick — a few hundred bytes), the
    page free list, and per-slot page commitments.  Admission reserves
    the worst-case ``ceil(need / page_size)`` pages up front (so
    ``extend`` can never fail mid-flight and nothing is ever preempted);
    physical pages are handed out lazily as the decode frontier crosses
    block boundaries, so ``peak_pages_in_use`` — the honest provisioning
    floor — tracks actual traffic, not the commitment.
    """

    backend = "paged"

    def __init__(self, model, n_slots: int, max_seq: int, *,
                 page_size: int, n_pages: int | None = None):
        if not getattr(model, "supports_paged_kv", False):
            raise ValueError(
                f"{type(model).__name__} does not support a paged KV "
                "cache (recurrent state lanes / cross-attention KV are "
                "fixed-size per slot) — use kv_backend='contiguous'")
        if max_seq % page_size:
            raise ValueError(
                f"max_seq={max_seq} must be a multiple of "
                f"page_size={page_size}")
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.max_blocks = max_seq // page_size
        worst = n_slots * self.max_blocks
        self.n_pages = worst + 1 if n_pages is None else n_pages
        if self.n_pages < 2:
            raise ValueError("n_pages must be >= 2 (page 0 is reserved)")

        self.arena: PyTree = model.init_paged_cache(self.n_pages,
                                                    page_size)
        self.scratch: PyTree = model.init_cache(1, max_seq)
        self.block_tables = np.zeros((n_slots, self.max_blocks), np.int32)
        self._init_slots(n_slots)
        self._init_pages()

    def _init_pages(self) -> None:
        self._free_pages: list[int] = list(range(self.n_pages - 1, 0, -1))
        self._pages_of: list[list[int]] = [[] for _ in range(self.n_slots)]
        self._commit_pages = [0] * self.n_slots
        self._committed_total = 0
        self.pages_in_use = 0
        self.peak_pages_in_use = 0

    # ----------------------------------------------------------- page maths
    @property
    def n_usable_pages(self) -> int:
        return self.n_pages - 1

    @property
    def n_free_pages(self) -> int:
        return len(self._free_pages)

    def pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    # ------------------------------------------------------- admit / extend
    def alloc(self, need_tokens: int = 0) -> int | None:
        """Admit: reserve a slot *and* its worst-case page commitment.

        Returns None (request stays queued) when either slots or pages
        are exhausted — over-committing would make a later ``extend``
        fail mid-decode, which is the corruption the commitment invariant
        exists to rule out.
        """
        need = self.pages_needed(need_tokens)
        if not self._free \
                or self._committed_total + need > self.n_usable_pages:
            return None
        slot = super().alloc()
        self._commit_pages[slot] = need
        self._committed_total += need
        return slot

    def extend(self, slot: int, n_tokens: int) -> None:
        """Materialize pages so positions ``[0, n_tokens)`` of ``slot``
        are backed (clamped to the slot's admission commitment)."""
        if self._is_free[slot]:
            raise ValueError(f"extend on free slot {slot}")
        if n_tokens > 0 and not self._commit_pages[slot]:
            raise ValueError(
                f"slot {slot} was admitted without a page commitment — "
                "pass the request's need_tokens to alloc(); extending a "
                "zero-commitment slot would silently route every write "
                "to the trash page")
        want = min(self.pages_needed(n_tokens), self._commit_pages[slot])
        row = self._pages_of[slot]
        while len(row) < want:
            if not self._free_pages:    # unreachable if commitments hold
                raise RuntimeError(
                    "page pool exhausted past its commitments — "
                    "allocator invariant violated")
            page = self._free_pages.pop()
            self.block_tables[slot, len(row)] = page
            row.append(page)
        self.pages_in_use = self.n_usable_pages - len(self._free_pages)
        self.peak_pages_in_use = max(self.peak_pages_in_use,
                                     self.pages_in_use)

    def extend_many(self, pairs) -> None:
        """Materialize pages for several slots at once: ``pairs`` is an
        iterable of ``(slot, n_tokens)`` — one admission group's worth of
        :meth:`extend` calls, kept host-side and cheap."""
        for slot, n_tokens in pairs:
            self.extend(slot, n_tokens)

    def free(self, slot: int) -> None:
        super().free(slot)
        self._free_pages.extend(reversed(self._pages_of[slot]))
        self._pages_of[slot] = []
        self._committed_total -= self._commit_pages[slot]
        self._commit_pages[slot] = 0
        self.block_tables[slot, :] = TRASH_PAGE
        self.pages_in_use = self.n_usable_pages - len(self._free_pages)

    def reset(self) -> None:
        self._init_slots(self.n_slots)
        self._init_pages()
        self.block_tables[:] = TRASH_PAGE

    # ----------------------------------------------------------- accounting
    def block_table_row(self, slot: int) -> jax.Array:
        return jnp.asarray(self.block_tables[slot])

    def block_table_rows(self, slots) -> jax.Array:
        """``[K, max_blocks]`` device rows for one admission group."""
        return jnp.asarray(self.block_tables[np.asarray(slots, np.int64)])

    def device_block_tables(self) -> jax.Array:
        return jnp.asarray(self.block_tables)

    def kv_bytes(self) -> int:
        """Provisioned device bytes: page pool + scratch lane."""
        return super().kv_bytes() + sum(
            leaf.nbytes for leaf in jax.tree_util.tree_leaves(self.scratch))

    def page_bytes(self) -> int:
        """Device bytes of ONE page across every layer/leaf."""
        return sum(leaf.nbytes // self.n_pages
                   for leaf in jax.tree_util.tree_leaves(self.arena))

    def peak_kv_bytes(self) -> int:
        """High-water footprint a right-sized pool would have needed:
        peak live pages (+ the trash page) plus the scratch lane."""
        scratch = sum(leaf.nbytes
                      for leaf in jax.tree_util.tree_leaves(self.scratch))
        return (self.peak_pages_in_use + 1) * self.page_bytes() \
            + scratch + self.block_tables.nbytes
