"""CachePool — slot-pooled KV/state arena with free-list allocation.

The arena is the model's own cache pytree, allocated **once** for
``n_slots`` lanes (every model family puts the batch axis at axis 1 of
each leaf, behind the stacked layer axis).  Requests are admitted into a
free slot and release it when they finish; the arrays never change shape,
so admission/retirement never reallocates device memory and never
invalidates a compiled executable.

Stale contents in a freed slot are harmless by construction: prefill
rewrites positions ``[0, prompt_len)`` wholesale (recurrent families
rebuild their state from scratch), and attention masks every position
beyond the slot's write frontier (``kv_valid_len``), so a reused slot can
never read the previous tenant's KV.  The slot-reuse tests pin this.
"""

from __future__ import annotations

from typing import Any

import jax

__all__ = ["CachePool"]

PyTree = Any

SLOT_AXIS = 1  # cache leaves are [layers, batch, ...] across all families


class CachePool:
    """Fixed arena of ``n_slots`` cache lanes + a host-side free list."""

    def __init__(self, model, n_slots: int, max_seq: int):
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.arena: PyTree = model.init_cache(n_slots, max_seq)
        for leaf in jax.tree_util.tree_leaves(self.arena):
            if leaf.ndim <= SLOT_AXIS or leaf.shape[SLOT_AXIS] != n_slots:
                raise ValueError(
                    f"cache leaf {leaf.shape} does not carry the slot axis "
                    f"at axis {SLOT_AXIS}; CachePool requires "
                    f"[layers, slots, ...] cache layouts")
        self._free: list[int] = list(range(n_slots - 1, -1, -1))

    # ------------------------------------------------------------ free list
    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self) -> int | None:
        """Pop a free slot id, or None when the arena is full."""
        return self._free.pop() if self._free else None

    def free(self, slot: int) -> None:
        if slot in self._free or not 0 <= slot < self.n_slots:
            raise ValueError(f"double free / bad slot {slot}")
        self._free.append(slot)

    def reset(self) -> None:
        """Release every slot (arena contents are left as-is: stale data
        is unreadable by construction, see module docstring)."""
        self._free = list(range(self.n_slots - 1, -1, -1))
