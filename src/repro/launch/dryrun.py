import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init).  This launcher — and ONLY this launcher — sees 512
# placeholder CPU devices standing in for the production TPU mesh.

"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``
containing ``memory_analysis()`` (proves it fits), ``cost_analysis()``
(FLOPs / bytes for §Roofline) and the per-collective byte totals parsed
from the optimized HLO (the roofline's third term).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
"""

import argparse
import json
import sys
import time
import traceback


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             out_dir: str, algo: str = "dreamddp", verbose: bool = True,
             phase: int | None = None, step_cfg=None,
             variant: str = "", **cell_kw) -> dict:
    import jax

    from ..analysis.hlo import parse_collectives
    from ..configs import SHAPES
    from .cells import build_cell
    from .mesh import make_production_mesh

    mesh_name = "multi_pod" if multi_pod else "single_pod"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    # jax >= 0.5 spells the mesh context jax.set_mesh; on 0.4.x the Mesh
    # object itself is the context manager.
    set_mesh = getattr(jax, "set_mesh", None)
    with (set_mesh(mesh) if set_mesh is not None else mesh):
        kw = {}
        if SHAPES[shape_name].kind == "train":
            kw = {"algo": algo, "phase": phase, **cell_kw}
            if step_cfg is not None:
                kw["step_cfg"] = step_cfg
        cell = build_cell(arch_id, shape_name, mesh, multi_pod=multi_pod,
                          **kw)
        lowered = cell.lower()
        compiled = lowered.compile()

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem[k] = int(getattr(ma, k, 0) or 0)
        mem["total_bytes"] = (mem.get("argument_size_in_bytes", 0)
                              + mem.get("temp_size_in_bytes", 0)
                              + mem.get("output_size_in_bytes", 0)
                              - mem.get("alias_size_in_bytes", 0))
    except Exception as e:                                   # noqa: BLE001
        mem["error"] = str(e)

    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:                                   # noqa: BLE001
        cost["error"] = str(e)

    hlo = compiled.as_text()
    from ..analysis.hlo_costs import parse_module_costs
    executed = parse_module_costs(hlo)       # loop-aware (true trip counts)

    art = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "kind": cell.kind, "n_devices": cell.n_devices,
        "model_flops": cell.model_flops,
        "cost_is_per_device": True,
        "memory_analysis": mem,
        # raw XLA numbers (loop bodies counted once) kept for reference
        "cost_analysis_raw": cost,
        # loop-aware executed costs — what §Roofline consumes
        "cost_analysis": {
            "flops": executed.flops,
            "bytes accessed": executed.bytes_accessed,
            "n_dots": executed.n_dots,
            "unknown_loops": executed.unknown_loops,
        },
        "collectives": executed.collectives.to_dict(),
        "collectives_static": parse_collectives(hlo).to_dict(),
        "meta": cell.meta,
        "compile_seconds": time.time() - t0,
    }
    os.makedirs(out_dir, exist_ok=True)
    tag = f"__{variant}" if variant else ""
    path = os.path.join(out_dir,
                        f"{arch_id}__{shape_name}__{mesh_name}{tag}.json")
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    import gzip
    with gzip.open(path[:-5] + ".hlo.gz", "wt") as f:
        f.write(hlo)
    if verbose:
        per_dev = mem.get("total_bytes", 0) / 1e9
        print(f"  OK  {arch_id:24s} {shape_name:12s} {mesh_name:10s} "
              f"flops/dev={executed.flops:.3e} "
              f"mem/dev={per_dev:.2f}GB "
              f"wire/dev={executed.collectives.total_wire_bytes / 1e9:.3f}GB "
              f"[{art['compile_seconds']:.0f}s]")
    return art


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--algo", default="dreamddp")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--variant", default="")
    ap.add_argument("--intra-worker", default="tp",
                    choices=("tp", "fsdp", "dp", "ep2"))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    from ..configs import ARCHS, all_cells

    if args.all:
        cells = all_cells()
    else:
        if args.arch is None:
            ap.error("--arch or --all required")
        archs = [args.arch] if args.arch != "all" else list(ARCHS)
        cells = [(a, s.name) for a in archs
                 for s in ARCHS[a].shapes()
                 if args.shape in (None, s.name)]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch_id, shape_name in cells:
        for mp in meshes:
            mesh_name = "multi_pod" if mp else "single_pod"
            path = os.path.join(
                args.out, f"{arch_id}__{shape_name}__{mesh_name}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"  skip {arch_id} {shape_name} {mesh_name}")
                continue
            try:
                run_cell(arch_id, shape_name, multi_pod=mp,
                         out_dir=args.out, algo=args.algo,
                         variant=args.variant,
                         intra_worker=args.intra_worker)
            except Exception:                                # noqa: BLE001
                failures.append((arch_id, shape_name, mesh_name))
                print(f"  FAIL {arch_id} {shape_name} {mesh_name}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} cell(s) FAILED: {failures}")
        return 1
    print("\nall requested cells compiled")
    return 0


if __name__ == "__main__":
    sys.exit(main())
