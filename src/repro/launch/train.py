"""End-to-end training driver — the :class:`repro.api.Session` CLI.

Trains a reduced config of any assigned architecture with any registered
sync strategy on the synthetic Markov corpus.  The whole pipeline (profile
-> schedule search -> bubble fill -> phase-specialized steps ->
fault-tolerant runner) is wired by ``Session(JobConfig(...)).fit(steps)``;
this module only parses flags.

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --algo dreamddp --workers 8 --steps 100 --period 5

``--algo`` accepts any name in the strategy registry — the paper's six
algorithms plus beyond-paper compositions (``dreamddp-int8``,
``hier-2tier``, ...).  To add your own::

    from repro.api import SyncStrategy, register_strategy

    @register_strategy("my-algo")
    class MyAlgo(SyncStrategy):
        def build_plan(self, profile, H, *, fill_mode="exact"):
            ...  # any repro.core.plans.SyncPlan construction

then launch with ``--algo my-algo`` (import your module first, e.g. via a
wrapper script).  The 100M-parameter example in ``examples/train_100m.py``
shows the :class:`~repro.api.Session` model-override path.
"""

from __future__ import annotations

import argparse
import json
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--algo", default="dreamddp",
                    help="any registered sync strategy (see repro.api)")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--period", type=int, default=5, help="H")
    ap.add_argument("--batch-per-worker", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--bandwidth", type=float, default=1e9)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress", default=None, choices=(None, "int8_ef"),
                    help="DEPRECATED: use --algo dreamddp-int8")
    ap.add_argument("--outer", action="store_true",
                    help="DiLoCo-style outer optimizer (beyond-paper; "
                         "DEPRECATED: register a strategy whose "
                         "sync_policy() returns OuterOptSync)")
    ap.add_argument("--track-divergence", action="store_true")
    ap.add_argument("--fused", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="period-fused runner: one host sync per H-step "
                         "period with prefetched data (--no-fused = "
                         "per-step oracle)")
    ap.add_argument("--period-exec", default="pipeline",
                    choices=("pipeline", "compiled"),
                    help="fused period execution: 'pipeline' (donated "
                         "per-phase executables, bitwise-equal to the "
                         "per-step path) or 'compiled' (one lax.scan "
                         "executable per period)")
    ap.add_argument("--async", dest="async_mode",
                    action=argparse.BooleanOptionalAction, default=False,
                    help="asynchronous two-tier runtime (repro.hier): "
                         "workers run periods on their own clocks and "
                         "push layer-wise deltas to a server tier — no "
                         "period-boundary barrier")
    ap.add_argument("--staleness-beta", type=float, default=0.9,
                    help="async merge: per-version staleness decay "
                         "(scale = beta ** min(tau, max_staleness))")
    ap.add_argument("--merge-rule", default="halos",
                    choices=("halos", "delayed-nesterov"),
                    help="async merge rule: HALoS staleness-aware "
                         "Nesterov momentum, or delayed-Nesterov "
                         "(buffered momentum every N merges)")
    ap.add_argument("--dry-run", action="store_true",
                    help="resolve the model/plan (and async config), "
                         "print them, and exit without training")
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    from ..api import JobConfig, Session, available_strategies

    if args.algo not in available_strategies():
        ap.error(f"unknown --algo {args.algo!r}; registered: "
                 f"{', '.join(available_strategies())}")

    sess = Session(JobConfig(
        arch=args.arch, algo=args.algo, workers=args.workers,
        period=args.period, bandwidth=args.bandwidth,
        batch_per_worker=args.batch_per_worker, seq=args.seq,
        smoke=args.smoke, lr=args.lr, warmup_steps=10,
        decay_steps=max(args.steps, 100), compress=args.compress,
        outer=args.outer, track_divergence=args.track_divergence,
        fused_period=args.fused, period_exec=args.period_exec,
        ckpt_dir=args.ckpt_dir, async_mode=args.async_mode,
        staleness_beta=args.staleness_beta, merge_rule=args.merge_rule))

    model = sess.model
    mode = "async" if sess.use_async else \
        ("off" if not args.fused else args.period_exec)
    print(f"arch={args.arch} smoke={args.smoke} "
          f"params={model.param_count() / 1e6:.1f}M algo={args.algo} "
          f"W={args.workers} H={args.period} exec={mode}")
    plan = sess.plan
    print(f"plan: {plan.meta.get('partition_counts')} "
          f"extra_syncs={plan.meta.get('extra_syncs')}")
    if sess.use_async:
        mc = sess.merge_config.resolve(args.workers)
        print(f"merge: rule={mc.rule} lr={mc.lr:.4g} "
              f"momentum={mc.momentum} beta={mc.staleness_beta} "
              f"max_staleness={mc.max_staleness}")
    if args.dry_run:
        print("dry run: configuration resolved, exiting before training")
        return 0

    steps = args.steps
    if sess.use_async and steps % args.period:
        steps = max(args.period, steps - steps % args.period)
        print(f"async fit advances whole periods: running {steps} steps")
    t0 = time.time()
    sess.fit(steps)
    dt = time.time() - t0
    losses = [h["loss"] for h in sess.history]
    data = sess.runner.data
    print(f"steps={len(sess.history)} loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f} (floor~{data.entropy_floor():.3f}) "
          f"[{dt:.1f}s, {dt / max(len(losses), 1) * 1e3:.0f} ms/step]")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(sess.history, f)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
