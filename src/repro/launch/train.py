"""End-to-end training driver (runs for real on this CPU container).

Trains a reduced config of any assigned architecture with any ``--algo``
on the synthetic Markov corpus, with checkpointing and the full DreamDDP
pipeline (profile -> Algorithm 2 -> bubble fill -> phase-specialized
steps).  The 100M-parameter example in ``examples/train_100m.py`` wraps
this module.

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --algo dreamddp --workers 8 --steps 100 --period 5
"""

from __future__ import annotations

import argparse
import json
import time

import jax


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--algo", default="dreamddp",
                    choices=("ssgd", "flsgd", "plsgd-enp", "dreamddp"))
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--period", type=int, default=5, help="H")
    ap.add_argument("--batch-per-worker", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--bandwidth", type=float, default=1e9)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress", default=None, choices=(None, "int8_ef"))
    ap.add_argument("--outer", action="store_true",
                    help="DiLoCo-style outer optimizer (beyond-paper)")
    ap.add_argument("--track-divergence", action="store_true")
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    from ..checkpoint import CheckpointManager
    from ..configs import get_arch
    from ..core import HardwareSpec, analytic_profile, build_plan
    from ..data import MarkovCorpus
    from ..optim import make_optimizer
    from ..runtime import (Runner, RunnerConfig, StepConfig,
                           init_train_state)

    arch = get_arch(args.arch)
    model = arch.make_smoke() if args.smoke else arch.make_model()
    cfg = model.cfg
    vocab = cfg.vocab
    print(f"arch={args.arch} smoke={args.smoke} "
          f"params={model.param_count() / 1e6:.1f}M algo={args.algo} "
          f"W={args.workers} H={args.period}")

    hw = HardwareSpec(bandwidth=args.bandwidth, n_workers=args.workers)
    prof = analytic_profile(
        model.layer_costs(args.batch_per_worker, args.seq), hw)
    plan = build_plan(args.algo, prof, args.period)
    print(f"plan: {plan.meta.get('partition_counts')} "
          f"extra_syncs={plan.meta.get('extra_syncs')}")

    opt = make_optimizer("adam", lr=args.lr, warmup_steps=10,
                         decay_steps=max(args.steps, 100))
    scfg = StepConfig(compress=args.compress, outer=args.outer,
                      track_divergence=args.track_divergence)
    state = init_train_state(model, opt, jax.random.PRNGKey(0),
                             args.workers, cfg=scfg)
    data = MarkovCorpus(vocab=vocab, seq_len=args.seq,
                        batch_per_worker=args.batch_per_worker,
                        n_workers=args.workers)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    runner = Runner(model, opt, plan, data, ckpt=ckpt, step_cfg=scfg)

    t0 = time.time()
    state = runner.run(state, args.steps)
    dt = time.time() - t0
    losses = [h["loss"] for h in runner.history]
    print(f"steps={len(runner.history)} loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f} (floor~{data.entropy_floor():.3f}) "
          f"[{dt:.1f}s, {dt / max(len(losses), 1) * 1e3:.0f} ms/step]")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(runner.history, f)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
