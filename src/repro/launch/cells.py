"""Cell builders: one lowerable jitted program per (arch x shape x mesh).

A *cell* bundles the jitted step function, its ShapeDtypeStruct argument
specs and explicit in/out shardings — everything ``dryrun.py`` needs to
``.lower().compile()`` without allocating a single parameter.

Sharding plan (baseline; §Perf hillclimbs from here):

* train — worker axis per :meth:`ArchSpec.worker_axes`; tensor/expert
  parallel over ``model``; ``large`` archs FSDP over ``data``; batch
  ``[W, n_micro, B_micro, ...]`` with grad-accumulation scan sized so the
  per-device remat stash stays under ~2 GB;
* prefill/decode — one synchronized replica; weights over ``model``
  (+``data`` for large archs), request batch over ``data`` when divisible,
  caches via :func:`repro.parallel.sharding.cache_shardings`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import SHAPES, ArchSpec, get_arch
from ..configs.common import batch_specs
from ..core import HardwareSpec, analytic_profile, build_plan
from ..core.plans import SyncPlan
from ..optim import make_optimizer
from ..parallel.sharding import leaf_spec, param_shardings
from ..runtime.step import (StepConfig, init_train_state, make_decode_step,
                            make_prefill_step, make_train_step)

__all__ = ["Cell", "build_cell", "WAN_BANDWIDTH"]

WAN_BANDWIDTH = 1e9          # geo sync-axis bytes/s for schedule solving
_STASH_BUDGET = 2e9          # per-device remat stash target (bytes)


@dataclass
class Cell:
    arch_id: str
    shape_name: str
    mesh_name: str
    kind: str                           # train | prefill | decode
    jitted: Any
    arg_specs: tuple
    n_devices: int
    model_flops: float
    meta: dict = field(default_factory=dict)

    def lower(self):
        return self.jitted.lower(*self.arg_specs)


def _mk_opt(arch: ArchSpec, override: str | None = None):
    name = override or arch.optimizer
    if name == "adafactor":
        return make_optimizer("adafactor", beta1=0.0, lr=1e-3)
    return make_optimizer(name, lr=3e-4)


def _plan_for(arch: ArchSpec, model, shape, w: int,
              bandwidth: float = WAN_BANDWIDTH) -> SyncPlan:
    bw_batch = max(shape.global_batch // max(w, 1), 1)
    costs = model.layer_costs(bw_batch, shape.seq_len)
    hw = HardwareSpec(bandwidth=bandwidth, n_workers=max(w, 2),
                      latency=1e-3)
    prof = analytic_profile(costs, hw)
    return build_plan("dreamddp", prof, H=5)


def _dominant_phase(plan: SyncPlan, model, shape) -> int:
    """Phase with the most synced parameter bytes (the sync-critical one)."""
    costs = model.layer_costs(1, shape.seq_len)
    best, best_b = 0, -1.0
    for h in range(plan.H):
        b = sum(costs[u][1] for u in plan.units_for_phase(h))
        if b > best_b:
            best, best_b = h, b
    return best


def _n_micro(arch: ArchSpec, model, shape, w: int, mesh: Mesh) -> int:
    """Grad-accumulation factor bounding the per-device remat stash.

    Constraint: for FSDP (large) archs the per-microbatch batch must stay
    divisible by the ``data`` axis, since the batch is data-sharded inside
    the worker."""
    cfg = model.cfg
    d = cfg.d_model
    n_layers = getattr(cfg, "n_layers", None) or \
        (cfg.n_enc_layers + cfg.n_dec_layers)
    bw_batch = max(shape.global_batch // max(w, 1), 1)
    data_shard = mesh.shape["data"] if arch.large else 1
    b_dev = max(bw_batch // data_shard, 1)
    stash = b_dev * shape.seq_len * d * 2 * n_layers
    n = max(1, math.ceil(stash / _STASH_BUDGET))
    n_max = max(bw_batch // data_shard, 1)
    n = min(n, n_max)
    while bw_batch % n or (bw_batch // n) % data_shard:
        n -= 1
    return max(n, 1)


def _shard_if_divisible(mesh: Mesh, n: int, axis: str = "data"):
    return axis if n % mesh.shape[axis] == 0 and n >= mesh.shape[axis] \
        else None


def _adafactor_shardings(pshard, pspec, mesh: Mesh, min_dim: int = 8):
    def one(ns, sds):
        spec = tuple(ns.spec) + (None,) * (len(sds.shape) - len(ns.spec))
        if (len(sds.shape) >= 2 and sds.shape[-1] >= min_dim
                and sds.shape[-2] >= min_dim):
            return {"vr": NamedSharding(mesh, P(*spec[:-1])),
                    "vc": NamedSharding(mesh, P(*spec[:-2], spec[-1]))}
        return {"v": NamedSharding(mesh, P(*spec))}
    is_ns = lambda x: isinstance(x, NamedSharding)
    return jax.tree.map(one, pshard, pspec, is_leaf=is_ns)


def _opt_shardings(opt_name: str, pshard, pspec, mesh: Mesh):
    if opt_name in ("adam", "adamw"):
        return {"m": pshard, "v": pshard}
    if opt_name == "momentum":
        return {"m": pshard}
    if opt_name == "adafactor":
        return {"v": _adafactor_shardings(pshard, pspec, mesh), "m": None}
    return {}


def _cache_shardings(cache_spec, mesh: Mesh, *, batch: int):
    """Serving caches ``[n_layers, B, ...]``: batch over data when
    divisible; the largest model-divisible trailing dim over ``model``."""
    msize = mesh.shape["model"]
    dsh = _shard_if_divisible(mesh, batch, "data")

    def one(s):
        dims: list = [None] * len(s.shape)
        if len(s.shape) >= 2:
            dims[1] = dsh
        for i in range(len(s.shape) - 1, 1, -1):     # prefer trailing dims
            if s.shape[i] % msize == 0 and s.shape[i] >= msize:
                dims[i] = "model"
                break
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(one, cache_spec)


# ---------------------------------------------------------------------------
# Train cells
# ---------------------------------------------------------------------------

def build_train_cell(arch: ArchSpec, shape, mesh: Mesh, *,
                     multi_pod: bool, algo: str = "dreamddp",
                     phase: int | None = None,
                     step_cfg: StepConfig | None = None,
                     intra_worker: str = "tp",
                     optimizer_override: str | None = None) -> Cell:
    """``intra_worker``: how a worker's 16 `model`-axis chips cooperate.

    * ``"tp"`` (baseline) — Megatron tensor parallel (heads/ff/vocab over
      `model`); activations all-reduced twice per layer.
    * ``"fsdp"`` — ZeRO-3 within the worker: weights sharded over `model`
      and gathered per layer; batch sharded over `model` (REFUTED in
      §Perf: GSPMD picks contraction-dim partial sums).
    * ``"dp"`` — weights replicated per chip, batch sharded over `model`
      (each chip = one DP rank inside the worker; grads all-reduced over
      `model`, DreamDDP partial sync over `data`).  Small archs whose
      params+Adafactor state fit one chip (beyond-paper §Perf winner).
    """
    model = arch.make_model()
    if intra_worker == "dp" and optimizer_override is None:
        optimizer_override = "adafactor"   # replicated state must fit
    opt = _mk_opt(arch, optimizer_override)
    w = arch.n_workers(multi_pod=multi_pod)
    worker_axes = arch.worker_axes(multi_pod=multi_pod)
    n_micro = _n_micro(arch, model, shape, w, mesh)
    if intra_worker in ("fsdp", "dp"):
        if arch.large:
            raise ValueError(f"{intra_worker} intra-worker mode is for "
                             "small archs")
        # batch shards over `model`: microbatching only if still too big
        bw_batch = shape.global_batch // max(w, 1)
        if bw_batch % mesh.shape["model"]:
            raise ValueError("worker batch must divide the model axis")
        n_micro = 1
    cfg = step_cfg or StepConfig(n_microbatches=n_micro)

    if algo == "dreamddp":
        plan = _plan_for(arch, model, shape, w)
    else:
        prof = analytic_profile(model.layer_costs(1, shape.seq_len),
                                HardwareSpec(n_workers=max(w, 2)))
        plan = build_plan(algo, prof, 5)
    ph = _dominant_phase(plan, model, shape) if phase is None else phase
    step_fn = make_train_step(model, opt, plan, ph, cfg=cfg)

    # ---- arg specs ----------------------------------------------------------
    state_spec = jax.eval_shape(
        lambda: init_train_state(model, opt, jax.random.PRNGKey(0), w,
                                 cfg=cfg))
    bspec = batch_specs(arch, shape, n_workers=w)
    if cfg.n_microbatches > 1:
        bspec = jax.tree.map(
            lambda s: ShapeDtypeStruct(
                (s.shape[0], cfg.n_microbatches,
                 s.shape[1] // cfg.n_microbatches) + s.shape[2:], s.dtype),
            bspec)

    # ---- shardings ----------------------------------------------------------
    from ..parallel.sharding import RULES_EP2, RULES_FSDP_MODEL
    if intra_worker == "ep2":
        # two-axis expert parallel (large MoE archs, expert count must
        # divide data x model): expert weights fully local; non-expert
        # weights TP over `model` + FSDP over `data` as usual
        pshard = param_shardings(model.param_specs(), mesh,
                                 worker_axes=worker_axes, fsdp=True,
                                 rules=RULES_EP2,
                                 shapes=state_spec.params)
    elif intra_worker == "fsdp":
        pshard = param_shardings(model.param_specs(), mesh,
                                 worker_axes=worker_axes, fsdp=True,
                                 fsdp_axis="model",
                                 rules=RULES_FSDP_MODEL,
                                 shapes=state_spec.params)
    elif intra_worker == "dp":
        pshard = param_shardings(model.param_specs(), mesh,
                                 worker_axes=worker_axes, fsdp=False,
                                 rules=RULES_FSDP_MODEL,
                                 shapes=state_spec.params)
    else:
        pshard = param_shardings(model.param_specs(), mesh,
                                 worker_axes=worker_axes, fsdp=arch.large,
                                 shapes=state_spec.params)
    oshard = _opt_shardings(optimizer_override or arch.optimizer, pshard,
                            state_spec.params, mesh)
    repl = NamedSharding(mesh, P())
    from ..runtime.step import TrainState
    state_sh = TrainState(params=pshard, opt_state=oshard, step=repl,
                          ef=None, outer=None)

    lead = (worker_axes if len(worker_axes) != 1 else worker_axes[0]) \
        if worker_axes else None
    data_left = "data" if arch.large else \
        ("model" if intra_worker in ("fsdp", "dp") else None)
    extra = (None,) if cfg.n_microbatches > 1 else ()

    def bsh(s):
        rest = (None,) * (len(s.shape) - 2 - len(extra))
        return NamedSharding(mesh, P(lead, *extra, data_left, *rest))

    batch_sh = jax.tree.map(bsh, bspec)

    jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
    tokens = shape.global_batch * shape.seq_len
    from ..analysis.roofline import model_flops
    return Cell(
        arch_id=arch.arch_id, shape_name=shape.name,
        mesh_name="multi_pod" if multi_pod else "single_pod", kind="train",
        jitted=jitted, arg_specs=(state_spec, bspec),
        n_devices=mesh.size,
        model_flops=model_flops(model.active_param_count(), tokens,
                                training=True),
        meta={"algo": algo, "phase": ph, "n_workers": w,
              "n_microbatches": cfg.n_microbatches,
              "intra_worker": intra_worker,
              "plan_counts": plan.meta.get("partition_counts"),
              "synced_units": list(plan.units_for_phase(ph))},
    )


# ---------------------------------------------------------------------------
# Serve cells
# ---------------------------------------------------------------------------

def _serve_param_shardings(arch: ArchSpec, model, mesh: Mesh, pspec):
    return param_shardings(model.param_specs(), mesh, worker_axes=(),
                           fsdp=arch.large, with_lead=False, shapes=pspec)


def build_prefill_cell(arch: ArchSpec, shape, mesh: Mesh, *,
                       multi_pod: bool) -> Cell:
    model = arch.make_model()
    b, s = shape.global_batch, shape.seq_len
    pspec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache_spec = jax.eval_shape(lambda: model.init_cache(b, s))
    bspec = batch_specs(arch, shape)
    fn = make_prefill_step(model, with_frontend=arch.frontend)

    pshard = _serve_param_shardings(arch, model, mesh, pspec)
    cshard = _cache_shardings(cache_spec, mesh, batch=b)
    dsh = _shard_if_divisible(mesh, b)
    tok_sh = NamedSharding(mesh, P(dsh, None))

    args = [pspec, bspec["tokens"], cache_spec]
    in_sh = [pshard, tok_sh, cshard]
    if arch.frontend == "audio":
        args.append(bspec["frames"])
        in_sh.append(NamedSharding(mesh, P(dsh, None, None)))
    elif arch.frontend == "vision":
        args.append(bspec["embeds"])
        in_sh.append(NamedSharding(mesh, P(dsh, None, None)))

    jitted = jax.jit(fn, in_shardings=tuple(in_sh),
                     out_shardings=(None, cshard), donate_argnums=(2,))
    from ..analysis.roofline import model_flops
    return Cell(
        arch_id=arch.arch_id, shape_name=shape.name,
        mesh_name="multi_pod" if multi_pod else "single_pod",
        kind="prefill", jitted=jitted, arg_specs=tuple(args),
        n_devices=mesh.size,
        model_flops=model_flops(model.active_param_count(), b * s,
                                training=False),
        meta={},
    )


def build_decode_cell(arch: ArchSpec, shape, mesh: Mesh, *,
                      multi_pod: bool) -> Cell:
    model = arch.make_model()
    b, s = shape.global_batch, shape.seq_len
    pspec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache_spec = jax.eval_shape(lambda: model.init_cache(b, s))
    bspec = batch_specs(arch, shape)
    fn = make_decode_step(model)

    pshard = _serve_param_shardings(arch, model, mesh, pspec)
    cshard = _cache_shardings(cache_spec, mesh, batch=b)
    dsh = _shard_if_divisible(mesh, b)
    in_sh = (pshard, cshard, NamedSharding(mesh, P(dsh, None)),
             NamedSharding(mesh, P(dsh)))
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=(None, cshard),
                     donate_argnums=(1,))
    from ..analysis.roofline import model_flops
    return Cell(
        arch_id=arch.arch_id, shape_name=shape.name,
        mesh_name="multi_pod" if multi_pod else "single_pod",
        kind="decode", jitted=jitted,
        arg_specs=(pspec, cache_spec, bspec["token"], bspec["pos"]),
        n_devices=mesh.size,
        model_flops=model_flops(model.active_param_count(), b,
                                training=False),
        meta={"kv_depth": s},
    )


def build_cell(arch_id: str, shape_name: str, mesh: Mesh, *,
               multi_pod: bool, **kw) -> Cell:
    arch = get_arch(arch_id)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_cell(arch, shape, mesh, multi_pod=multi_pod,
                                **kw)
    kw.pop("intra_worker", None)
    kw.pop("algo", None)
    kw.pop("phase", None)
    if shape.kind == "prefill":
        return build_prefill_cell(arch, shape, mesh, multi_pod=multi_pod,
                                  **kw)
    return build_decode_cell(arch, shape, mesh, multi_pod=multi_pod, **kw)
