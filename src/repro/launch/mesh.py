"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = {"shape": (16, 16), "axes": ("data", "model")}
MULTI_POD = {"shape": (2, 16, 16), "axes": ("pod", "data", "model")}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; ``multi_pod`` adds the 2-pod geo axis.

    The dry-run launcher sets ``XLA_FLAGS=--xla_force_host_platform_
    device_count=512`` before any jax import so this mesh can be built on
    the CPU-only container (see ``dryrun.py`` lines 1-2).
    """
    spec = MULTI_POD if multi_pod else SINGLE_POD
    return jax.make_mesh(spec["shape"], spec["axes"])
