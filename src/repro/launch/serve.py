"""Serving driver: continuous-batching engine over a reduced config.

Runs the :class:`repro.serve.ServeEngine` for real on CPU; the full
configs are exercised by the dry-run cells (prefill_32k / decode_32k /
long_500k).

Synthetic workload (uniform batch, like the old driver)::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --gen 16

Trace-driven mode — ``--requests`` takes a JSON file with a list of
request dicts (``tokens`` or ``prompt_len``, ``max_new_tokens``, optional
``eos_id`` / ``temperature`` / ``top_k`` / ``seed``)::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests trace.json --max-batch 4

Both modes print the engine's :class:`~repro.serve.EngineStats` report.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np


def _load_trace(path: str, vocab: int, rng) -> list[dict]:
    with open(path) as f:
        trace = json.load(f)
    if not isinstance(trace, list):
        raise ValueError(f"{path}: expected a JSON list of request dicts")
    for r in trace:
        if "tokens" not in r:
            n = int(r.get("prompt_len", 8))
            r["tokens"] = rng.randint(0, vocab, size=n).tolist()
        _ = r.setdefault("max_new_tokens", 16)
    return trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="synthetic mode: number of requests")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", default=None,
                    help="JSON trace file (list of request dicts)")
    ap.add_argument("--max-batch", type=int, default=None,
                    help="engine slot count (default: --batch)")
    ap.add_argument("--max-seq", type=int, default=None)
    ap.add_argument("--decode-block", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=None)
    ap.add_argument("--kv-backend", default="contiguous",
                    choices=("contiguous", "paged"))
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged backend)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="pool pages incl. trash page (default: worst "
                         "case); smaller pools defer admission")
    ap.add_argument("--serial-admission", action="store_true",
                    help="disable per-tick batched admission (one "
                         "prefill + one sync per request — the "
                         "equivalence oracle; identical greedy tokens)")
    args = ap.parse_args(argv)

    from ..configs import get_arch
    from ..serve import (EngineConfig, Request, SamplingParams, ServeEngine)

    arch = get_arch(args.arch)
    model = arch.make_smoke() if args.smoke else arch.make_model()
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)

    if args.requests:
        trace = _load_trace(args.requests, cfg.vocab, rng)
    else:
        trace = [{"tokens": rng.randint(0, cfg.vocab,
                                        size=args.prompt_len).tolist(),
                  "max_new_tokens": args.gen}
                 for _ in range(args.batch)]

    def req_extra(r):
        if arch.frontend == "audio":
            return (np.asarray(rng.standard_normal(
                (cfg.n_frames, cfg.d_model)), np.float32),)
        if arch.frontend == "vision":
            return (np.asarray(rng.standard_normal(
                (8, cfg.d_model)), np.float32),)
        return ()

    requests = [
        Request(tokens=r["tokens"],
                max_new_tokens=int(r["max_new_tokens"]),
                eos_id=r.get("eos_id"),
                sampling=SamplingParams(
                    temperature=float(r.get("temperature", 0.0)),
                    top_k=int(r.get("top_k", 0)),
                    seed=int(r.get("seed", 0))),
                extra=req_extra(r))
        for r in trace]

    prefix = 8 if arch.frontend == "vision" else 0
    need = max(prefix + len(r.tokens) + r.max_new_tokens
               for r in requests)
    max_seq = args.max_seq or need
    if args.kv_backend == "paged":       # pages divide the lane evenly
        max_seq += (-max_seq) % args.page_size
    engine = ServeEngine(
        model, params,
        EngineConfig(max_batch=args.max_batch or args.batch,
                     max_seq=max_seq,
                     decode_block=args.decode_block,
                     prefill_chunk=args.prefill_chunk,
                     kv_backend=args.kv_backend,
                     page_size=args.page_size,
                     kv_pages=args.kv_pages,
                     batched_admission=not args.serial_admission),
        frontend=arch.frontend)

    completions = engine.generate(requests)
    engine.take_completed()     # drain the bounded completion history
    st = engine.stats
    n_dec = st.decode_tokens
    ms_tok = (st.decode_time_s / n_dec * 1e3) if n_dec else 0.0
    print(f"arch={args.arch} requests={st.requests_completed} "
          f"prompt_tokens={st.prompt_tokens} "
          f"generated={st.generated_tokens}")
    print(f"prefill={st.prefill_time_s * 1e3:.1f}ms "
          f"({st.prefill_batches} batched prefills / {st.admit_ticks} "
          f"admit ticks)  "
          f"decode {n_dec} steps={st.decode_time_s * 1e3:.1f}ms "
          f"({ms_tok:.1f} ms/tok, {st.decode_tokens_per_s:.1f} tok/s)")
    print(f"ttft mean={st.mean_ttft_s * 1e3:.1f}ms  "
          f"latency mean={st.mean_latency_s * 1e3:.1f}ms  "
          f"slot_util={st.slot_utilization:.2f}")
    print("generated:", completions[0].tokens[:12])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
