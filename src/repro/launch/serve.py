"""Batched serving driver: prefill a prompt batch, decode greedily.

Runs a reduced config for real on CPU; the full configs are exercised by
the dry-run cells (prefill_32k / decode_32k / long_500k).

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    from ..configs import get_arch
    from ..runtime.step import make_decode_step, make_prefill_step

    arch = get_arch(args.arch)
    model = arch.make_smoke() if args.smoke else arch.make_model()
    cfg = model.cfg
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    b, s, gen = args.batch, args.prompt_len, args.gen
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab)

    prefill = jax.jit(make_prefill_step(model,
                                        with_frontend=arch.frontend))
    decode = jax.jit(make_decode_step(model))

    cache = model.init_cache(b, s + gen)
    extra = ()
    if arch.frontend == "audio":
        extra = (jax.random.normal(key, (b, cfg.n_frames, cfg.d_model)),)
    elif arch.frontend == "vision":
        extra = (jax.random.normal(key, (b, 8, cfg.d_model)),)

    t0 = time.perf_counter()
    logits, cache = prefill(params, tokens, cache, *extra)
    logits = jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    out = [jnp.argmax(logits, -1).astype(jnp.int32)]

    # Block per decode step: each measured section must cover exactly one
    # token's dispatch+compute, otherwise async dispatch skews ms/tok
    # (the old loop only blocked on the final token).
    tok_times = []
    for i in range(gen - 1):
        t0 = time.perf_counter()
        pos = jnp.full((b,), s + i, jnp.int32)
        logits, cache = decode(params, cache, out[-1], pos)
        out.append(jax.block_until_ready(
            jnp.argmax(logits, -1).astype(jnp.int32)))
        tok_times.append(time.perf_counter() - t0)
    t_decode = sum(tok_times)

    gen_tokens = jnp.concatenate(out, axis=1)
    ms_tok = t_decode / max(len(tok_times), 1) * 1e3
    print(f"arch={args.arch} prefill[{b}x{s}]={t_prefill * 1e3:.1f}ms  "
          f"decode {gen - 1} steps={t_decode * 1e3:.1f}ms "
          f"({ms_tok:.1f} ms/tok)")
    print("generated:", gen_tokens[0, :12].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
