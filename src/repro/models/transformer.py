"""Generic decoder-only LM: dense GQA, MoE, and MLA variants.

One class serves seven of the ten assigned architectures (granite, phi4,
qwen2.5, qwen3, llava backbone, qwen3-moe, deepseek-v3).  Blocks of the same
kind are **stacked** (leading layer axis) and executed with
``jax.lax.scan`` — constant HLO size in depth, the standard TPU idiom — and
the stack can be split at arbitrary unit boundaries (``segment_cuts``) so a
DreamDDP phase's parameter all-reduce becomes data-independent of the
remaining backward segments (the overlap window XLA's latency-hiding
scheduler exploits; DESIGN.md §2).

Parameter tree = dict of *groups* (the partial-sync unit space):
``embed`` / [``dense_blocks``] / ``blocks`` / [``mtp``] / ``head``.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from ..core.partial_sync import UnitEntry, UnitLayout
from ..kernels.paged_attention import paged_attention, write_token_to_pages
from . import mla as mla_mod
from . import moe as moe_mod
from .layers import (Init, apply_rope, dense, dense_init, embed_init,
                     gqa_attention, layer_norm, mlp_apply, mlp_init,
                     norm_init, rms_norm, rope_freqs, softmax_xent)

__all__ = ["LMConfig", "DecoderLM"]

PyTree = Any


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    qkv_bias: bool = False
    qk_norm: bool = False
    mlp_kind: str = "swiglu"
    norm_kind: str = "rmsnorm"
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    window: int | None = None             # local attention window
    param_dtype: str = "bfloat16"
    remat: bool = True
    attn_impl: str = "einsum"             # or "flash" (Pallas kernel)
    # MoE
    moe: moe_mod.MoEConfig | None = None
    n_dense_layers: int = 0               # leading dense layers (dsv3: 3)
    dense_d_ff: int | None = None
    # MLA
    mla: mla_mod.MLAConfig | None = None
    # Multi-token prediction (dsv3)
    mtp: bool = False
    mtp_weight: float = 0.3

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def runs(self) -> list[tuple[str, str, int]]:
        """(group_name, block_kind, n_layers) in network order."""
        if self.moe is None:
            return [("blocks", "dense", self.n_layers)]
        out = []
        if self.n_dense_layers:
            out.append(("dense_blocks", "dense", self.n_dense_layers))
        out.append(("blocks", "moe", self.n_layers - self.n_dense_layers))
        return out


class DecoderLM:
    """Functional decoder LM (init / apply / loss / prefill / decode)."""

    # cache entries are addressed by position and masked by valid length,
    # so right-padded (chunked) prefill cannot leak into decode
    kv_position_indexed = True
    # every attention variant (GQA / MoE blocks / MLA latents) stores
    # position-addressed KV, so the cache can live in a paged pool
    supports_paged_kv = True

    def __init__(self, cfg: LMConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def _attn_init(self, init: Init):
        cfg = self.cfg
        if cfg.mla is not None:
            return mla_mod.mla_init(init, cfg.mla, cfg.d_model,
                                    dtype=cfg.dtype)
        d, hd = cfg.d_model, cfg.hd
        p, s = {}, {}
        p["wq"], s["wq"] = dense_init(init, d, cfg.n_heads * hd,
                                      bias=cfg.qkv_bias, dtype=cfg.dtype,
                                      out_axis="heads")
        p["wk"], s["wk"] = dense_init(init, d, cfg.n_kv_heads * hd,
                                      bias=cfg.qkv_bias, dtype=cfg.dtype,
                                      out_axis="heads")
        p["wv"], s["wv"] = dense_init(init, d, cfg.n_kv_heads * hd,
                                      bias=cfg.qkv_bias, dtype=cfg.dtype,
                                      out_axis="heads")
        p["wo"], s["wo"] = dense_init(init, cfg.n_heads * hd, d,
                                      dtype=cfg.dtype,
                                      scale=(cfg.n_heads * hd) ** -0.5,
                                      in_axis="heads")
        if cfg.qk_norm:
            p["q_norm"], s["q_norm"] = norm_init(hd, dtype=cfg.dtype)
            p["k_norm"], s["k_norm"] = norm_init(hd, dtype=cfg.dtype)
        return p, s

    def _block_init(self, key: jax.Array, kind: str):
        cfg = self.cfg
        init = Init(key)
        p, s = {}, {}
        p["ln1"], s["ln1"] = norm_init(cfg.d_model, dtype=cfg.dtype,
                                       bias=cfg.norm_kind == "layernorm")
        p["attn"], s["attn"] = self._attn_init(init)
        p["ln2"], s["ln2"] = norm_init(cfg.d_model, dtype=cfg.dtype,
                                       bias=cfg.norm_kind == "layernorm")
        if kind == "moe":
            p["mlp"], s["mlp"] = moe_mod.moe_init(init, cfg.moe, cfg.d_model,
                                                  dtype=cfg.dtype)
        else:
            d_ff = cfg.dense_d_ff or cfg.d_ff
            p["mlp"], s["mlp"] = mlp_init(init, cfg.d_model, d_ff,
                                          kind=cfg.mlp_kind, dtype=cfg.dtype)
        return p, s

    def init(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        keys = iter(jax.random.split(key, 8))
        params: dict = {}
        params["embed"], self._embed_spec = embed_init(
            Init(next(keys)), cfg.vocab, cfg.d_model, dtype=cfg.dtype)
        for group, kind, n in cfg.runs():
            lkeys = jax.random.split(next(keys), n)
            params[group] = jax.vmap(
                lambda k, kd=kind: self._block_init(k, kd)[0])(lkeys)
        if cfg.mtp:
            init = Init(next(keys))
            blk, _ = self._block_init(init.next(),
                                      cfg.runs()[-1][1])
            proj, _ = dense_init(init, 2 * cfg.d_model, cfg.d_model,
                                 dtype=cfg.dtype)
            nrm, _ = norm_init(cfg.d_model, dtype=cfg.dtype)
            params["mtp"] = {"block": blk, "proj": proj, "norm": nrm}
        head: dict = {"norm": norm_init(cfg.d_model, dtype=cfg.dtype,
                                        bias=cfg.norm_kind == "layernorm")[0]}
        if not cfg.tie_embeddings:
            head["out"], _ = dense_init(Init(next(keys)), cfg.d_model,
                                        cfg.vocab, dtype=cfg.dtype,
                                        out_axis="vocab")
        params["head"] = head
        return params

    def param_specs(self) -> PyTree:
        """Logical-axis spec tree mirroring ``init``'s output (stacked
        groups get a leading ``layers`` axis)."""
        cfg = self.cfg
        specs: dict = {"embed": {"table": ("vocab", None)}}
        for group, kind, _ in cfg.runs():
            blk_spec = self._block_init_spec(kind)
            specs[group] = jax.tree.map(
                lambda sp: ("layers",) + tuple(sp), blk_spec,
                is_leaf=lambda x: isinstance(x, tuple))
        if cfg.mtp:
            specs["mtp"] = {
                "block": self._block_init_spec(cfg.runs()[-1][1]),
                "proj": {"w": (None, None)},
                "norm": {"scale": (None,)},
            }
        head: dict = {"norm": {"scale": (None,)}}
        if cfg.norm_kind == "layernorm":
            head["norm"]["bias"] = (None,)
        if not cfg.tie_embeddings:
            head["out"] = {"w": (None, "vocab")}
        specs["head"] = head
        return specs

    def _block_init_spec(self, kind: str) -> PyTree:
        """Spec of one (unstacked) block — computed without materializing
        any arrays (the spec is side-channeled out of an eval_shape trace)."""
        box: dict = {}

        def fn(k):
            p, s = self._block_init(k, kind)
            box["spec"] = s
            return p

        jax.eval_shape(fn, jax.random.PRNGKey(0))
        return box["spec"]

    # ----------------------------------------------------------------- apply
    def _project_qkv(self, p, x, positions):
        """Shared GQA preamble: projections, optional qk-norm, RoPE.
        Both cache layouts (contiguous lanes and the paged pool) go
        through here, so the paged-vs-contiguous bitwise equivalence
        cannot drift."""
        cfg = self.cfg
        b, s, _ = x.shape
        hd = cfg.hd
        q = dense(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
        k = dense(p["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
        v = dense(p["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
        if cfg.qk_norm:
            q = rms_norm(p["q_norm"], q)
            k = rms_norm(p["k_norm"], k)
        inv_freq = rope_freqs(hd, cfg.rope_theta)
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)
        return q, k, v

    def _attend(self, p, x, positions, cache, write_pos):
        """Attention sub-layer; returns (out, new_cache_entry)."""
        cfg = self.cfg
        if cfg.mla is not None:
            if cache is None:
                out, _ = mla_mod.mla_apply_full(p, cfg.mla, x, positions)
                return out, None
            if x.shape[1] > 1:           # prefill: full pass, then fill cache
                out, fresh = mla_mod.mla_apply_full(p, cfg.mla, x, positions)
                pos0 = write_pos[0]
                new_cache = {
                    k: jax.lax.dynamic_update_slice_in_dim(
                        cache[k], fresh[k].astype(cache[k].dtype), pos0,
                        axis=1)
                    for k in ("c_kv", "k_rope")
                }
                return out, new_cache
            return mla_mod.mla_decode(p, cfg.mla, x, cache, write_pos)

        b, s, _ = x.shape
        q, k, v = self._project_qkv(p, x, positions)

        if cache is None:
            out = gqa_attention(q, k, v, q_positions=positions,
                                kv_positions=positions, causal=True,
                                window=cfg.window)
            new_cache = None
        else:
            pos0 = write_pos[0]
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), pos0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), pos0, axis=1)
            sk = ck.shape[1]
            kv_pos = jnp.broadcast_to(jnp.arange(sk), (b, sk))
            out = gqa_attention(q, ck, cv, q_positions=positions,
                                kv_positions=kv_pos, causal=True,
                                window=cfg.window,
                                kv_valid_len=write_pos + s)
            new_cache = {"k": ck, "v": cv}
        return out.reshape(b, s, -1) @ p["wo"]["w"], new_cache

    def _norm(self, p, x):
        return (rms_norm(p, x) if self.cfg.norm_kind == "rmsnorm"
                else layer_norm(p, x))

    def _block_apply(self, kind: str, p, x, positions, cache=None,
                     write_pos=None):
        a, new_cache = self._attend(p["attn"], self._norm(p["ln1"], x),
                                    positions, cache, write_pos)
        x = x + a
        h = self._norm(p["ln2"], x)
        if kind == "moe":
            x = x + moe_mod.moe_apply(p["mlp"], self.cfg.moe, h)
        else:
            x = x + mlp_apply(p["mlp"], h, kind=self.cfg.mlp_kind)
        return x, new_cache

    def _run_stack(self, kind, stacked, x, positions, cache=None,
                   write_pos=None, cuts=()):
        """Scan a block stack over its layer axis, split at ``cuts``."""
        n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        bounds = sorted({0, n, *[c for c in cuts if 0 < c < n]})
        caches = []
        for lo, hi in zip(bounds[:-1], bounds[1:], strict=True):
            seg = jax.tree.map(lambda a, lo=lo, hi=hi: a[lo:hi], stacked)
            seg_cache = (None if cache is None else
                         jax.tree.map(lambda a, lo=lo, hi=hi: a[lo:hi],
                                      cache))

            def body(carry, xs):
                lp, lc = xs
                fn = self._block_apply
                if self.cfg.remat and cache is None:
                    fn = jax.checkpoint(fn, static_argnums=(0,))
                y, nc = fn(kind, lp, carry, positions, lc, write_pos)
                return y, nc

            x, new_c = jax.lax.scan(body, x, (seg, seg_cache))
            if cache is not None:
                caches.append(new_c)
        if cache is None:
            return x, None
        new_cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *caches)
        return x, new_cache

    def _embed(self, params, tokens, embeds):
        cfg = self.cfg
        parts = []
        if embeds is not None:
            parts.append(embeds.astype(cfg.dtype))
        if tokens is not None:
            parts.append(params["embed"]["table"][tokens])
        return jnp.concatenate(parts, axis=1) if len(parts) > 1 \
            else parts[0]

    def _head(self, params, x):
        x = self._norm(params["head"]["norm"], x)
        if self.cfg.tie_embeddings:
            return x @ params["embed"]["table"].T
        return dense(params["head"]["out"], x)

    def _backbone(self, params, tokens=None, *, embeds=None, positions=None,
                  segment_cuts: tuple[int, ...] = ()) -> jax.Array:
        """Embed + block stacks -> final hidden states ``[b, s_total, d]``.

        ``segment_cuts`` are *global unit ids* (layout order) at which block
        stacks are split into separate scans (DreamDDP overlap windows).
        """
        cfg = self.cfg
        x = self._embed(params, tokens, embeds)
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        unit0 = 1                        # unit 0 is the embedding
        for group, kind, n in cfg.runs():
            local_cuts = tuple(c - unit0 for c in segment_cuts
                               if unit0 < c < unit0 + n)
            x, _ = self._run_stack(kind, params[group], x, positions,
                                   cuts=local_cuts)
            unit0 += n
        return x

    def apply(self, params, tokens=None, *, embeds=None, positions=None,
              segment_cuts: tuple[int, ...] = ()) -> jax.Array:
        """Full-sequence forward -> logits ``[b, s_total, vocab]``."""
        x = self._backbone(params, tokens, embeds=embeds,
                           positions=positions, segment_cuts=segment_cuts)
        return self._head(params, x)

    # ----------------------------------------------------------------- loss
    def loss(self, params, batch, *,
             segment_cuts: tuple[int, ...] = ()) -> jax.Array:
        cfg = self.cfg
        embeds = batch.get("embeds")
        tokens = batch.get("tokens")
        x = self._backbone(params, tokens, embeds=embeds,
                           segment_cuts=segment_cuts)
        if embeds is not None:           # VLM: loss on the text tail only
            x = x[:, embeds.shape[1]:]
        logits = self._head(params, x)
        labels = batch["labels"]
        loss = softmax_xent(logits[:, :-1], labels[:, 1:])
        if cfg.mtp:
            loss = loss + cfg.mtp_weight * self._mtp_loss(params, x, batch)
        return loss

    def _mtp_loss(self, params, trunk_h, batch) -> jax.Array:
        """DeepSeek-V3 multi-token prediction: one extra block predicts
        token ``t+2`` from ``[h_t ; E(tok_{t+1})]`` (trunk is shared)."""
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        b, s, _ = trunk_h.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        mtp = params["mtp"]
        nxt = params["embed"]["table"][tokens[:, 1:]]
        h = jnp.concatenate([self._norm(mtp["norm"], trunk_h[:, :-1]), nxt],
                            -1)
        h = dense(mtp["proj"], h)
        h, _ = self._block_apply(cfg.runs()[-1][1], mtp["block"], h,
                                 positions[:, :-1])
        logits = self._head(params, h)
        return softmax_xent(logits[:, :-1], labels[:, 2:])

    # --------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_seq: int) -> PyTree:
        cfg = self.cfg
        cache: dict = {}
        for group, _kind, n in cfg.runs():
            if cfg.mla is not None:
                one = mla_mod.mla_init_cache(cfg.mla, batch, max_seq,
                                             cfg.dtype)
            else:
                one = {
                    "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.hd),
                                   cfg.dtype),
                    "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.hd),
                                   cfg.dtype),
                }
            cache[group] = jax.tree.map(
                lambda a, n=n: jnp.broadcast_to(a[None], (n,) + a.shape),
                one)
        return cache

    def prefill(self, params, tokens, cache, *,
                embeds=None) -> tuple[jax.Array, PyTree]:
        """Fill the cache with ``tokens`` (``embeds`` optionally prepended —
        VLM prefix); returns (last-token logits, cache)."""
        cfg = self.cfg
        x = self._embed(params, tokens, embeds)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        write_pos = jnp.zeros((b,), jnp.int32)
        new_cache = {}
        for group, kind, _n in cfg.runs():
            x, new_cache[group] = self._run_stack(
                kind, params[group], x, positions,
                cache=cache[group], write_pos=write_pos)
        logits = self._head(params, x[:, -1:])
        return logits, new_cache

    def decode_step(self, params, cache, token, pos
                    ) -> tuple[jax.Array, PyTree]:
        """One-token decode.  ``token [b, 1]``, ``pos [b]`` (write index)."""
        cfg = self.cfg
        x = self._embed(params, token, None)
        b = x.shape[0]
        positions = pos[:, None]
        new_cache = {}
        for group, kind, _n in cfg.runs():
            x, new_cache[group] = self._run_stack(
                kind, params[group], x, positions,
                cache=cache[group], write_pos=pos)
        return self._head(params, x), new_cache

    # -------------------------------------------------------- paged serving
    def init_paged_cache(self, n_pages: int, page_size: int) -> PyTree:
        """Global KV page pool: per group, leaves are ``[layers, n_pages,
        page_size, ...]`` (GQA: k/v heads; MLA: latent + key-rope).  Page
        0 is reserved by the pool as a trash page (see
        :class:`repro.serve.cache.PagedCachePool`)."""
        cfg = self.cfg
        cache: dict = {}
        for group, _kind, n in cfg.runs():
            if cfg.mla is not None:
                one = mla_mod.mla_init_paged_cache(cfg.mla, n_pages,
                                                   page_size, cfg.dtype)
            else:
                one = {
                    "k": jnp.zeros((n_pages, page_size, cfg.n_kv_heads,
                                    cfg.hd), cfg.dtype),
                    "v": jnp.zeros((n_pages, page_size, cfg.n_kv_heads,
                                    cfg.hd), cfg.dtype),
                }
            cache[group] = jax.tree.map(
                lambda a, n=n: jnp.broadcast_to(a[None], (n,) + a.shape),
                one)
        return cache

    def _attend_paged(self, p, x, positions, pages, block_tables, pos,
                      active):
        """Paged-pool counterpart of the decode branch of ``_attend``:
        write this token's KV into its slot's current page (inactive
        lanes write the trash page), then attend through the block
        table.  ``x [slots, 1, d]``."""
        cfg = self.cfg
        if cfg.mla is not None:
            return mla_mod.mla_decode_paged(p, cfg.mla, x, pages,
                                            block_tables, pos, active)
        b, s, _ = x.shape
        q, k, v = self._project_qkv(p, x, positions)
        ck = write_token_to_pages(pages["k"], block_tables, pos, active,
                                  k[:, 0])
        cv = write_token_to_pages(pages["v"], block_tables, pos, active,
                                  v[:, 0])
        out = paged_attention(q[:, 0], ck, cv, block_tables, pos + 1,
                              window=cfg.window)
        return out.reshape(b, s, -1) @ p["wo"]["w"], {"k": ck, "v": cv}

    def _block_apply_paged(self, kind, p, x, positions, pages,
                           block_tables, pos, active):
        a, new_pages = self._attend_paged(p["attn"],
                                          self._norm(p["ln1"], x),
                                          positions, pages, block_tables,
                                          pos, active)
        x = x + a
        h = self._norm(p["ln2"], x)
        if kind == "moe":
            x = x + moe_mod.moe_apply(p["mlp"], self.cfg.moe, h)
        else:
            x = x + mlp_apply(p["mlp"], h, kind=self.cfg.mlp_kind)
        return x, new_pages

    def decode_step_paged(self, params, pages, token, pos, block_tables,
                          active) -> tuple[jax.Array, PyTree]:
        """Slot-batched one-token decode against the page pool.

        ``token [slots, 1]``, ``pos [slots]`` (per-slot write index),
        ``block_tables [slots, max_blocks]`` int32, ``active [slots]``
        bool (gates page writes).  Returns (logits ``[slots, 1, vocab]``,
        updated page pool).
        """
        cfg = self.cfg
        x = self._embed(params, token, None)
        positions = pos[:, None]
        new_pages = {}
        for group, kind, _n in cfg.runs():
            def body(carry, xs, kd=kind):
                lp, lpg = xs
                return self._block_apply_paged(kd, lp, carry, positions,
                                               lpg, block_tables, pos,
                                               active)

            x, new_pages[group] = jax.lax.scan(
                body, x, (params[group], pages[group]))
        return self._head(params, x), new_pages

    # ------------------------------------------------------------- structure
    def unit_layout(self) -> UnitLayout:
        cfg = self.cfg
        entries = [UnitEntry("embed", "embed", None)]
        gi = 0
        for group, _kind, n in cfg.runs():
            for i in range(n):
                entries.append(UnitEntry(f"layer_{gi + i}", group, i))
            gi += n
        if cfg.mtp:
            entries.append(UnitEntry("mtp", "mtp", None))
        entries.append(UnitEntry("head", "head", None))
        return UnitLayout(tuple(entries))

    # ---------------------------------------------------- analytic accounting
    def _block_param_count(self, kind: str) -> int:
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.hd
        if cfg.mla is not None:
            attn = mla_mod.mla_param_count(cfg.mla, d)
        else:
            attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
                + cfg.n_heads * hd * d
            if cfg.qkv_bias:
                attn += hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
            if cfg.qk_norm:
                attn += 2 * hd
        norms = 2 * d * (2 if cfg.norm_kind == "layernorm" else 1)
        if kind == "moe":
            mlp = moe_mod.moe_param_count(cfg.moe, d)
        else:
            d_ff = cfg.dense_d_ff or cfg.d_ff
            mlp = d * d_ff * (3 if cfg.mlp_kind == "swiglu" else 2)
        return attn + mlp + norms

    def param_count(self) -> int:
        cfg = self.cfg
        n = cfg.vocab * cfg.d_model                       # embed
        for _group, kind, cnt in cfg.runs():
            n += cnt * self._block_param_count(kind)
        if cfg.mtp:
            n += self._block_param_count(cfg.runs()[-1][1]) \
                + 2 * cfg.d_model * cfg.d_model + cfg.d_model
        n += cfg.d_model                                  # final norm
        if not cfg.tie_embeddings:
            n += cfg.d_model * cfg.vocab
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared only)."""
        cfg = self.cfg
        if cfg.moe is None:
            return self.param_count()
        n = cfg.vocab * cfg.d_model + cfg.d_model
        if not cfg.tie_embeddings:
            n += cfg.d_model * cfg.vocab
        for _group, kind, cnt in cfg.runs():
            per = self._block_param_count(kind)
            if kind == "moe":
                per = (per - moe_mod.moe_param_count(cfg.moe, cfg.d_model)
                       + moe_mod.moe_active_param_count(cfg.moe, cfg.d_model))
            n += cnt * per
        return n

    def _block_fwd_flops(self, kind: str, tokens: int, seq: int,
                         kv_len: int) -> float:
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.hd
        if cfg.mla is not None:
            attn = mla_mod.mla_fwd_flops(cfg.mla, d, tokens, kv_len)
        else:
            proj = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
                + cfg.n_heads * hd * d
            att_len = kv_len if cfg.window is None else min(kv_len,
                                                            cfg.window)
            attn = 2.0 * tokens * proj \
                + 2.0 * tokens * att_len * cfg.n_heads * hd * 2
        if kind == "moe":
            mlp = moe_mod.moe_fwd_flops(cfg.moe, d, tokens, seq)
        else:
            d_ff = cfg.dense_d_ff or cfg.d_ff
            mlp = 2.0 * tokens * d * d_ff * (3 if cfg.mlp_kind == "swiglu"
                                             else 2)
        return attn + mlp

    def layer_costs(self, batch: int, seq: int, *,
                    mode: str = "train") -> list[tuple[str, float, float]]:
        """(unit_name, n_params, fwd_flops) per unit — profiler input.

        ``mode="decode"`` charges one-token steps against a ``seq``-deep KV
        cache (serving shapes)."""
        cfg = self.cfg
        if mode == "train":
            tokens, kv_len, s = batch * seq, seq, seq
        else:
            tokens, kv_len, s = batch * 1, seq, seq
        out = [("embed", float(cfg.vocab * cfg.d_model), 2.0 * tokens
                * cfg.d_model)]
        gi = 0
        for _group, kind, cnt in cfg.runs():
            per_p = float(self._block_param_count(kind))
            per_f = self._block_fwd_flops(kind, tokens, s, kv_len)
            for i in range(cnt):
                out.append((f"layer_{gi + i}", per_p, per_f))
            gi += cnt
        if cfg.mtp:
            p = float(self._block_param_count(cfg.runs()[-1][1])
                      + 2 * cfg.d_model * cfg.d_model)
            f = self._block_fwd_flops(cfg.runs()[-1][1], tokens, s, kv_len) \
                + 2.0 * tokens * 2 * cfg.d_model * cfg.d_model
            out.append(("mtp", p, f))
        head_p = float(cfg.d_model + (0 if cfg.tie_embeddings
                                      else cfg.d_model * cfg.vocab))
        head_f = 2.0 * tokens * cfg.d_model * cfg.vocab
        out.append(("head", head_p, head_f))
        return out
