"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local MQA.

Layer pattern is 2 recurrent : 1 local-attention (arXiv:2402.19427).  The
38 layers are organised as 12 scanned **superblocks** of (rec, rec, attn) —
each sub-block followed by a GeGLU MLP — plus a 2-layer (rec, rec) tail
stack.  A superblock is one schedulable DreamDDP unit: exactly the
heterogeneous per-layer cost profile where Algorithm 2 beats the
equal-number partition.

The RG-LRU recurrence ``h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t x_t)`` runs
as a ``jax.lax.associative_scan`` for train/prefill (log-depth, TPU
friendly) and as an O(1) state update for decode — with the 2048-token
local-attention window this makes ``long_500k`` decoding constant-memory.

Gates use the reference block-diagonal linears (``n_blocks = n_heads``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..core.partial_sync import UnitEntry, UnitLayout
from .layers import (Init, apply_rope, dense, dense_init, gqa_attention,
                     norm_init, rms_norm, rope_freqs, softmax_xent)

__all__ = ["RGConfig", "RGLM", "rg_lru_scan"]

PyTree = Any
_C = 8.0  # RG-LRU temperature


@dataclass(frozen=True)
class RGConfig:
    name: str
    n_layers: int                     # total temporal layers (38)
    d_model: int
    n_heads: int
    n_kv_heads: int                   # 1 (MQA)
    d_ff: int
    vocab: int
    lru_width: int | None = None
    head_dim: int | None = None
    window: int = 2048
    conv_width: int = 4
    pattern: tuple[str, ...] = ("rec", "rec", "attn")
    rope_theta: float = 1e4
    param_dtype: str = "bfloat16"
    remat: bool = True
    tie_embeddings: bool = True

    @property
    def lru(self) -> int:
        return self.lru_width or self.d_model

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def n_super(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def n_tail(self) -> int:
        return self.n_layers % len(self.pattern)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def _block_diag(p, x):
    """Block-diagonal linear: w ``[nb, c, c]``, x ``[..., nb*c]``."""
    nb, c, _ = p["w"].shape
    xs = x.reshape(x.shape[:-1] + (nb, c))
    y = jnp.einsum("...nc,ncd->...nd", xs, p["w"]).reshape(x.shape)
    return y + p["b"]


def rg_lru_scan(log_a: jax.Array, bt: jax.Array,
                h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """First-order recurrence h_t = exp(log_a_t) h_{t-1} + b_t over axis 1.

    Returns (all h ``[B, L, D]``, final h ``[B, D]``).  ``h0`` optionally
    seeds the recurrence (decode prefix)."""
    if h0 is not None:
        # fold h0 in as a virtual step 0 contribution
        bt = bt.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 + a2, jnp.exp(a2) * b1 + b2

    la, hs = jax.lax.associative_scan(combine, (log_a, bt), axis=1)
    return hs, hs[:, -1]


def _rg_lru_apply(p, x, h0=None):
    """x ``[B, L, lru]`` -> (y, h_final).  Gates + gated recurrence."""
    r = jax.nn.sigmoid(_block_diag(p["r_gate"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(p["i_gate"], x).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i * x.astype(jnp.float32)
    hs, h_fin = rg_lru_scan(log_a, gated, h0)
    return hs.astype(x.dtype), h_fin


def _rg_lru_step(p, x, h):
    """One-token step.  x ``[B, lru]``, h ``[B, lru]`` (float32)."""
    r = jax.nn.sigmoid(_block_diag(p["r_gate"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(p["i_gate"], x).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    h_new = a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
        * i * x.astype(jnp.float32)
    return h_new.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class RGLM:
    # LRU states and attention ring buffers fold pad steps in, so
    # right-padded (chunked) prefill would corrupt them — exact prefill only
    kv_position_indexed = False

    def __init__(self, cfg: RGConfig):
        self.cfg = cfg

    # -- sub-block inits ------------------------------------------------------
    def _rec_init(self, init: Init):
        cfg = self.cfg
        d, lru, nb = cfg.d_model, cfg.lru, cfg.n_heads
        c = lru // nb
        gate = lambda: {"w": init.normal((nb, c, c), c ** -0.5, cfg.dtype),
                        "b": jnp.zeros((lru,), cfg.dtype)}
        return {
            "ln": norm_init(d, dtype=cfg.dtype)[0],
            "in_x": dense_init(init, d, lru, dtype=cfg.dtype,
                               out_axis="heads")[0],
            "in_gate": dense_init(init, d, lru, dtype=cfg.dtype,
                                  out_axis="heads")[0],
            "conv": init.normal((cfg.conv_width, lru),
                                cfg.conv_width ** -0.5, cfg.dtype),
            "conv_bias": jnp.zeros((lru,), cfg.dtype),
            "r_gate": gate(), "i_gate": gate(),
            "lam": jnp.linspace(0.9, 4.0, lru, dtype=jnp.float32),
            "out": dense_init(init, lru, d, dtype=cfg.dtype,
                              scale=lru ** -0.5, in_axis="heads")[0],
            "mlp": self._mlp_init(init),
            "ln_mlp": norm_init(d, dtype=cfg.dtype)[0],
        }

    def _attn_init(self, init: Init):
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.hd
        return {
            "ln": norm_init(d, dtype=cfg.dtype)[0],
            "wq": dense_init(init, d, cfg.n_heads * hd, dtype=cfg.dtype,
                             out_axis="heads")[0],
            "wk": dense_init(init, d, cfg.n_kv_heads * hd,
                             dtype=cfg.dtype)[0],
            "wv": dense_init(init, d, cfg.n_kv_heads * hd,
                             dtype=cfg.dtype)[0],
            "wo": dense_init(init, cfg.n_heads * hd, d, dtype=cfg.dtype,
                             scale=(cfg.n_heads * hd) ** -0.5,
                             in_axis="heads")[0],
            "mlp": self._mlp_init(init),
            "ln_mlp": norm_init(d, dtype=cfg.dtype)[0],
        }

    def _mlp_init(self, init: Init):
        cfg = self.cfg
        return {
            "gate": dense_init(init, cfg.d_model, cfg.d_ff, dtype=cfg.dtype,
                               out_axis="ff")[0],
            "up": dense_init(init, cfg.d_model, cfg.d_ff, dtype=cfg.dtype,
                             out_axis="ff")[0],
            "down": dense_init(init, cfg.d_ff, cfg.d_model, dtype=cfg.dtype,
                               scale=cfg.d_ff ** -0.5, in_axis="ff")[0],
        }

    def _super_init(self, key: jax.Array):
        init = Init(key)
        out = {}
        for j, kind in enumerate(self.cfg.pattern):
            out[f"sub{j}"] = (self._rec_init(init) if kind == "rec"
                              else self._attn_init(init))
        return out

    def init(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        params: dict = {"embed": {"table": Init(k1).normal(
            (cfg.vocab, cfg.d_model), 1.0, cfg.dtype)}}
        skeys = jax.random.split(k2, cfg.n_super)
        params["blocks"] = jax.vmap(self._super_init)(skeys)
        if cfg.n_tail:
            tkeys = jax.random.split(k3, cfg.n_tail)
            params["tail"] = jax.vmap(
                lambda k: self._rec_init(Init(k)))(tkeys)
        params["head"] = {"norm": norm_init(cfg.d_model,
                                            dtype=cfg.dtype)[0]}
        return params

    def param_specs(self) -> PyTree:
        """Logical-axis spec tree mirroring ``init`` (structure-derived:
        2D+ leaves shard their widest dim over ``heads``->model)."""
        shapes = jax.eval_shape(self.init, jax.random.PRNGKey(0))

        def one(sds):
            nd = len(sds.shape)
            if nd <= 1:
                return (None,) * nd
            # stacked leaves: [n_super/n_tail, ...]; shard the largest
            # trailing dim over the model axis
            dims = [None] * nd
            widest = max(range(1, nd), key=lambda i: sds.shape[i])
            dims[widest] = "heads"
            dims[0] = "layers"
            return tuple(dims)

        specs = jax.tree.map(one, shapes)
        specs["embed"] = {"table": ("vocab", None)}
        specs["head"] = {"norm": {"scale": (None,)}}
        return specs

    # -- sub-block applies ----------------------------------------------------
    def _mlp(self, p, x):
        h = jax.nn.gelu(dense(p["gate"], x)) * dense(p["up"], x)
        return dense(p["down"], h)

    def _conv_full(self, p, u):
        w = p["conv"]
        width = w.shape[0]
        pad = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
        return sum(pad[:, i:i + u.shape[1]] * w[i] for i in range(width)) \
            + p["conv_bias"]

    def _rec_apply(self, p, x, state=None):
        """state = (conv_state [B,W-1,lru], h [B,lru]) or None."""
        cfg = self.cfg
        xin = rms_norm(p["ln"], x)
        gate = jax.nn.gelu(dense(p["in_gate"], xin))
        u = dense(p["in_x"], xin)
        if state is None:
            u = self._conv_full(p, u)
            y, _ = _rg_lru_apply(p, u)
            new_state = None
        else:
            conv_state, h = state
            hist = jnp.concatenate([conv_state, u], 1)
            new_conv = hist[:, 1:]
            u1 = jnp.einsum("bwc,wc->bc", hist, p["conv"]) + p["conv_bias"]
            y1, h_new = _rg_lru_step(p, u1, h)
            y = y1[:, None]
            new_state = (new_conv, h_new.astype(jnp.float32))
        x = x + dense(p["out"], y * gate)
        x = x + self._mlp(p["mlp"], rms_norm(p["ln_mlp"], x))
        return x, new_state

    def _attn_apply(self, p, x, positions, cache=None, write_pos=None):
        cfg = self.cfg
        b, s, _ = x.shape
        hd = cfg.hd
        xin = rms_norm(p["ln"], x)
        q = dense(p["wq"], xin).reshape(b, s, cfg.n_heads, hd)
        k = dense(p["wk"], xin).reshape(b, s, cfg.n_kv_heads, hd)
        v = dense(p["wv"], xin).reshape(b, s, cfg.n_kv_heads, hd)
        inv = rope_freqs(hd, cfg.rope_theta)
        q = apply_rope(q, positions, inv)
        k = apply_rope(k, positions, inv)
        if cache is None:
            att = gqa_attention(q, k, v, q_positions=positions,
                                kv_positions=positions, causal=True,
                                window=cfg.window)
            new_cache = None
        else:
            # ring-buffer window cache: slot = pos % window
            slot = write_pos[0] % cfg.window
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
            cpos = jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], positions.astype(cache["pos"].dtype), slot,
                axis=1)
            att = gqa_attention(q, ck, cv, q_positions=positions,
                                kv_positions=cpos, causal=True,
                                window=cfg.window)
            new_cache = {"k": ck, "v": cv, "pos": cpos}
        att = att.reshape(b, s, -1)
        x = x + dense(p["wo"], att)
        x = x + self._mlp(p["mlp"], rms_norm(p["ln_mlp"], x))
        return x, new_cache

    def _super_apply(self, p, x, positions, cache=None, write_pos=None):
        new_cache = {}
        for j, kind in enumerate(self.cfg.pattern):
            sub = p[f"sub{j}"]
            key = f"sub{j}"
            if kind == "rec":
                st = None if cache is None else cache[key]
                x, ns = self._rec_apply(sub, x, st)
            else:
                st = None if cache is None else cache[key]
                x, ns = self._attn_apply(sub, x, positions, st, write_pos)
            new_cache[key] = ns
        return x, (None if cache is None else new_cache)

    # -- full model ----------------------------------------------------------
    def _backbone(self, params, tokens, cache=None, write_pos=None,
                  positions=None):
        cfg = self.cfg
        x = params["embed"]["table"][tokens] * (cfg.d_model ** 0.5)
        b, s = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def sup_body(carry, xs):
            lp, lc = xs
            fn = self._super_apply
            if cfg.remat and cache is None:
                fn = jax.checkpoint(fn)
            y, nc = fn(lp, carry, positions, lc, write_pos)
            return y, nc

        sc = None if cache is None else cache["blocks"]
        x, new_sc = jax.lax.scan(sup_body, x, (params["blocks"], sc))
        new_cache = None if cache is None else {"blocks": new_sc}
        if cfg.n_tail:
            def tail_body(carry, xs):
                lp, lc = xs
                fn = self._rec_apply
                if cfg.remat and cache is None:
                    fn = jax.checkpoint(fn)
                return fn(lp, carry, lc)
            tc = None if cache is None else cache["tail"]
            x, new_tc = jax.lax.scan(tail_body, x, (params["tail"], tc))
            if cache is not None:
                new_cache["tail"] = new_tc
        return x, new_cache

    def _head(self, params, x):
        x = rms_norm(params["head"]["norm"], x)
        return x @ params["embed"]["table"].T

    def apply(self, params, tokens) -> jax.Array:
        x, _ = self._backbone(params, tokens)
        return self._head(params, x)

    def loss(self, params, batch, *, segment_cuts=()) -> jax.Array:
        logits = self.apply(params, batch["tokens"])
        return softmax_xent(logits[:, :-1], batch["labels"][:, 1:])

    # -- serving ---------------------------------------------------------------
    def _rec_state0(self, batch):
        cfg = self.cfg
        return (jnp.zeros((batch, cfg.conv_width - 1, cfg.lru), cfg.dtype),
                jnp.zeros((batch, cfg.lru), jnp.float32))

    def _attn_cache0(self, batch):
        cfg = self.cfg
        w = cfg.window
        return {"k": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.hd), cfg.dtype),
                "v": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.hd), cfg.dtype),
                "pos": jnp.full((batch, w), -10 ** 9, jnp.int32)}

    def init_cache(self, batch: int, max_seq: int) -> PyTree:
        cfg = self.cfg
        one = {f"sub{j}": (self._rec_state0(batch) if kind == "rec"
                           else self._attn_cache0(batch))
               for j, kind in enumerate(cfg.pattern)}
        cache = {"blocks": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_super,) + a.shape),
            one)}
        if cfg.n_tail:
            t = self._rec_state0(batch)
            cache["tail"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None],
                                           (cfg.n_tail,) + a.shape), t)
        return cache

    def prefill(self, params, tokens, cache) -> tuple[jax.Array, PyTree]:
        """Full-sequence pass that also captures decode states (recurrent h,
        conv tails, window ring buffers) in one sweep."""
        x, cache = self._prefill_states(params, tokens, cache)
        return self._head(params, x[:, -1:]), cache

    def _prefill_states(self, params, tokens, cache) -> PyTree:
        cfg = self.cfg
        b, s = tokens.shape
        x = params["embed"]["table"][tokens] * (cfg.d_model ** 0.5)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def capture_rec(p, x):
            xin = rms_norm(p["ln"], x)
            gate = jax.nn.gelu(dense(p["in_gate"], xin))
            u = dense(p["in_x"], xin)
            conv_state = jnp.pad(
                u, ((0, 0), (max(cfg.conv_width - 1 - s, 0), 0), (0, 0))
            )[:, -(cfg.conv_width - 1):]
            uc = self._conv_full(p, u)
            y, h_fin = _rg_lru_apply(p, uc)
            x = x + dense(p["out"], y * gate)
            x = x + self._mlp(p["mlp"], rms_norm(p["ln_mlp"], x))
            return x, (conv_state.astype(cfg.dtype), h_fin)

        def capture_attn(p, x):
            cfg_ = self.cfg
            w = cfg_.window
            xin = rms_norm(p["ln"], x)
            k = dense(p["wk"], xin).reshape(b, s, cfg_.n_kv_heads, cfg_.hd)
            v = dense(p["wv"], xin).reshape(b, s, cfg_.n_kv_heads, cfg_.hd)
            inv = rope_freqs(cfg_.hd, cfg_.rope_theta)
            k = apply_rope(k, positions, inv)
            cache_a = self._attn_cache0(b)
            take = min(s, w)
            tail_pos = positions[:, -take:]
            slots = tail_pos[0] % w
            # scatter tail tokens into their ring slots
            ck = cache_a["k"].at[:, slots].set(k[:, -take:].astype(cfg_.dtype))
            cv = cache_a["v"].at[:, slots].set(v[:, -take:].astype(cfg_.dtype))
            cp = cache_a["pos"].at[:, slots].set(tail_pos)
            x_full, _ = self._attn_apply(p, x, positions)
            return x_full, {"k": ck, "v": cv, "pos": cp}

        def sup_body(carry, lp):
            x = carry
            states = {}
            for j, kind in enumerate(cfg.pattern):
                sub = lp[f"sub{j}"]
                if kind == "rec":
                    x, st = capture_rec(sub, x)
                else:
                    x, st = capture_attn(sub, x)
                states[f"sub{j}"] = st
            return x, states

        x, sup_states = jax.lax.scan(sup_body, x, params["blocks"])
        new_cache = {"blocks": sup_states}
        if cfg.n_tail:
            def tail_body(carry, lp):
                return capture_rec(lp, carry)
            x, tail_states = jax.lax.scan(tail_body, x, params["tail"])
            new_cache["tail"] = tail_states
        return x, new_cache

    def decode_step(self, params, cache, token, pos
                    ) -> tuple[jax.Array, PyTree]:
        x, new_cache = self._backbone(params, token, cache, pos,
                                      positions=pos[:, None])
        return self._head(params, x), new_cache

    # -- structure -------------------------------------------------------------
    def unit_layout(self) -> UnitLayout:
        cfg = self.cfg
        entries = [UnitEntry("embed", "embed", None)]
        entries += [UnitEntry(f"super_{i}", "blocks", i)
                    for i in range(cfg.n_super)]
        entries += [UnitEntry(f"tail_{i}", "tail", i)
                    for i in range(cfg.n_tail)]
        entries.append(UnitEntry("head", "head", None))
        return UnitLayout(tuple(entries))

    def _rec_param_count(self) -> int:
        cfg = self.cfg
        d, lru, nb = cfg.d_model, cfg.lru, cfg.n_heads
        n = d + 2 * d * lru                                  # ln + in projs
        n += cfg.conv_width * lru + lru                      # conv
        n += 2 * (nb * (lru // nb) ** 2 + lru)               # gates
        n += lru                                             # lambda
        n += lru * d                                         # out
        n += d + 3 * d * cfg.d_ff                            # ln_mlp + mlp
        return n

    def _attn_param_count(self) -> int:
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.hd
        n = d + d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
            + cfg.n_heads * hd * d
        n += d + 3 * d * cfg.d_ff
        return n

    def _super_param_count(self) -> int:
        return sum(self._rec_param_count() if k == "rec"
                   else self._attn_param_count() for k in self.cfg.pattern)

    def param_count(self) -> int:
        cfg = self.cfg
        return (cfg.vocab * cfg.d_model
                + cfg.n_super * self._super_param_count()
                + cfg.n_tail * self._rec_param_count()
                + cfg.d_model)

    def active_param_count(self) -> int:
        return self.param_count()

    def layer_costs(self, batch: int, seq: int, *, mode: str = "train"):
        cfg = self.cfg
        tokens = batch * (seq if mode == "train" else 1)
        att_len = min(seq, cfg.window)
        out = [("embed", float(cfg.vocab * cfg.d_model),
                2.0 * tokens * cfg.d_model)]
        rec_f = 2.0 * tokens * (2 * cfg.d_model * cfg.lru
                                + 2 * cfg.lru ** 2 / cfg.n_heads
                                + cfg.lru * cfg.d_model
                                + 3 * cfg.d_model * cfg.d_ff)
        attn_f = 2.0 * tokens * (cfg.d_model * cfg.hd
                                 * (cfg.n_heads + 2 * cfg.n_kv_heads)
                                 + cfg.n_heads * cfg.hd * cfg.d_model
                                 + 3 * cfg.d_model * cfg.d_ff) \
            + 2.0 * tokens * att_len * cfg.n_heads * cfg.hd * 2
        sup_f = sum(rec_f if k == "rec" else attn_f for k in cfg.pattern)
        for i in range(cfg.n_super):
            out.append((f"super_{i}", float(self._super_param_count()),
                        sup_f))
        for i in range(cfg.n_tail):
            out.append((f"tail_{i}", float(self._rec_param_count()), rec_f))
        out.append(("head", float(cfg.d_model),
                    2.0 * tokens * cfg.d_model * cfg.vocab))
        return out
