"""Whisper-style encoder-decoder backbone (audio frontend = stub).

Per the assignment brief the modality frontend is a STUB: ``input_specs()``
supplies precomputed mel-frame embeddings ``[b, n_frames, d_model]`` (the
output of Whisper's two conv layers), and this module implements the
transformer backbone — 24 bidirectional encoder blocks, 24 causal decoder
blocks with cross-attention, pre-LayerNorm, GELU MLPs, learned decoder
positions, tied output head.

``max_positions`` is configured to the assigned stress shape (32k decode
exercises the *backbone*, not Whisper's real 448-token decoder limit —
DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..core.partial_sync import UnitEntry, UnitLayout
from .layers import (Init, dense, dense_init, gqa_attention, layer_norm,
                     norm_init, softmax_xent)

__all__ = ["WhisperConfig", "WhisperModel"]

PyTree = Any


@dataclass(frozen=True)
class WhisperConfig:
    name: str
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_frames: int = 1500
    max_positions: int = 448
    param_dtype: str = "bfloat16"
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.d_model // self.n_heads

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)


def _sinusoid(length: int, d: int) -> jax.Array:
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    t = jnp.arange(length)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


class WhisperModel:
    # decoder self-KV is position-addressed + length-masked: right-padded
    # (chunked) prefill cannot leak into decode
    kv_position_indexed = True

    def __init__(self, cfg: WhisperConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def _attn_init(self, init: Init, *, bias_v: bool = True):
        cfg = self.cfg
        d = cfg.d_model
        return {
            "wq": dense_init(init, d, d, bias=True, dtype=cfg.dtype,
                             out_axis="heads")[0],
            "wk": dense_init(init, d, d, bias=False, dtype=cfg.dtype,
                             out_axis="heads")[0],
            "wv": dense_init(init, d, d, bias=bias_v, dtype=cfg.dtype,
                             out_axis="heads")[0],
            "wo": dense_init(init, d, d, bias=True, dtype=cfg.dtype,
                             scale=d ** -0.5, in_axis="heads")[0],
        }

    def _mlp_init(self, init: Init):
        cfg = self.cfg
        return {
            "up": dense_init(init, cfg.d_model, cfg.d_ff, bias=True,
                             dtype=cfg.dtype, out_axis="ff")[0],
            "down": dense_init(init, cfg.d_ff, cfg.d_model, bias=True,
                               dtype=cfg.dtype, scale=cfg.d_ff ** -0.5,
                               in_axis="ff")[0],
        }

    def _enc_block_init(self, key: jax.Array):
        cfg = self.cfg
        init = Init(key)
        return {
            "ln1": norm_init(cfg.d_model, dtype=cfg.dtype, bias=True)[0],
            "attn": self._attn_init(init),
            "ln2": norm_init(cfg.d_model, dtype=cfg.dtype, bias=True)[0],
            "mlp": self._mlp_init(init),
        }

    def _dec_block_init(self, key: jax.Array):
        cfg = self.cfg
        init = Init(key)
        return {
            "ln1": norm_init(cfg.d_model, dtype=cfg.dtype, bias=True)[0],
            "self_attn": self._attn_init(init),
            "ln_x": norm_init(cfg.d_model, dtype=cfg.dtype, bias=True)[0],
            "cross_attn": self._attn_init(init),
            "ln2": norm_init(cfg.d_model, dtype=cfg.dtype, bias=True)[0],
            "mlp": self._mlp_init(init),
        }

    def init(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        init = Init(k1)
        params: dict = {
            "embed": {
                "table": init.normal((cfg.vocab, cfg.d_model), 1.0,
                                     cfg.dtype),
                "pos": init.normal((cfg.max_positions, cfg.d_model), 0.02,
                                   cfg.dtype),
            },
        }
        ekeys = jax.random.split(k2, cfg.n_enc_layers)
        params["enc_blocks"] = jax.vmap(self._enc_block_init)(ekeys)
        params["bridge"] = {"ln": norm_init(cfg.d_model, dtype=cfg.dtype,
                                            bias=True)[0]}
        dkeys = jax.random.split(k3, cfg.n_dec_layers)
        params["dec_blocks"] = jax.vmap(self._dec_block_init)(dkeys)
        params["head"] = {"norm": norm_init(cfg.d_model, dtype=cfg.dtype,
                                            bias=True)[0]}
        return params

    def param_specs(self) -> PyTree:
        """Logical-axis specs: attention/MLP matrices shard their output
        (or input, for down/out projections) dim over ``heads``->model."""
        shapes = jax.eval_shape(self.init, jax.random.PRNGKey(0))

        def one(sds):
            nd = len(sds.shape)
            if nd <= 2:                     # stacked biases / norm scales
                return ("layers",) + (None,) * (nd - 1) if nd else ()
            # stacked weight [n_layers, d_in, d_out]: shard the larger of
            # the two matrix dims
            dims = [None] * nd
            dims[0] = "layers"
            widest = max(range(1, nd), key=lambda i: sds.shape[i])
            dims[widest] = "heads"
            return tuple(dims)

        specs = jax.tree.map(one, shapes)
        specs["embed"] = {"table": ("vocab", None), "pos": (None, None)}
        specs["bridge"] = {"ln": {"scale": (None,), "bias": (None,)}}
        specs["head"] = {"norm": {"scale": (None,), "bias": (None,)}}
        return specs

    # ----------------------------------------------------------------- apply
    def _mha(self, p, xq, xkv=None, *, causal, q_pos=None, kv_pos=None,
             kv_valid=None, cache_kv=None):
        cfg = self.cfg
        b, sq, _ = xq.shape
        q = dense(p["wq"], xq).reshape(b, sq, cfg.n_heads, cfg.hd)
        if cache_kv is not None:
            k, v = cache_kv
        else:
            src = xq if xkv is None else xkv
            sk = src.shape[1]
            k = dense(p["wk"], src).reshape(b, sk, cfg.n_heads, cfg.hd)
            v = dense(p["wv"], src).reshape(b, sk, cfg.n_heads, cfg.hd)
        out = gqa_attention(q, k, v, causal=causal, q_positions=q_pos,
                            kv_positions=kv_pos, kv_valid_len=kv_valid)
        return dense(p["wo"], out.reshape(b, sq, -1)), (k, v)

    def _enc_block(self, p, x):
        a, _ = self._mha(p["attn"], layer_norm(p["ln1"], x), causal=False)
        x = x + a
        h = layer_norm(p["ln2"], x)
        return x + dense(p["mlp"]["down"],
                         jax.nn.gelu(dense(p["mlp"]["up"], h)))

    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames ``[b, n_frames, d]`` (precomputed conv-frontend output)."""
        cfg = self.cfg
        x = frames.astype(cfg.dtype) \
            + _sinusoid(frames.shape[1], cfg.d_model).astype(cfg.dtype)

        def body(carry, lp):
            fn = self._enc_block
            if cfg.remat:
                fn = jax.checkpoint(fn)
            return fn(lp, carry), None

        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return layer_norm(params["bridge"]["ln"], x)

    def _dec_block(self, p, x, enc_out, positions, self_cache=None,
                   write_pos=None, cross_kv=None):
        b, s, _ = x.shape
        if self_cache is None:
            a, _ = self._mha(p["self_attn"], layer_norm(p["ln1"], x),
                             causal=True, q_pos=positions, kv_pos=positions)
            new_self = None
        else:
            xq = layer_norm(p["ln1"], x)
            q = dense(p["self_attn"]["wq"], xq)
            k_new = dense(p["self_attn"]["wk"], xq)
            v_new = dense(p["self_attn"]["wv"], xq)
            pos0 = write_pos[0]
            ck = jax.lax.dynamic_update_slice_in_dim(
                self_cache["k"],
                k_new.reshape(b, s, self.cfg.n_heads,
                              self.cfg.hd).astype(self_cache["k"].dtype),
                pos0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                self_cache["v"],
                v_new.reshape(b, s, self.cfg.n_heads,
                              self.cfg.hd).astype(self_cache["v"].dtype),
                pos0, axis=1)
            sk = ck.shape[1]
            kv_pos = jnp.broadcast_to(jnp.arange(sk), (b, sk))
            att = gqa_attention(
                q.reshape(b, s, self.cfg.n_heads, self.cfg.hd), ck, cv,
                causal=True, q_positions=positions, kv_positions=kv_pos,
                kv_valid_len=write_pos + s)
            a = dense(p["self_attn"]["wo"], att.reshape(b, s, -1))
            new_self = {"k": ck, "v": cv}
        x = x + a
        ca, kv = self._mha(p["cross_attn"], layer_norm(p["ln_x"], x),
                           enc_out, causal=False, cache_kv=cross_kv)
        x = x + ca
        h = layer_norm(p["ln2"], x)
        x = x + dense(p["mlp"]["down"],
                      jax.nn.gelu(dense(p["mlp"]["up"], h)))
        return x, new_self, kv

    def _decode_stack(self, params, tokens, enc_out, *, cache=None,
                      write_pos=None, positions=None):
        cfg = self.cfg
        b, s = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x = params["embed"]["table"][tokens] \
            + params["embed"]["pos"][positions]

        if cache is None:
            def body(carry, lp):
                fn = lambda q, c: self._dec_block(q, c, enc_out,
                                                  positions)[0]
                if cfg.remat:
                    fn = jax.checkpoint(fn)
                return fn(lp, carry), None
            x, _ = jax.lax.scan(body, x, params["dec_blocks"])
            return x, None

        def body(carry, xs):
            lp, lc = xs
            y, new_self, kv = self._dec_block(
                lp, carry, enc_out, positions, self_cache=lc["self"],
                write_pos=write_pos,
                cross_kv=(lc["cross_k"], lc["cross_v"]))
            return y, {"self": new_self, "cross_k": kv[0], "cross_v": kv[1]}

        x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
        return x, new_cache

    def apply(self, params, tokens, frames) -> jax.Array:
        enc_out = self.encode(params, frames)
        x, _ = self._decode_stack(params, tokens, enc_out)
        x = layer_norm(params["head"]["norm"], x)
        return x @ params["embed"]["table"].T

    def loss(self, params, batch, *, segment_cuts=()) -> jax.Array:
        logits = self.apply(params, batch["tokens"], batch["frames"])
        return softmax_xent(logits[:, :-1], batch["labels"][:, 1:])

    # --------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_seq: int) -> PyTree:
        cfg = self.cfg
        one = {
            "self": {
                "k": jnp.zeros((batch, max_seq, cfg.n_heads, cfg.hd),
                               cfg.dtype),
                "v": jnp.zeros((batch, max_seq, cfg.n_heads, cfg.hd),
                               cfg.dtype),
            },
            "cross_k": jnp.zeros((batch, cfg.n_frames, cfg.n_heads, cfg.hd),
                                 cfg.dtype),
            "cross_v": jnp.zeros((batch, cfg.n_frames, cfg.n_heads, cfg.hd),
                                 cfg.dtype),
        }
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None],
                                       (cfg.n_dec_layers,) + a.shape), one)

    def prefill(self, params, tokens, cache, frames
                ) -> tuple[jax.Array, PyTree]:
        """Encode audio, cache cross-KV, prefill decoder self-KV."""
        enc_out = self.encode(params, frames)
        b, s = tokens.shape
        write_pos = jnp.zeros((b,), jnp.int32)
        # cross-KV must be computed fresh from enc_out: pass zeros and let
        # _dec_block recompute?  No — cache_kv short-circuits; so compute it
        # here layer-by-layer inside the scan by passing cache_kv=None.
        cfg = self.cfg
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x = params["embed"]["table"][tokens] \
            + params["embed"]["pos"][positions]

        def body(carry, xs):
            lp, lc = xs
            y, new_self, kv = self._dec_block(
                lp, carry, enc_out, positions, self_cache=lc["self"],
                write_pos=write_pos, cross_kv=None)
            return y, {"self": new_self, "cross_k": kv[0], "cross_v": kv[1]}

        x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
        x = layer_norm(params["head"]["norm"], x[:, -1:])
        return x @ params["embed"]["table"].T, new_cache

    def decode_step(self, params, cache, token, pos
                    ) -> tuple[jax.Array, PyTree]:
        """One-token decode against cached self/cross KV (no re-encode)."""
        x, new_cache = self._decode_stack(
            params, token, None, cache=cache, write_pos=pos,
            positions=pos[:, None])
        x = layer_norm(params["head"]["norm"], x)
        return x @ params["embed"]["table"].T, new_cache

    # ------------------------------------------------------------- structure
    def unit_layout(self) -> UnitLayout:
        cfg = self.cfg
        entries = [UnitEntry("embed", "embed", None)]
        entries += [UnitEntry(f"enc_{i}", "enc_blocks", i)
                    for i in range(cfg.n_enc_layers)]
        entries.append(UnitEntry("bridge", "bridge", None))
        entries += [UnitEntry(f"dec_{i}", "dec_blocks", i)
                    for i in range(cfg.n_dec_layers)]
        entries.append(UnitEntry("head", "head", None))
        return UnitLayout(tuple(entries))

    def _attn_params(self) -> int:
        d = self.cfg.d_model
        return 4 * d * d + 3 * d          # q,k,v,o + q/v/o biases

    def _mlp_params(self) -> int:
        cfg = self.cfg
        return 2 * cfg.d_model * cfg.d_ff + cfg.d_ff + cfg.d_model

    def _enc_block_params(self) -> int:
        return self._attn_params() + self._mlp_params() \
            + 4 * self.cfg.d_model

    def _dec_block_params(self) -> int:
        return 2 * self._attn_params() + self._mlp_params() \
            + 6 * self.cfg.d_model

    def param_count(self) -> int:
        cfg = self.cfg
        return (cfg.vocab * cfg.d_model + cfg.max_positions * cfg.d_model
                + cfg.n_enc_layers * self._enc_block_params()
                + 2 * cfg.d_model                       # bridge ln
                + cfg.n_dec_layers * self._dec_block_params()
                + 2 * cfg.d_model)                      # head ln

    def active_param_count(self) -> int:
        return self.param_count()

    def layer_costs(self, batch: int, seq: int, *, mode: str = "train"):
        cfg = self.cfg
        d = cfg.d_model
        enc_t = batch * cfg.n_frames
        dec_t = batch * (seq if mode == "train" else 1)
        kv_len = seq
        out = [("embed", float((cfg.vocab + cfg.max_positions) * d),
                2.0 * dec_t * d)]
        enc_f = 2.0 * enc_t * (4 * d * d + 2 * d * cfg.d_ff) \
            + 2.0 * enc_t * cfg.n_frames * d * 2
        if mode != "train":
            enc_f = 0.0                    # decode: audio already encoded
        for i in range(cfg.n_enc_layers):
            out.append((f"enc_{i}", float(self._enc_block_params()), enc_f))
        out.append(("bridge", float(2 * d), 0.0))
        dec_f = 2.0 * dec_t * (8 * d * d + 2 * d * cfg.d_ff) \
            + 2.0 * dec_t * kv_len * d * 2 \
            + 2.0 * dec_t * cfg.n_frames * d * 2
        for i in range(cfg.n_dec_layers):
            out.append((f"dec_{i}", float(self._dec_block_params()), dec_f))
        out.append(("head", float(2 * d), 2.0 * dec_t * d * cfg.vocab))
        return out
