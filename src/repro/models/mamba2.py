"""Mamba-2 (state-space duality / SSD) language model.

The SSD forward is the chunked algorithm of arXiv:2405.21060: quadratic
attention-like compute inside chunks (MXU-friendly) + a linear recurrence
across chunk states.  Decode is the O(1)-state recurrent update, which is
what makes the ``long_500k`` cell runnable (no KV cache, constant memory in
sequence length).

Layout follows the reference implementation: ``in_proj`` emits
``[z, x, B, C, dt]``; a causal depthwise conv (width 4) runs over
``[x, B, C]``; the SSD core uses per-head scalar decay ``A``; output is
gated-RMSNormed and projected back.

A Pallas kernel for the chunk-local core lives in
``repro.kernels.ssd_scan`` (this module is its ``ref`` semantics).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..core.partial_sync import UnitEntry, UnitLayout
from .layers import Init, dense, norm_init, rms_norm, softmax_xent

__all__ = ["Mamba2Config", "Mamba2LM", "ssd_chunked", "ssd_decode_step"]

PyTree = Any


@dataclass(frozen=True)
class Mamba2Config:
    name: str
    n_layers: int
    d_model: int
    vocab: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128
    param_dtype: str = "float32"
    remat: bool = True
    tie_embeddings: bool = True

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def d_in_proj(self) -> int:
        return 2 * self.d_inner + 2 * self.n_groups * self.d_state \
            + self.n_heads

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)


# ---------------------------------------------------------------------------
# SSD core (chunked, pure jnp — the kernel oracle)
# ---------------------------------------------------------------------------

def _segsum(x: jax.Array) -> jax.Array:
    """Lower-triangular segment sums: out[..., i, j] = sum_{j<k<=i} x[..., k]."""
    t = x.shape[-1]
    c = jnp.cumsum(x, -1)
    diff = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                b: jax.Array, c: jax.Array, chunk: int,
                init_state: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x ``[B, L, H, P]``, dt ``[B, L, H]`` (post-softplus), a_log ``[H]``,
    b / c ``[B, L, G, N]`` with ``H % G == 0``.  Sequences are padded to a
    chunk multiple with ``dt = 0`` steps (identity state updates).
    Returns (y ``[B, L, H, P]``, final_state ``[B, H, P, N]``).
    """
    l_orig = x.shape[1]
    pad = (-l_orig) % chunk
    if pad:
        padt = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (a.ndim - 2))
        x, dt, b, c = padt(x), padt(dt), padt(b), padt(c)
    bs, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    nc = l // chunk
    rep = h // g

    # fold dt into the input; decay per step
    xdt = (x * dt[..., None]).reshape(bs, nc, chunk, h, p)
    da = (dt * (-jnp.exp(a_log.astype(jnp.float32)))).reshape(bs, nc, chunk, h)
    bq = jnp.repeat(b.reshape(bs, nc, chunk, g, n), rep, axis=3)
    cq = jnp.repeat(c.reshape(bs, nc, chunk, g, n), rep, axis=3)

    seg = _segsum(jnp.moveaxis(da, -1, -2))          # [B,nc,H,cs,cs]
    L = jnp.exp(seg)
    # intra-chunk (quadratic, attention-like)
    y_diag = jnp.einsum("bzihn,bzjhn,bzhij,bzjhp->bzihp",
                        cq, bq, L.astype(cq.dtype), xdt)

    # chunk output states
    cum = jnp.cumsum(da, axis=2)                      # [B,nc,cs,H]
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)   # [B,nc,cs,H]
    states = jnp.einsum("bzjhn,bzjh,bzjhp->bzhpn",
                        bq, decay_states.astype(bq.dtype), xdt)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cum[:, :, -1, :])           # [B,nc,H]

    def scan_fn(carry, inp):
        s, d = inp                                    # [B,H,P,N], [B,H]
        new = carry * d[..., None, None].astype(carry.dtype) + s
        return new, carry                             # emit state *before*

    init = (jnp.zeros_like(states[:, 0]) if init_state is None
            else init_state.astype(states.dtype))
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)     # [B,nc,H,P,N]

    # inter-chunk contribution
    state_decay = jnp.exp(cum)                        # [B,nc,cs,H]
    y_off = jnp.einsum("bzihn,bzhpn,bzih->bzihp",
                       cq, prev_states, state_decay.astype(cq.dtype))

    y = (y_diag + y_off).reshape(bs, l, h, p)
    return y[:, :l_orig], final


def ssd_decode_step(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                    b: jax.Array, c: jax.Array, state: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """O(1) recurrent step.  x ``[B,H,P]``, dt ``[B,H]``, b/c ``[B,G,N]``,
    state ``[B,H,P,N]``."""
    h, g = x.shape[1], b.shape[1]
    rep = h // g
    bq = jnp.repeat(b, rep, axis=1)                   # [B,H,N]
    cq = jnp.repeat(c, rep, axis=1)
    da = jnp.exp(dt * (-jnp.exp(a_log.astype(jnp.float32))))
    xdt = x * dt[..., None]
    new_state = state * da[..., None, None].astype(state.dtype) \
        + jnp.einsum("bhp,bhn->bhpn", xdt, bq)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, cq)
    return y, new_state


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Mamba2LM:
    # recurrent state folds every prefill step in (pad steps included), so
    # right-padded (chunked) prefill would corrupt it — exact prefill only
    kv_position_indexed = False

    def __init__(self, cfg: Mamba2Config):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def _block_init(self, key: jax.Array):
        cfg = self.cfg
        init = Init(key)
        d = cfg.d_model
        p = {
            "ln": norm_init(d, dtype=cfg.dtype)[0],
            "in_proj": {"w": init.normal((d, cfg.d_in_proj), d ** -0.5,
                                         cfg.dtype)},
            "conv": init.normal((cfg.conv_width, cfg.conv_dim),
                                cfg.conv_width ** -0.5, cfg.dtype),
            "conv_bias": jnp.zeros((cfg.conv_dim,), cfg.dtype),
            "a_log": jnp.log(jnp.linspace(1.0, 16.0, cfg.n_heads,
                                          dtype=jnp.float32)),
            "dt_bias": jnp.zeros((cfg.n_heads,), jnp.float32),
            "d_skip": jnp.ones((cfg.n_heads,), jnp.float32),
            "out_norm": norm_init(cfg.d_inner, dtype=cfg.dtype)[0],
            "out_proj": {"w": init.normal((cfg.d_inner, d),
                                          cfg.d_inner ** -0.5, cfg.dtype)},
        }
        spec = {
            "ln": {"scale": (None,)},
            "in_proj": {"w": (None, "heads")},
            "conv": (None, "heads"),
            "conv_bias": ("heads",),
            "a_log": ("heads",),
            "dt_bias": ("heads",),
            "d_skip": ("heads",),
            "out_norm": {"scale": ("heads",)},
            "out_proj": {"w": ("heads", None)},
        }
        return p, spec

    def init(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        init = Init(k1)
        params: dict = {
            "embed": {"table": init.normal((cfg.vocab, cfg.d_model), 1.0,
                                           cfg.dtype)},
        }
        lkeys = jax.random.split(k2, cfg.n_layers)
        params["blocks"] = jax.vmap(lambda k: self._block_init(k)[0])(lkeys)
        head: dict = {"norm": norm_init(cfg.d_model, dtype=cfg.dtype)[0]}
        if not cfg.tie_embeddings:
            head["out"] = {"w": Init(k3).normal(
                (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5, cfg.dtype)}
        params["head"] = head
        return params

    def param_specs(self) -> PyTree:
        box: dict = {}

        def fn(k):
            p, s = self._block_init(k)
            box["spec"] = s
            return p

        jax.eval_shape(fn, jax.random.PRNGKey(0))
        blk = jax.tree.map(lambda sp: ("layers",) + tuple(sp), box["spec"],
                           is_leaf=lambda x: isinstance(x, tuple))
        specs = {"embed": {"table": ("vocab", None)}, "blocks": blk,
                 "head": {"norm": {"scale": (None,)}}}
        if not self.cfg.tie_embeddings:
            specs["head"]["out"] = {"w": (None, "vocab")}
        return specs

    # ----------------------------------------------------------------- apply
    def _split_proj(self, zxbcdt: jax.Array):
        cfg = self.cfg
        return jnp.split(
            zxbcdt,
            [cfg.d_inner, 2 * cfg.d_inner,
             2 * cfg.d_inner + cfg.n_groups * cfg.d_state,
             2 * cfg.d_inner + 2 * cfg.n_groups * cfg.d_state],
            axis=-1)

    def _conv_full(self, p, u: jax.Array) -> jax.Array:
        """Causal depthwise conv over time.  u ``[B, L, C]``."""
        w = p["conv"]                                  # [W, C]
        width = w.shape[0]
        pad = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
        out = sum(pad[:, i:i + u.shape[1]] * w[i] for i in range(width))
        return jax.nn.silu(out + p["conv_bias"])

    def _block_core(self, p, x: jax.Array, conv_state=None, ssm_state=None):
        """Returns (y, new_conv_state, new_ssm_state).  Full-seq when states
        are None (train/prefill), O(1) step when given (decode, L == 1)."""
        cfg = self.cfg
        b, l, _ = x.shape
        z, xc, bmat, cmat, dt = self._split_proj(dense(p["in_proj"], x))
        conv_in = jnp.concatenate([xc, bmat, cmat], -1)

        if conv_state is None:
            conv_out = self._conv_full(p, conv_in)
            new_conv_state = None
            if False:
                pass  # ssd_chunked pads internally
        else:
            # roll the conv window: state [B, W-1, C]
            hist = jnp.concatenate([conv_state, conv_in], 1)
            new_conv_state = hist[:, 1:]
            w = p["conv"]
            conv_out = jax.nn.silu(
                jnp.einsum("bwc,wc->bc", hist, w) + p["conv_bias"])[:, None]

        xq, bq, cq = jnp.split(
            conv_out, [cfg.d_inner, cfg.d_inner + cfg.n_groups * cfg.d_state],
            axis=-1)
        xq = xq.reshape(b, l, cfg.n_heads, cfg.head_dim)
        bq = bq.reshape(b, l, cfg.n_groups, cfg.d_state)
        cq = cq.reshape(b, l, cfg.n_groups, cfg.d_state)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

        if ssm_state is None:
            y, final = ssd_chunked(xq, dt, p["a_log"], bq, cq, cfg.chunk)
        else:
            y1, final = ssd_decode_step(xq[:, 0], dt[:, 0], p["a_log"],
                                        bq[:, 0], cq[:, 0], ssm_state)
            y = y1[:, None]
        y = y + xq * p["d_skip"][:, None].astype(y.dtype)
        y = y.reshape(b, l, cfg.d_inner)
        y = rms_norm(p["out_norm"], y * jax.nn.silu(z))
        return dense(p["out_proj"], y), new_conv_state, final

    def _block_apply(self, p, x, conv_state=None, ssm_state=None):
        y, ncs, nss = self._block_core(p, rms_norm(p["ln"], x),
                                       conv_state, ssm_state)
        return x + y.astype(x.dtype), ncs, nss

    def _backbone(self, params, tokens, cache=None):
        cfg = self.cfg
        x = params["embed"]["table"][tokens]

        if cache is None:
            def body(carry, lp):
                fn = self._block_apply
                if cfg.remat:
                    fn = jax.checkpoint(fn)
                y, _, _ = fn(lp, carry)
                return y, None
            x, _ = jax.lax.scan(body, x, params["blocks"])
            return x, None

        def body(carry, xs):
            lp, (cs, ss) = xs
            y, ncs, nss = self._block_apply(lp, carry, cs, ss)
            return y, (ncs, nss)
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        return x, new_cache

    def _head(self, params, x):
        x = rms_norm(params["head"]["norm"], x)
        if self.cfg.tie_embeddings:
            return x @ params["embed"]["table"].T
        return dense(params["head"]["out"], x)

    def apply(self, params, tokens) -> jax.Array:
        x, _ = self._backbone(params, tokens)
        return self._head(params, x)

    def loss(self, params, batch, *, segment_cuts=()) -> jax.Array:
        logits = self.apply(params, batch["tokens"])
        return softmax_xent(logits[:, :-1], batch["labels"][:, 1:])

    # --------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_seq: int) -> PyTree:
        cfg = self.cfg
        one = (
            jnp.zeros((batch, cfg.conv_width - 1, cfg.conv_dim), cfg.dtype),
            jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                      jnp.float32),
        )
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape),
            one)

    def prefill(self, params, tokens, cache) -> tuple[jax.Array, PyTree]:
        """Run the full sequence, emitting per-layer final states."""
        cfg = self.cfg
        x = params["embed"]["table"][tokens]

        def body(carry, xs):
            lp, _ = xs
            xin = rms_norm(lp["ln"], carry)
            b, l, _ = xin.shape
            z, xc, bmat, cmat, dt = self._split_proj(dense(lp["in_proj"],
                                                           xin))
            conv_in = jnp.concatenate([xc, bmat, cmat], -1)
            conv_out = self._conv_full(lp, conv_in)
            new_conv = conv_in[:, -(cfg.conv_width - 1):]
            xq = conv_out[..., :cfg.d_inner].reshape(b, l, cfg.n_heads,
                                                     cfg.head_dim)
            bq = conv_out[..., cfg.d_inner:cfg.d_inner + cfg.n_groups
                          * cfg.d_state].reshape(b, l, cfg.n_groups,
                                                 cfg.d_state)
            cq = conv_out[..., cfg.d_inner + cfg.n_groups
                          * cfg.d_state:].reshape(b, l, cfg.n_groups,
                                                  cfg.d_state)
            dtp = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
            y, final = ssd_chunked(xq, dtp, lp["a_log"], bq, cq, cfg.chunk)
            y = y + xq * lp["d_skip"][:, None].astype(y.dtype)
            y = y.reshape(b, l, cfg.d_inner)
            y = rms_norm(lp["out_norm"], y * jax.nn.silu(z))
            return carry + dense(lp["out_proj"], y).astype(carry.dtype), \
                (new_conv.astype(cfg.dtype), final)

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        return self._head(params, x[:, -1:]), new_cache

    def decode_step(self, params, cache, token, pos
                    ) -> tuple[jax.Array, PyTree]:
        x, new_cache = self._backbone(params, token, cache)
        return self._head(params, x), new_cache

    # ------------------------------------------------------------- structure
    def unit_layout(self) -> UnitLayout:
        entries = [UnitEntry("embed", "embed", None)]
        entries += [UnitEntry(f"layer_{i}", "blocks", i)
                    for i in range(self.cfg.n_layers)]
        entries.append(UnitEntry("head", "head", None))
        return UnitLayout(tuple(entries))

    def _block_param_count(self) -> int:
        cfg = self.cfg
        return (cfg.d_model                                     # ln
                + cfg.d_model * cfg.d_in_proj                   # in_proj
                + cfg.conv_width * cfg.conv_dim + cfg.conv_dim  # conv
                + 3 * cfg.n_heads                               # a/dt/D
                + cfg.d_inner                                   # out_norm
                + cfg.d_inner * cfg.d_model)                    # out_proj

    def param_count(self) -> int:
        cfg = self.cfg
        n = cfg.vocab * cfg.d_model + cfg.n_layers * self._block_param_count()
        n += cfg.d_model
        if not cfg.tie_embeddings:
            n += cfg.d_model * cfg.vocab
        return n

    def active_param_count(self) -> int:
        return self.param_count()

    def layer_costs(self, batch: int, seq: int, *, mode: str = "train"):
        cfg = self.cfg
        tokens = batch * (seq if mode == "train" else 1)
        out = [("embed", float(cfg.vocab * cfg.d_model),
                2.0 * tokens * cfg.d_model)]
        per_p = float(self._block_param_count())
        proj = 2.0 * tokens * cfg.d_model * (cfg.d_in_proj + cfg.d_inner)
        if mode == "train":
            ssd = 2.0 * tokens * cfg.chunk * cfg.n_heads * (
                cfg.d_state + cfg.head_dim) \
                + 4.0 * tokens * cfg.n_heads * cfg.head_dim * cfg.d_state
        else:
            ssd = 4.0 * tokens * cfg.n_heads * cfg.head_dim * cfg.d_state
        for i in range(cfg.n_layers):
            out.append((f"layer_{i}", per_p, proj + ssd))
        head_p = float(cfg.d_model + (0 if cfg.tie_embeddings
                                      else cfg.d_model * cfg.vocab))
        out.append(("head", head_p, 2.0 * tokens * cfg.d_model * cfg.vocab))
        return out
