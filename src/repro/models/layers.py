"""Neural-net primitives shared by the model zoo (pure functional JAX).

Conventions
-----------
* Parameters are plain dicts of ``jax.Array``; every builder returns
  ``(params, spec)`` where ``spec`` mirrors the params structure with
  *logical axis names* (strings or ``None``) used by
  :mod:`repro.parallel.sharding` to derive mesh shardings.
* Compute dtype is the params dtype (bf16 by default); softmax, norms and
  losses accumulate in float32.
* Attention is GQA throughout (MHA = ``n_kv == n_heads``); RoPE is the
  rotate-half convention.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "Init",
    "dense_init", "dense",
    "norm_init", "rms_norm", "layer_norm",
    "embed_init",
    "rope_freqs", "apply_rope",
    "gqa_attention",
    "mlp_init", "mlp_apply",
    "softmax_xent",
    "count_params",
]

PyTree = Any


class Init:
    """Keyed initializer stream (splits deterministically on demand)."""

    def __init__(self, key: jax.Array):
        self._key = key

    def next(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def normal(self, shape, scale: float, dtype) -> jax.Array:
        return (jax.random.normal(self.next(), shape, jnp.float32)
                * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Linear / norm / embedding
# ---------------------------------------------------------------------------

def dense_init(init: Init, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.bfloat16, scale: float | None = None,
               in_axis: str | None = None, out_axis: str | None = None):
    """Weight ``[d_in, d_out]`` (+ optional bias); returns (params, spec)."""
    scale = (d_in ** -0.5) if scale is None else scale
    p = {"w": init.normal((d_in, d_out), scale, dtype)}
    s = {"w": (in_axis, out_axis)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        s["b"] = (out_axis,)
    return p, s


def dense(p: PyTree, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d: int, *, dtype=jnp.bfloat16, bias: bool = False):
    p = {"scale": jnp.ones((d,), dtype)}
    s = {"scale": (None,)}
    if bias:
        p["bias"] = jnp.zeros((d,), dtype)
        s["bias"] = (None,)
    return p, s


def rms_norm(p: PyTree, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    """Moments in float32, normalized tensor in the storage dtype.

    Deliberately avoids materializing a full f32 copy of ``x``: with
    Megatron-TP the residual stream crosses per-layer all-reduces, and
    XLA's convert-sinking otherwise promotes those collectives to f32 —
    2x the wire bytes (measured in the §Perf granite hillclimb)."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                  keepdims=True)
    inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
    return x * inv * p["scale"]


def layer_norm(p: PyTree, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (x - mu.astype(x.dtype)) \
        * jax.lax.rsqrt(var + eps).astype(x.dtype) * p["scale"]
    if "bias" in p:
        y = y + p["bias"]
    return y


def embed_init(init: Init, vocab: int, d: int, *, dtype=jnp.bfloat16):
    p = {"table": init.normal((vocab, d), 1.0, dtype)}
    s = {"table": ("vocab", None)}
    return p, s


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies ``[head_dim // 2]`` (float32)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               inv_freq: jax.Array) -> jax.Array:
    """Rotate-half RoPE.  ``x: [b, s, n, hd]``, ``positions: [b, s]``."""
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # [b, s, hd/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal / local-window / cross, cached decode)
# ---------------------------------------------------------------------------

def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  q_positions: jax.Array | None = None,
                  kv_positions: jax.Array | None = None,
                  causal: bool = True,
                  window: int | None = None,
                  kv_valid_len: jax.Array | None = None,
                  scale: float | None = None,
                  q_chunk: int = 1024) -> jax.Array:
    """Grouped-query attention.

    q ``[b, sq, n_q, hd]``; k, v ``[b, sk, n_kv, hd]`` with
    ``n_q % n_kv == 0``.  KV heads are repeated to ``n_q`` so the head axis
    shards cleanly over the ``model`` mesh axis.  Masks are position-based,
    so the same code serves full-sequence training, windowed attention and
    one-token cached decode (``kv_valid_len`` masks unwritten cache slots).

    Long queries are processed in ``q_chunk`` blocks under ``jax.remat`` —
    the score tensor peaks at ``[b, n_q, q_chunk, sk]`` instead of
    ``[b, n_q, sq, sk]`` (memory-efficient attention; required for the
    32k-prefill cells).
    """
    b, sq, n_q, hd = q.shape
    _, sk, n_kv, _ = k.shape
    assert n_q % n_kv == 0, (n_q, n_kv)
    if n_kv != n_q:
        k = jnp.repeat(k, n_q // n_kv, axis=2)
        v = jnp.repeat(v, n_q // n_kv, axis=2)
    scale = (hd ** -0.5) if scale is None else scale

    # Pin the head dim to the tensor-parallel axis: GSPMD's solver
    # otherwise shards the 64-192-wide contraction dim and partial-sums
    # the full score map over `model` (3.3 TB/step in the whisper 32k
    # prefill cell — §Perf).  No-op without an ambient mesh.
    from ..parallel.sharding import maybe_constrain
    q = maybe_constrain(q, None, None, "model", None)
    k = maybe_constrain(k, None, None, "model", None)
    v = maybe_constrain(v, None, None, "model", None)

    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(sk), (b, sk))

    def attend(qc: jax.Array, qp: jax.Array) -> jax.Array:
        # qc [b, c, n, hd]; scores [b, n, c, sk]
        scores = jnp.einsum("bqnh,bsnh->bnqs", qc, k,
                            preferred_element_type=jnp.float32) * scale
        qpm = qp[:, None, :, None]
        kpm = kv_positions[:, None, None, :]
        mask = jnp.ones((b, 1, qc.shape[1], sk), bool)
        if causal:
            mask &= kpm <= qpm
        if window is not None:
            mask &= kpm > qpm - window
        if kv_valid_len is not None:
            mask &= kpm < kv_valid_len[:, None, None, None]
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(qc.dtype)
        return jnp.einsum("bnqs,bsnh->bqnh", probs, v)

    if q_chunk is None or sq <= q_chunk:
        return attend(q, q_positions)

    pad = (-sq) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)))
    nc = q.shape[1] // q_chunk
    qs = q.reshape(b, nc, q_chunk, n_q, hd)
    ps = q_positions.reshape(b, nc, q_chunk)

    def body(_, xs):
        qc, pc = xs
        return None, jax.checkpoint(attend)(qc, pc)

    _, out = jax.lax.scan(body, None,
                          (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(ps, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, -1, n_q, hd)
    return out[:, :sq]


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(init: Init, d_model: int, d_ff: int, *, kind: str = "swiglu",
             dtype=jnp.bfloat16):
    """SwiGLU (gate+up+down) or GELU (up+down) feed-forward."""
    p, s = {}, {}
    if kind == "swiglu":
        p["gate"], s["gate"] = dense_init(init, d_model, d_ff, dtype=dtype,
                                          out_axis="ff")
        p["up"], s["up"] = dense_init(init, d_model, d_ff, dtype=dtype,
                                      out_axis="ff")
    elif kind == "gelu":
        p["up"], s["up"] = dense_init(init, d_model, d_ff, dtype=dtype,
                                      out_axis="ff")
    else:
        raise ValueError(kind)
    p["down"], s["down"] = dense_init(
        init, d_ff, d_model, dtype=dtype,
        scale=d_ff ** -0.5, in_axis="ff")
    return p, s


def mlp_apply(p: PyTree, x: jax.Array, *, kind: str = "swiglu") -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x)
    else:
        h = jax.nn.gelu(dense(p["up"], x))
    return dense(p["down"], h)


# ---------------------------------------------------------------------------
# Loss / misc
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array,
                 ignore_index: int = -100) -> jax.Array:
    """Mean token cross-entropy in float32; ``labels == ignore_index`` masked."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    ok = labels != ignore_index
    return jnp.sum(nll * ok) / jnp.maximum(jnp.sum(ok), 1)


def count_params(tree: PyTree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))
