"""Mixture-of-Experts feed-forward (token-choice top-k, capacity-based).

Shardability is the design driver: the dispatch/combine one-hot einsums keep
`expert` and `batch` as *free* dimensions, so with experts sharded over the
``model`` mesh axis and batch over ``data`` the whole MoE layer partitions
with **zero resharding collectives** (the per-device dispatch matmul is the
price — it is counted and discussed in the roofline analysis; the
§Perf hillclimb offers a gather-based alternative).

Capacity is per-sequence (``C = ceil(S * k / E * capacity_factor)``), the
MaxText/Switch convention; overflow tokens are dropped (their combine weight
is zero), underflow slots compute on zeros.

Routing variants:

* ``router="softmax"`` — softmax over all expert logits, renormalized top-k
  (Qwen3-MoE);
* ``router="sigmoid"`` — sigmoid scores, top-k, normalize, scale
  (DeepSeek-V3's noaux-tc routing, sans the aux-loss-free bias update);
  plus ``n_shared`` always-on shared experts (DeepSeek-V3: 1).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .layers import Init, dense

__all__ = ["MoEConfig", "moe_init", "moe_apply", "moe_param_count",
           "moe_fwd_flops"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden size
    n_shared: int = 0            # always-on shared experts
    capacity_factor: float = 1.25
    router: str = "softmax"      # or "sigmoid"
    routed_scale: float = 1.0    # DeepSeek routed_scaling_factor (2.5 for V3)

    def capacity(self, seq_len: int) -> int:
        c = int(seq_len * self.top_k / self.n_experts * self.capacity_factor)
        return max(c, self.top_k)


def moe_init(init: Init, cfg: MoEConfig, d_model: int, *, dtype=jnp.bfloat16):
    """Router + stacked expert SwiGLU weights (+ shared experts)."""
    e, f = cfg.n_experts, cfg.d_ff
    s_in = d_model ** -0.5
    s_out = f ** -0.5
    p = {
        "router": {"w": init.normal((d_model, e), s_in, jnp.float32)},
        "gate": init.normal((e, d_model, f), s_in, dtype),
        "up": init.normal((e, d_model, f), s_in, dtype),
        "down": init.normal((e, f, d_model), s_out, dtype),
    }
    spec = {
        "router": {"w": (None, None)},
        "gate": ("expert", None, "ff"),
        "up": ("expert", None, "ff"),
        "down": ("expert", "ff", None),
    }
    if cfg.n_shared:
        fs = f * cfg.n_shared
        p["shared"] = {
            "gate": {"w": init.normal((d_model, fs), s_in, dtype)},
            "up": {"w": init.normal((d_model, fs), s_in, dtype)},
            "down": {"w": init.normal((fs, d_model), s_out, dtype)},
        }
        spec["shared"] = {
            "gate": {"w": (None, "ff")},
            "up": {"w": (None, "ff")},
            "down": {"w": ("ff", None)},
        }
    return p, spec


def _route(cfg: MoEConfig, logits: jax.Array):
    """Top-k routing -> (weights [b,s,k], indices [b,s,k]) in float32."""
    if cfg.router == "softmax":
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    elif cfg.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        w, idx = jax.lax.top_k(scores, cfg.top_k)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
        w = w * cfg.routed_scale
    else:
        raise ValueError(cfg.router)
    return w, idx


def moe_apply(p, cfg: MoEConfig, x: jax.Array) -> jax.Array:
    """x ``[b, s, d]`` -> ``[b, s, d]``; top-k routed + shared experts."""
    b, s, d = x.shape
    e, k, c = cfg.n_experts, cfg.top_k, cfg.capacity(s)

    logits = x.astype(jnp.float32) @ p["router"]["w"]        # [b,s,e]
    weights, idx = _route(cfg, logits)                       # [b,s,k]

    # --- capacity assignment (Switch-style, per sequence) -------------------
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)          # [b,s,k,e]
    # priority: sequence position major, then routing rank
    flat = onehot.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                        # [b,s*k,e]
    pos = pos.reshape(b, s, k, e)
    pos_of = jnp.sum(pos * onehot, axis=-1)                   # [b,s,k]
    keep = pos_of < c
    w_kept = weights * keep                                   # dropped -> 0

    # dispatch [b,s,e,c] / combine [b,s,e,c] one-hots.  Both kept in the
    # activation dtype: a f32 combine tensor drags f32 through the routed
    # path and doubles the MoE backward's collective bytes (§Perf dsv3
    # hillclimb); router weights stay exact in the [b,s,k] view.
    slot = jax.nn.one_hot(jnp.where(keep, pos_of, c), c, dtype=x.dtype)
    disp = jnp.einsum("bske,bskc->bsec",
                      onehot.astype(x.dtype) * keep[..., None], slot)
    comb = jnp.einsum("bske,bskc->bsec",
                      onehot.astype(x.dtype)
                      * w_kept[..., None].astype(x.dtype), slot)

    # --- expert compute (free dims: e over 'model', b over 'data') ----------
    # (§Perf note: constraining the FSDP-stored expert weights to a
    # gathered view was tried and REFUTED — per-microbatch regathers cost
    # more than the partial-sum all-reduces they replace.)
    xe = jnp.einsum("bsec,bsd->ebcd", disp, x)                # [e,b,c,d]
    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xe, p["gate"])) \
        * jnp.einsum("ebcd,edf->ebcf", xe, p["up"])
    ye = jnp.einsum("ebcf,efd->ebcd", h, p["down"])           # [e,b,c,d]
    out = jnp.einsum("bsec,ebcd->bsd", comb, ye)

    if cfg.n_shared:
        sh = p["shared"]
        hs = jax.nn.silu(dense(sh["gate"], x)) * dense(sh["up"], x)
        out = out + dense(sh["down"], hs)
    return out


# ---------------------------------------------------------------------------
# Analytic accounting (profiler / roofline)
# ---------------------------------------------------------------------------

def moe_param_count(cfg: MoEConfig, d_model: int) -> int:
    n = d_model * cfg.n_experts                      # router
    n += 3 * cfg.n_experts * d_model * cfg.d_ff      # routed experts
    n += 3 * cfg.n_shared * d_model * cfg.d_ff       # shared
    return n


def moe_active_param_count(cfg: MoEConfig, d_model: int) -> int:
    """Per-token active parameters (for MODEL_FLOPS = 6*N_active*D)."""
    n = d_model * cfg.n_experts
    n += 3 * cfg.top_k * d_model * cfg.d_ff
    n += 3 * cfg.n_shared * d_model * cfg.d_ff
    return n


def moe_fwd_flops(cfg: MoEConfig, d_model: int, tokens: int,
                  seq_len: int) -> float:
    """Forward FLOPs actually executed (incl. dispatch/combine einsums)."""
    c = cfg.capacity(seq_len)
    e = cfg.n_experts
    flops = 2.0 * tokens * d_model * e                       # router
    flops += 2.0 * tokens * e * c * d_model * 2              # dispatch+combine
    eff = tokens / seq_len * e * c                           # slot-tokens
    flops += 2.0 * eff * d_model * cfg.d_ff * 3              # expert SwiGLU
    flops += 2.0 * tokens * d_model * (cfg.n_shared * cfg.d_ff) * 3
    return flops
